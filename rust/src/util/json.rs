//! Minimal JSON parser / writer.
//!
//! The artifact manifest (`artifacts/manifest.json`) and synthesis plans
//! are JSON; with no serde in the vendored crate set, this is a small
//! recursive-descent implementation covering the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::parse("json", format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::parse("json", format!("expected usize, got {f}")));
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::parse("json", format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::parse("json", format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::parse("json", format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(Error::parse("json", format!("expected object, got {other:?}"))),
        }
    }

    /// Object field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::parse("json", format!("missing key {key:?}")))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// `[1,2,3]` → `vec![1,2,3]` for shape-like arrays.
    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- writer --------------------------------------------------------------

    /// Serialise; stable key order (BTreeMap) makes output diff-friendly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for constructing JSON programmatically.
impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn usizes(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let ctx_end = (self.pos + 20).min(self.b.len());
        let ctx = String::from_utf8_lossy(&self.b[self.pos..ctx_end]);
        Error::parse("json", format!("{msg} at byte {} (near {ctx:?})", self.pos))
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {word}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our writers;
                            // map unpaired surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is &str, so valid).
                    let rest = std::str::from_utf8(&self.b[self.pos..]).unwrap();
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v, Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":-3}}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_written() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn usize_vec_accessor() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(Json::parse("[1.5]").unwrap().usize_vec().is_err());
        assert!(Json::parse("[-1]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string(), "4");
        assert_eq!(Json::Num(4.5).to_string(), "4.5");
    }
}
