//! Bench: regenerate paper Table III (Cappuccino vs CNNDroid, AlexNet on
//! the Snapdragon 810).
//!
//! CNNDroid's execution strategy (per-layer GPU offload with host<->GPU
//! copies, conventional layout, no inexact modes) is implemented as its
//! own model over the same device constants — the comparison is between
//! *approaches*. Paper: 709 ms vs 512.72 ms (1.38x) vs 61.80 ms (11.47x).

use cappuccino::bench::Table;
use cappuccino::model::zoo;
use cappuccino::soc::{self, CnnDroidModel, ProcessingMode};

fn main() {
    let device = soc::devices::nexus6p();
    let net = zoo::alexnet();

    let droid = CnnDroidModel::for_device(&device).latency_ms(&net, &device);
    let par = soc::measure_trimmed(&net, &device, ProcessingMode::Parallel, 100, 0.01, 5);
    let imp = soc::measure_trimmed(&net, &device, ProcessingMode::Imprecise, 100, 0.01, 6);

    let mut table = Table::new(&["system", "exec time (ms)", "speedup vs CNNDroid", "paper"]);
    table.row(&[
        "CNNDroid [10]".into(),
        format!("{droid:.2}"),
        "1.00x".into(),
        "709 ms".into(),
    ]);
    table.row(&[
        "Cappuccino: Parallel".into(),
        format!("{par:.2}"),
        format!("{:.2}x", droid / par),
        "512.72 ms (1.38x)".into(),
    ]);
    table.row(&[
        "Cappuccino: Imprecise".into(),
        format!("{imp:.2}"),
        format!("{:.2}x", droid / imp),
        "61.80 ms (11.47x)".into(),
    ]);

    println!("# Table III — vs prior art, AlexNet on Snapdragon 810\n");
    table.print();

    assert!(droid > par, "Cappuccino parallel must beat CNNDroid");
    assert!((1.05..4.0).contains(&(droid / par)), "parallel factor {:.2}", droid / par);
    assert!((4.0..40.0).contains(&(droid / imp)), "imprecise factor {:.2}", droid / imp);
    println!("\ntable3 bench OK");
}
