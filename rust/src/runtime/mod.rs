//! PJRT runtime: artifact manifest + loader/executor.
//!
//! Python lowers each (net, mode, batch) variant once (`make
//! artifacts`); this module loads the HLO text and serves inference
//! with no Python anywhere near the request path.

pub mod executor;
pub mod manifest;

pub use executor::{batch_to_mapmajor, LoadedModel, ParamSource, Runtime};
pub use manifest::{ArtifactSpec, Manifest, ParamSpec};
