//! PJRT runtime: artifact manifest + loader/executor.
//!
//! Python lowers each (net, mode, batch) variant once (`make
//! artifacts`); this module loads the HLO text and serves inference
//! with no Python anywhere near the request path.
//!
//! The real executor needs the `xla` crate (PJRT CPU plugin), which is
//! vendored only in full build environments. The default build ships a
//! stub with the identical API whose `Runtime::new` reports that PJRT
//! support is absent; enable the `pjrt` cargo feature (with the `xla`
//! crate wired in via a path/patch dependency) for the real thing.
//! Everything manifest- and layout-related is pure Rust and always on.

#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;
pub mod manifest;

pub use executor::{LoadedModel, ParamSource, Runtime};
pub use manifest::{ArtifactSpec, Manifest, ParamSpec};

/// Map-major transform of a batch of conventional NCHW images, padded
/// up to `batch` with zeros — the serving-side input prologue.
pub fn batch_to_mapmajor(
    images: &[&[f32]],
    c: usize,
    h: usize,
    w: usize,
    u: usize,
    batch: usize,
) -> Vec<f32> {
    assert!(images.len() <= batch, "batch overflow");
    let per = crate::util::ceil_div(c, u) * h * w * u;
    let mut out = vec![0.0f32; batch * per];
    for (i, img) in images.iter().enumerate() {
        crate::layout::nchw_to_mapmajor_into(img, c, h, w, u, &mut out[i * per..(i + 1) * per]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_transform_pads_with_zeros() {
        let (c, h, w, u) = (3, 2, 2, 4);
        let img: Vec<f32> = (0..c * h * w).map(|i| i as f32 + 1.0).collect();
        let out = batch_to_mapmajor(&[&img], c, h, w, u, 2);
        let per = crate::util::ceil_div(c, u) * h * w * u;
        assert_eq!(out.len(), 2 * per);
        assert_eq!(&out[..per], &crate::layout::nchw_to_mapmajor(&img, c, h, w, u)[..]);
        assert!(out[per..].iter().all(|&v| v == 0.0), "pad slot must be zero");
    }
}
