//! Property-based tests over coordinator invariants, using the in-repo
//! `testing` helper (proptest is not in the vendored crate set).
//!
//! Each property runs dozens of seeded pseudo-random cases; failures
//! report the case index + seed for deterministic reproduction.

use cappuccino::engine::{
    cast_weights, conv_mm, conv_mm_packed, conv_nchw_flp, conv_nchw_klp, conv_nchw_scalar,
    ArithMode, ConvTiling, MapTensor,
};
use cappuccino::layout;
use cappuccino::testing::{check, close, Gen};
use cappuccino::util::ceil_div;

/// Random conv geometry small enough to run hundreds of cases.
struct ConvCase {
    c: usize,
    h: usize,
    w: usize,
    m: usize,
    k: usize,
    s: usize,
    p: usize,
    u: usize,
}

fn conv_case(g: &mut Gen) -> ConvCase {
    let k = g.choose(&[1usize, 3, 5]);
    ConvCase {
        c: g.int(1, 9),
        h: g.int(k, 12),
        w: g.int(k, 12),
        m: g.int(1, 12),
        k,
        s: g.int(1, 3),
        p: g.int(0, 2),
        u: g.choose(&[1usize, 2, 4, 8]),
    }
}

#[test]
fn prop_layout_roundtrip() {
    check("nchw<->mapmajor roundtrip", 100, 0xA1, |g| {
        let (c, h, w) = (g.int(1, 16), g.int(1, 10), g.int(1, 10));
        let u = g.choose(&[1usize, 2, 4, 8]);
        let src = g.normal_vec(c * h * w);
        let back = layout::mapmajor_to_nchw(&layout::nchw_to_mapmajor(&src, c, h, w, u), c, h, w, u);
        if back != src {
            return Err("roundtrip not identity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_index_equations_bijective() {
    check("eqs (3)-(5) bijective", 60, 0xA2, |g| {
        let u = g.choose(&[1usize, 2, 4, 8]);
        let wout = g.int(1, 9);
        let hout = g.int(1, 9);
        let stacks = g.int(1, 4);
        let total = u * wout * hout * stacks;
        let mut seen = vec![false; total];
        for x in 0..total {
            let (w, h, m) = layout::thread_index_to_whm(x, u, wout, hout);
            let back = layout::whm_to_thread_index(w, h, m, u, wout, hout);
            if back != x {
                return Err(format!("x={x} -> ({w},{h},{m}) -> {back}"));
            }
            let key = (m * hout + h) * wout + w;
            if seen[key] {
                return Err(format!("duplicate target at x={x}"));
            }
            seen[key] = true;
        }
        Ok(())
    });
}

#[test]
fn prop_mapmajor_conv_matches_scalar() {
    check("conv_mm == conv_nchw_scalar", 40, 0xA3, |g| {
        let case = conv_case(g);
        let ConvCase { c, h, w, m, k, s, p, u } = case;
        if h + 2 * p < k || w + 2 * p < k {
            return Ok(()); // degenerate window; constructor rejects
        }
        let input = g.normal_vec(c * h * w);
        let weights = g.normal_vec(m * c * k * k);
        let bias = g.normal_vec(m);
        let (want, ..) = conv_nchw_scalar(
            &input, c, h, w, &weights, &bias, m, k, s, p, false, ArithMode::Precise,
        );
        let got = conv_mm(
            &MapTensor::from_nchw(&input, c, h, w, u),
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            &layout::bias_to_mapmajor(&bias, u),
            m, k, s, p, false, ArithMode::Precise, g.int(1, 4),
        );
        close(&got.to_nchw(), &want, 1e-4)
    });
}

#[test]
fn prop_all_parallelism_policies_agree() {
    check("OLP == FLP == KLP numerics", 25, 0xA4, |g| {
        let case = conv_case(g);
        let ConvCase { c, h, w, m, k, s, p, .. } = case;
        if h + 2 * p < k || w + 2 * p < k {
            return Ok(());
        }
        let input = g.normal_vec(c * h * w);
        let weights = g.normal_vec(m * c * k * k);
        let bias = g.normal_vec(m);
        let threads = g.int(1, 4);
        let (scalar, ..) = conv_nchw_scalar(
            &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise,
        );
        let (flp, ..) = conv_nchw_flp(
            &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise, threads,
        );
        let (klp, ..) = conv_nchw_klp(
            &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise, threads,
        );
        close(&flp, &scalar, 1e-3)?;
        close(&klp, &scalar, 1e-3)
    });
}

#[test]
fn prop_thread_count_does_not_change_olp_output() {
    check("OLP output invariant to thread count", 30, 0xA5, |g| {
        let case = conv_case(g);
        let ConvCase { c, h, w, m, k, s, p, u } = case;
        if h + 2 * p < k || w + 2 * p < k {
            return Ok(());
        }
        let input = g.normal_vec(c * h * w);
        let weights = g.normal_vec(m * c * k * k);
        let bias = g.normal_vec(m);
        let mm = MapTensor::from_nchw(&input, c, h, w, u);
        let w_mm = layout::weights_to_mapmajor(&weights, m, c, k, u);
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let one = conv_mm(&mm, &w_mm, &b_mm, m, k, s, p, false, ArithMode::Precise, 1);
        for threads in [2, 3, 5, 8] {
            let t = conv_mm(&mm, &w_mm, &b_mm, m, k, s, p, false, ArithMode::Precise, threads);
            if t.data != one.data {
                return Err(format!("threads={threads} changed the output"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_packed_tiled_kernel_bitwise_matches_unpacked() {
    // The packed-panel row-tile macro-kernel must be a pure layout /
    // traversal refactoring: bitwise identical to the unpacked kernel
    // for random geometry, u, thread count, and (random, usually
    // non-dividing) tile sizes.
    check("packed+tiled == unpacked bitwise", 40, 0xAB, |g| {
        let case = conv_case(g);
        let ConvCase { c, h, w, m, k, s, p, u } = case;
        if h + 2 * p < k || w + 2 * p < k {
            return Ok(());
        }
        let input = g.normal_vec(c * h * w);
        let weights = g.normal_vec(m * c * k * k);
        let bias = g.normal_vec(m);
        let mm = MapTensor::from_nchw(&input, c, h, w, u);
        let mode = g.choose(&ArithMode::ALL);
        let w_mm = cast_weights(&layout::weights_to_mapmajor(&weights, m, c, k, u), mode);
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let (mb, cb) = (ceil_div(m, u), ceil_div(c, u));
        let w_pack = layout::pack_conv_panels(&w_mm, mb, cb, k, u);
        let threads = g.int(1, 4);
        let tile = ConvTiling { tm: g.int(1, 5), th: g.int(1, 8) };
        let want = conv_mm(&mm, &w_mm, &b_mm, m, k, s, p, true, mode, threads);
        let got = conv_mm_packed(&mm, &w_pack, &b_mm, m, k, s, p, true, mode, threads, tile);
        if got.data != want.data {
            return Err(format!("diverged (u={u} threads={threads} tile={tile:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_imprecise_error_bounded() {
    // bf16 operand rounding has <= 2^-8 relative error per operand; the
    // conv accumulation keeps the result within a modest relative bound.
    check("imprecise error bounded", 30, 0xA6, |g| {
        let case = conv_case(g);
        let ConvCase { c, h, w, m, k, s, p, u } = case;
        if h + 2 * p < k || w + 2 * p < k {
            return Ok(());
        }
        let input = g.normal_vec(c * h * w);
        let weights = g.normal_vec(m * c * k * k);
        let bias = g.normal_vec(m);
        let mm = MapTensor::from_nchw(&input, c, h, w, u);
        let w_mm = layout::weights_to_mapmajor(&weights, m, c, k, u);
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let precise = conv_mm(&mm, &w_mm, &b_mm, m, k, s, p, false, ArithMode::Precise, 1);
        // Production contract: weights baked at compile time, activations
        // cast by the kernel — both operands rounded.
        let w_baked = cappuccino::engine::cast_weights(&w_mm, ArithMode::Imprecise);
        let imprecise = conv_mm(&mm, &w_baked, &b_mm, m, k, s, p, false, ArithMode::Imprecise, 1);
        // Scale: the reduction length bounds worst-case error growth.
        let terms = (c * k * k) as f32;
        let tol = 0.01 * terms.sqrt().max(1.0);
        close(&imprecise.data, &precise.data, tol)
    });
}

#[test]
fn prop_modelfile_roundtrip() {
    use cappuccino::config::modelfile::{ModelFile, NamedTensor};
    check("modelfile roundtrip", 50, 0xA7, |g| {
        let mut mf = ModelFile::new();
        let n_tensors = g.int(1, 6);
        for i in 0..n_tensors {
            let ndim = g.int(1, 4);
            let dims: Vec<usize> = (0..ndim).map(|_| g.int(1, 5)).collect();
            let data = g.normal_vec(dims.iter().product());
            mf.insert(format!("t{i}/w"), NamedTensor::new(dims, data));
        }
        let back = ModelFile::parse(&mf.serialize()).map_err(|e| e.to_string())?;
        if back.names() != mf.names() {
            return Err("name order changed".into());
        }
        for name in mf.names() {
            if back.get(name).unwrap() != mf.get(name).unwrap() {
                return Err(format!("tensor {name} changed"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use cappuccino::util::json::Json;
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        if depth == 0 {
            return match g.int(0, 3) {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f32(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
                _ => Json::Str(format!("s{}-\"quoted\"\n", g.int(0, 99))),
            };
        }
        match g.int(0, 1) {
            0 => Json::Arr((0..g.int(0, 4)).map(|_| gen_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.int(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 80, 0xA8, |g| {
        let v = gen_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("roundtrip changed value: {text}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cappnet_shape_inference_total() {
    // Any well-formed linear net the generator produces must either
    // parse+infer cleanly or be rejected with an error — no panics.
    check("cappnet parse/infer total", 60, 0xA9, |g| {
        let mut text = String::from("net gen\n");
        let (c, hw) = (g.int(1, 8), g.int(6, 24));
        text.push_str(&format!("input {c} {hw} {hw}\n"));
        let mut conv_count = 0;
        let mut last_m = c;
        for i in 0..g.int(1, 5) {
            match g.int(0, 2) {
                0 => {
                    last_m = g.choose(&[4usize, 8, 16]);
                    text.push_str(&format!(
                        "conv c{i} m={last_m} k=3 s=1 p=1\n"
                    ));
                    conv_count += 1;
                }
                1 => text.push_str("maxpool k=2 s=2\n"),
                _ => text.push_str("lrn size=3\n"),
            }
        }
        let _ = conv_count;
        text.push_str(&format!("classes {last_m}\ngap\n"));
        match cappuccino::config::parse_cappnet(&text) {
            Ok(net) => {
                // Inference must agree with the declared classes.
                let info = cappuccino::model::shapes::infer(&net).map_err(|e| e.to_string())?;
                if info.output.elements() != last_m {
                    return Err(format!("output {:?} vs classes {last_m}", info.output));
                }
                Ok(())
            }
            // Rejection is fine (e.g. pooling shrank below the window).
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn prop_quantize_symmetric_roundtrip_bounded() {
    use cappuccino::engine::mode::quantize_symmetric;
    check("symmetric i8 quantization error <= scale/2", 80, 0xAC, |g| {
        let n = g.int(1, 256);
        let amp = g.f32(1e-3, 1e4);
        let x: Vec<f32> = g.normal_vec(n).iter().map(|v| v * amp).collect();
        let (q, scale) = quantize_symmetric(&x);
        let amax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            return if scale == 1.0 && q.iter().all(|&v| v == 0) {
                Ok(())
            } else {
                Err("zero tensor must quantize to zeros with scale 1".into())
            };
        }
        // Round-to-nearest: dequantization error is at most half a step
        // (plus f32 rounding slack).
        let tol = scale * 0.5 * (1.0 + 1e-5) + 1e-6;
        for (&qi, &xi) in q.iter().zip(&x) {
            let err = (qi as f32 * scale - xi).abs();
            if err > tol {
                return Err(format!("|{qi}*{scale} - {xi}| = {err} > {tol}"));
            }
        }
        // The max-magnitude element must use the full i8 range.
        if !q.iter().any(|&v| v.unsigned_abs() == 127) {
            return Err("amax element did not map to +-127".into());
        }
        Ok(())
    });
}

#[test]
fn prop_quant_i8_plan_tracks_precise_logits() {
    // End-to-end property for the quantized path: for random weights,
    // inputs and vector widths, the int8 plan's logits stay finite and
    // within a scale-aware tolerance of the precise f32 plan. (Top-1
    // agreement on the *trained* net is asserted in `src/inexact`.)
    use cappuccino::engine::{EngineParams, PlanBuilder, Schedule};
    use cappuccino::model::zoo;
    check("quant_i8 logits track f32", 8, 0xAD, |g| {
        let net = zoo::tinynet();
        let u = g.choose(&[1usize, 2, 4, 8]);
        let params = EngineParams::random(&net, g.int(1, 1000) as u64, u)
            .map_err(|e| e.to_string())?;
        let x = g.normal_vec(net.input.elements());
        let mut precise = PlanBuilder::new(&net, &params)
            .build()
            .map_err(|e| e.to_string())?;
        let want = precise.run(&x).map_err(|e| e.to_string())?;
        let mut sched = Schedule::default_for(&net, u);
        for ls in sched.layers.values_mut() {
            ls.mode = ArithMode::QuantI8;
        }
        let mut quant = PlanBuilder::new(&net, &params)
            .schedule(sched)
            .build()
            .map_err(|e| e.to_string())?;
        let got = quant.run(&x).map_err(|e| e.to_string())?;
        let scale = want.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
        for (w, q) in want.iter().zip(&got) {
            if !q.is_finite() || (w - q).abs() > 0.2 * scale {
                return Err(format!("u={u}: {w} vs {q} (scale {scale})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_never_loses_requests() {
    use cappuccino::engine::{EngineParams, ModeAssignment};
    use cappuccino::model::zoo;
    use cappuccino::serve::{BatchPolicy, EngineBackend, Server};
    check("serving conservation", 6, 0xAA, |g| {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).map_err(|e| e.to_string())?;
        let backend = EngineBackend::new(
            net,
            params,
            ModeAssignment::uniform(ArithMode::Imprecise),
            1,
            g.choose(&[1usize, 4, 8]),
        );
        let policy = BatchPolicy {
            max_batch: g.choose(&[1usize, 4, 8]),
            max_delay: std::time::Duration::from_millis(g.int(0, 4) as u64),
            queue_depth: 256,
            ..Default::default()
        };
        let server = Server::start(vec![("m".into(), backend.factory(), policy)])
            .map_err(|e| e.to_string())?;
        let n = g.int(1, 40);
        let rxs: Vec<_> = (0..n)
            .map(|_| server.router().submit("m", g.normal_vec(768)).unwrap())
            .collect();
        let mut got = 0;
        for rx in rxs {
            if rx.recv().is_ok() {
                got += 1;
            }
        }
        server.shutdown();
        if got != n {
            return Err(format!("submitted {n}, completed {got}"));
        }
        Ok(())
    });
}
