//! Layer IR: the network representation every subsystem consumes.
//!
//! A [`Network`] is a linear list of [`Layer`]s whose only structural op
//! is [`LayerOp::Fork`] (branch + channel-concat, covering SqueezeNet
//! fire modules and GoogLeNet inception modules). The IR mirrors the
//! Python spec in `python/compile/model.py` one-to-one — the AOT
//! manifest embeds the expanded Python spec and
//! [`Network::from_manifest`] rebuilds it here, so both sides provably
//! describe the same computation (checked in integration tests).

pub mod shapes;
pub mod zoo;

use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Primitive layer operations (post fire/inception expansion).
#[derive(Debug, Clone, PartialEq)]
pub enum LayerOp {
    /// Convolution: `m` output maps, `k`x`k` kernels, stride `s`,
    /// symmetric spatial padding `p`, optional fused ReLU.
    Conv { m: usize, k: usize, s: usize, p: usize, relu: bool },
    MaxPool { k: usize, s: usize, p: usize },
    AvgPool { k: usize, s: usize, p: usize },
    /// Local response normalisation across channels.
    Lrn { size: usize, alpha: f32, beta: f32 },
    /// Parallel branches whose outputs are channel-concatenated.
    Fork { branches: Vec<Vec<Layer>> },
    Flatten,
    /// Global average pool (+ implicit flatten to `(C,)`).
    Gap,
    Dense { o: usize, relu: bool },
    Softmax,
}

/// A named layer. Only conv/dense names are semantically meaningful
/// (parameters + arithmetic-mode assignment address them); other layers
/// carry names for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub op: LayerOp,
}

impl Layer {
    pub fn new(name: impl Into<String>, op: LayerOp) -> Self {
        Layer { name: name.into(), op }
    }

    /// Does this layer own parameters (and therefore a mode assignment)?
    pub fn has_params(&self) -> bool {
        matches!(self.op, LayerOp::Conv { .. } | LayerOp::Dense { .. })
    }
}

/// Activation shape flowing between layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorShape {
    /// Feature maps `(C, H, W)` (stored map-major at runtime).
    Maps { c: usize, h: usize, w: usize },
    /// Flattened vector `(len,)`.
    Flat { len: usize },
}

impl TensorShape {
    pub fn maps(c: usize, h: usize, w: usize) -> Self {
        TensorShape::Maps { c, h, w }
    }

    pub fn elements(&self) -> usize {
        match *self {
            TensorShape::Maps { c, h, w } => c * h * w,
            TensorShape::Flat { len } => len,
        }
    }

    /// `(C, H, W)` or an error for flat shapes.
    pub fn as_maps(&self) -> Result<(usize, usize, usize)> {
        match *self {
            TensorShape::Maps { c, h, w } => Ok((c, h, w)),
            TensorShape::Flat { len } => {
                Err(Error::Shape(format!("expected feature maps, got flat({len})")))
            }
        }
    }
}

/// A complete network: metadata + layers.
#[derive(Debug, Clone, PartialEq)]
pub struct Network {
    pub name: String,
    /// Input shape `(C, H, W)` in conventional terms.
    pub input: TensorShape,
    /// Number of classifier outputs.
    pub classes: usize,
    pub layers: Vec<Layer>,
}

impl Network {
    /// Walk every layer depth-first (branches in order), applying `f`.
    pub fn visit<'a>(&'a self, f: &mut impl FnMut(&'a Layer)) {
        fn walk<'a>(layers: &'a [Layer], f: &mut impl FnMut(&'a Layer)) {
            for layer in layers {
                f(layer);
                if let LayerOp::Fork { branches } = &layer.op {
                    for br in branches {
                        walk(br, f);
                    }
                }
            }
        }
        walk(&self.layers, f);
    }

    /// Names of every parameterised (conv/dense) layer, in the canonical
    /// order shared with the Python AOT path (`model.param_order`).
    pub fn param_layer_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        self.visit(&mut |l| {
            if l.has_params() {
                names.push(l.name.clone());
            }
        });
        names
    }

    /// Total number of parameters (weights + biases, conventional layout).
    pub fn param_count(&self) -> usize {
        shapes::infer(self)
            .map(|info| {
                info.param_layers
                    .iter()
                    .map(|p| p.weight_elems + p.bias_elems)
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Rebuild a network from the AOT manifest's expanded spec.
    pub fn from_manifest(name: &str, net_json: &Json) -> Result<Network> {
        let input = net_json.get("input_shape")?.usize_vec()?;
        if input.len() != 3 {
            return Err(Error::parse("manifest", format!("input_shape {input:?}")));
        }
        let classes = net_json.get("classes")?.as_usize()?;
        let layers = parse_layers(net_json.get("layers")?.as_arr()?)?;
        Ok(Network {
            name: name.to_string(),
            input: TensorShape::maps(input[0], input[1], input[2]),
            classes,
            layers,
        })
    }
}

fn parse_layers(arr: &[Json]) -> Result<Vec<Layer>> {
    let mut out = Vec::with_capacity(arr.len());
    for (i, lay) in arr.iter().enumerate() {
        let op = lay.get("op")?.as_str()?;
        let name = lay
            .opt("name")
            .and_then(|n| n.as_str().ok())
            .map(str::to_string)
            .unwrap_or_else(|| format!("{op}{i}"));
        let op = match op {
            "conv" => LayerOp::Conv {
                m: lay.get("m")?.as_usize()?,
                k: lay.get("k")?.as_usize()?,
                s: lay.get("s")?.as_usize()?,
                p: lay.get("p")?.as_usize()?,
                relu: lay.get("relu")?.as_bool()?,
            },
            "maxpool" | "avgpool" => {
                let k = lay.get("k")?.as_usize()?;
                let s = lay.get("s")?.as_usize()?;
                let p = lay.get("p")?.as_usize()?;
                if op == "maxpool" {
                    LayerOp::MaxPool { k, s, p }
                } else {
                    LayerOp::AvgPool { k, s, p }
                }
            }
            "lrn" => LayerOp::Lrn {
                size: lay.get("size")?.as_usize()?,
                alpha: lay.get("alpha")?.as_f64()? as f32,
                beta: lay.get("beta")?.as_f64()? as f32,
            },
            "fork" => {
                let branches = lay
                    .get("branches")?
                    .as_arr()?
                    .iter()
                    .map(|br| parse_layers(br.as_arr()?))
                    .collect::<Result<Vec<_>>>()?;
                LayerOp::Fork { branches }
            }
            "flatten" => LayerOp::Flatten,
            "gap" => LayerOp::Gap,
            "dense" => LayerOp::Dense {
                o: lay.get("o")?.as_usize()?,
                relu: lay.get("relu")?.as_bool()?,
            },
            "softmax" => LayerOp::Softmax,
            other => {
                return Err(Error::parse("manifest", format!("unknown op {other:?}")))
            }
        };
        out.push(Layer { name, op });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn visit_covers_branches() {
        let net = zoo::squeezenet();
        let mut n = 0;
        net.visit(&mut |_| n += 1);
        // 2 convs+3 pools+1 gap + 8 fires * (1 squeeze conv + 1 fork +
        // 2 branch convs) = definitely more than the top-level count.
        assert!(n > net.layers.len());
    }

    #[test]
    fn param_layer_names_order_matches_python() {
        let net = zoo::tinynet();
        assert_eq!(
            net.param_layer_names(),
            vec!["conv1", "conv2", "conv3", "fc4", "fc5"]
        );
    }

    #[test]
    fn tensor_shape_accessors() {
        let s = TensorShape::maps(3, 4, 5);
        assert_eq!(s.elements(), 60);
        assert_eq!(s.as_maps().unwrap(), (3, 4, 5));
        assert!(TensorShape::Flat { len: 9 }.as_maps().is_err());
    }
}
