//! Small substrates the rest of the crate builds on.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure vendored, so the usual ecosystem crates (serde,
//! rand, etc.) are unavailable — these modules are deliberately small,
//! from-scratch implementations of exactly what the system needs.

pub mod error;
pub mod json;
pub mod rng;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// Write `contents` to `path` atomically: write a `.tmp` sibling, then
/// `rename` it into place. A crash or kill mid-write can leave a stale
/// temp file behind but never a truncated/corrupt artifact at `path` —
/// every artifact writer (schedules, bench JSON, replay output) goes
/// through here so the next run always parses either the old file or
/// the complete new one.
pub fn write_atomic(
    path: impl AsRef<std::path::Path>,
    contents: impl AsRef<[u8]>,
) -> std::io::Result<()> {
    let path = path.as_ref();
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, contents)?;
    match std::fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            // Don't leave the temp file behind on a failed rename.
            let _ = std::fs::remove_file(&tmp);
            Err(e)
        }
    }
}

/// Format a float with engineering-style units (1.23 k / 4.56 M / ...).
pub fn eng(value: f64) -> String {
    let (v, suffix) = if value.abs() >= 1e9 {
        (value / 1e9, "G")
    } else if value.abs() >= 1e6 {
        (value / 1e6, "M")
    } else if value.abs() >= 1e3 {
        (value / 1e3, "k")
    } else {
        (value, "")
    };
    format!("{v:.2}{suffix}")
}

/// Format a duration in adaptive units (ns/µs/ms/s).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(3, 4), 4);
        assert_eq!(round_up(8, 4), 8);
        assert_eq!(round_up(9, 4), 12);
    }

    #[test]
    fn eng_formatting() {
        assert_eq!(eng(1234.0), "1.23k");
        assert_eq!(eng(12.0), "12.00");
        assert_eq!(eng(2.5e7), "25.00M");
        assert_eq!(eng(3.1e9), "3.10G");
    }

    #[test]
    fn write_atomic_replaces_and_cleans_temp() {
        let dir = std::env::temp_dir().join(format!("capp-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("artifact.json");
        write_atomic(&path, b"{\"v\":1}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"v\":1}");
        write_atomic(&path, b"{\"v\":2}").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"{\"v\":2}");
        assert!(
            !dir.join("artifact.json.tmp").exists(),
            "temp file left behind after rename"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
    }
}
