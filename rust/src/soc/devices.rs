//! Device catalog: analytic models of the paper's three test phones.
//!
//! Architectural constants (cores, clocks, SIMD width, memory bandwidth)
//! come from public Snapdragon 800/810/820 specifications. The three
//! *efficiency* scalars per device (Java interpreter throughput, parallel
//! compute efficiency, achievable bandwidth fraction) are calibrated from
//! the paper's own Table I baselines — one scalar each, no per-network
//! fitting (DESIGN.md "Calibration notes"). Absolute milliseconds are
//! therefore approximate; the *shape* (who wins, speedup bands,
//! imprecise ≥ parallel) is what the simulator reproduces and what the
//! Table I bench asserts.

/// Execution mode of the synthesized program on a device (Table I columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProcessingMode {
    /// Single-threaded Java interpreter baseline.
    JavaBaseline,
    /// Cappuccino parallel program, RenderScript precise arithmetic
    /// (no vector units — the paper: vectors need inexact modes).
    Parallel,
    /// Cappuccino parallel program, imprecise arithmetic + vectors.
    Imprecise,
}

impl ProcessingMode {
    pub const ALL: [ProcessingMode; 3] = [
        ProcessingMode::JavaBaseline,
        ProcessingMode::Parallel,
        ProcessingMode::Imprecise,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ProcessingMode::JavaBaseline => "baseline",
            ProcessingMode::Parallel => "parallel",
            ProcessingMode::Imprecise => "imprecise",
        }
    }
}

/// Analytic model of one mobile SoC platform.
#[derive(Debug, Clone)]
pub struct DeviceModel {
    pub name: &'static str,
    pub soc: &'static str,
    /// CPU cores usable by the parallel runtime.
    pub cores: usize,
    /// Sustained big-core clock, GHz.
    pub ghz: f64,
    /// f32 SIMD lanes (NEON = 4) — the paper's `u`.
    pub simd_lanes: usize,
    /// Achievable memory bandwidth, GB/s (effective, not datasheet peak).
    pub mem_bw_gbs: f64,
    /// Measured single-thread Java throughput, MFLOP/s (calibrated from
    /// the paper's baseline column).
    pub java_mflops: f64,
    /// Fraction of scalar-FMA peak the parallel RenderScript program
    /// achieves across CPU+GPU+DSP (calibrated).
    pub parallel_eff: f64,
    /// Additional throughput factor of relaxed-FP arithmetic on top of
    /// vectorisation (denormal handling, fast paths).
    pub relaxed_gain: f64,
    /// Per-kernel-launch dispatch overhead, ms (RenderScript runtime).
    pub dispatch_ms: f64,
    // -- power model (energy Table II) ---------------------------------
    /// Single active core, W.
    pub p_single_w: f64,
    /// All cores + GPU active under the parallel program, W.
    pub p_parallel_w: f64,
}

impl DeviceModel {
    /// Peak scalar-FMA compute of the parallel configuration, GFLOP/s.
    pub fn parallel_peak_gflops(&self) -> f64 {
        self.cores as f64 * self.ghz * 2.0 // 2 FLOPs/cycle (FMA)
    }

    /// Effective parallel compute rate, GFLOP/s.
    pub fn parallel_gflops(&self) -> f64 {
        self.parallel_peak_gflops() * self.parallel_eff
    }

    /// Effective vectorised (imprecise-mode) compute rate, GFLOP/s,
    /// before per-layer vector-efficiency derating.
    pub fn imprecise_gflops(&self) -> f64 {
        self.parallel_gflops() * self.simd_lanes as f64 * self.relaxed_gain
    }
}

/// Nexus 5 — Snapdragon 800 (4x Krait 400 @ 2.26 GHz, Adreno 330,
/// LPDDR3-1600 x2).
pub fn nexus5() -> DeviceModel {
    DeviceModel {
        name: "Nexus 5",
        soc: "Snapdragon 800",
        cores: 4,
        ghz: 2.26,
        simd_lanes: 4,
        mem_bw_gbs: 6.0,
        java_mflops: 40.0,
        parallel_eff: 0.075,
        relaxed_gain: 1.3,
        dispatch_ms: 0.45,
        p_single_w: 0.60,
        p_parallel_w: 2.60,
    }
}

/// Nexus 6P — Snapdragon 810 (4x A57 @ ~2.0 GHz + 4x A53, Adreno 430,
/// LPDDR4). The big.LITTLE pair is modelled as 8 usable cores at the
/// big-core clock derated through `parallel_eff`.
pub fn nexus6p() -> DeviceModel {
    DeviceModel {
        name: "Nexus 6P",
        soc: "Snapdragon 810",
        cores: 8,
        ghz: 2.0,
        simd_lanes: 4,
        mem_bw_gbs: 12.0,
        java_mflops: 120.0,
        parallel_eff: 0.085,
        relaxed_gain: 2.0,
        dispatch_ms: 0.30,
        p_single_w: 0.75,
        p_parallel_w: 3.40,
    }
}

/// Galaxy S7 — Snapdragon 820 (4x Kryo @ 2.15 GHz, Adreno 530, LPDDR4).
pub fn galaxy_s7() -> DeviceModel {
    DeviceModel {
        name: "Galaxy S7",
        soc: "Snapdragon 820",
        cores: 4,
        ghz: 2.15,
        simd_lanes: 4,
        mem_bw_gbs: 14.0,
        java_mflops: 140.0,
        parallel_eff: 0.135,
        relaxed_gain: 1.3,
        dispatch_ms: 0.25,
        p_single_w: 0.70,
        p_parallel_w: 3.00,
    }
}

/// The paper's three platforms, in Table I order.
pub fn catalog() -> Vec<DeviceModel> {
    vec![nexus5(), nexus6p(), galaxy_s7()]
}

/// Look a device up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<DeviceModel> {
    let l = name.to_lowercase().replace([' ', '-', '_'], "");
    match l.as_str() {
        "nexus5" => Some(nexus5()),
        "nexus6p" => Some(nexus6p()),
        "galaxys7" | "s7" => Some(galaxy_s7()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_three_paper_devices() {
        let c = catalog();
        assert_eq!(c.len(), 3);
        assert_eq!(c[0].soc, "Snapdragon 800");
        assert_eq!(c[2].name, "Galaxy S7");
    }

    #[test]
    fn by_name_variants() {
        assert!(by_name("Nexus 5").is_some());
        assert!(by_name("nexus-6p").is_some());
        assert!(by_name("galaxy_s7").is_some());
        assert!(by_name("pixel9").is_none());
    }

    #[test]
    fn compute_rates_ordered() {
        // Vectorised rate must exceed parallel rate everywhere; parallel
        // rate must exceed Java throughput by a wide margin.
        for d in catalog() {
            assert!(d.imprecise_gflops() > d.parallel_gflops(), "{}", d.name);
            assert!(
                d.parallel_gflops() * 1e3 > d.java_mflops * 5.0,
                "{}: parallel barely beats java",
                d.name
            );
        }
    }

    #[test]
    fn power_ordering() {
        for d in catalog() {
            assert!(d.p_parallel_w > d.p_single_w);
        }
    }
}
