//! Ablation: map-major layout + u-way vectorised MAC vs conventional
//! row-major scalar execution (paper section IV.B).
//!
//! Sweeps the vector width u over {1, 2, 4, 8, 16} on a fixed conv
//! layer: u=1 map-major degenerates to scalar-with-reordered-layout, so
//! the sweep isolates the superword-MAC benefit from the layout change
//! itself. Also reports the row-major scalar reference.
//!
//! Two further sections isolate each tentpole contribution of the
//! packed-weight tiled plan:
//!
//! * **packed vs unpacked** — same kernel structure, weights read from
//!   tap-major panels (sequential) vs the `(Mb, u, Cb, K, K, u)` layout
//!   (per-tap gather), both at tile = {1, 1} (row walk), so the delta
//!   is the weight-streaming win alone.
//! * **tiled vs row-walk** — packed weights in both, cost-model tiles
//!   vs `{tm: 1, th: 1}`, so the delta is the input-row reuse of the
//!   row-tile macro-kernel alone.
//! * **pinned vs unpinned pool** — the same packed tiled kernel on two
//!   private topology-shaped pools ([`cappuccino::engine::with_pool`]),
//!   differing only in worker pinning, so the delta is the affinity
//!   contribution alone (uniform hosts show ~1.00x by construction).

use cappuccino::bench::{bench, ms, BenchConfig, Table};
use cappuccino::engine::{
    cast_weights, conv_mm, conv_mm_packed, conv_nchw_scalar, with_pool, ArithMode, ConvTiling,
    MapTensor, ThreadPool, Topology,
};
use cappuccino::layout;
use cappuccino::util::ceil_div;
use cappuccino::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(0x1A10);
    // Mid-network geometry: plenty of channels for lane fill.
    let (c, h, w, m, k, s, p) = (64usize, 28usize, 28usize, 64usize, 3usize, 1usize, 1usize);
    let input = rng.normal_vec(c * h * w);
    let weights = rng.normal_vec(m * c * k * k);
    let bias = rng.normal_vec(m);

    let scalar = bench("rowmajor-scalar", cfg, || {
        std::hint::black_box(conv_nchw_scalar(
            &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise,
        ));
    });

    let mut table = Table::new(&["layout", "u", "time(ms)", "vs row-major"]);
    table.row(&[
        "row-major scalar".into(),
        "-".into(),
        ms(scalar.mean_ms),
        "1.00x".into(),
    ]);

    let mut best_u = 1;
    let mut best_ms = f64::INFINITY;
    for u in [1usize, 2, 4, 8, 16] {
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        // Weights baked into the imprecise domain once, compile-time.
        let w_mm = cast_weights(
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            ArithMode::Imprecise,
        );
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let meas = bench(format!("mm-u{u}"), cfg, || {
            std::hint::black_box(conv_mm(
                &mm_in, &w_mm, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1,
            ));
        });
        if meas.mean_ms < best_ms {
            best_ms = meas.mean_ms;
            best_u = u;
        }
        table.row(&[
            "map-major".into(),
            u.to_string(),
            ms(meas.mean_ms),
            format!("{:.2}x", scalar.mean_ms / meas.mean_ms),
        ]);
    }

    println!("# Ablation — data layout & vector width (sec IV.B)\n");
    table.print();
    println!("\nbest u = {best_u} ({:.2}x over row-major scalar)", scalar.mean_ms / best_ms);
    println!("(the paper's RenderScript target has 4-lane NEON vectors; on this");
    println!("host the autovectorised u-wide MAC plays the same role)");

    // Structural invariant: some u must beat the scalar reference.
    assert!(
        best_ms < scalar.mean_ms,
        "map-major vectorisation never beat scalar ({best_ms:.2} vs {:.2})",
        scalar.mean_ms
    );

    // -- Packed vs unpacked, tiled vs row-walk (ISSUE 3 tentpole) --------
    let mut packed_table = Table::new(&["kernel", "u", "time(ms)", "vs unpacked row-walk"]);
    for u in [4usize, 8] {
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        let w_mm = cast_weights(
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            ArithMode::Imprecise,
        );
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let (mb, cb) = (ceil_div(m, u), ceil_div(c, u));
        let w_pack = layout::pack_conv_panels(&w_mm, mb, cb, k, u);
        let ho = (h + 2 * p - k) / s + 1;
        let row_walk = ConvTiling { tm: 1, th: 1 };
        let model = ConvTiling::choose(cb, w + 2 * p, u, k, s, mb, ho);

        let unpacked = bench(format!("unpacked-u{u}"), cfg, || {
            std::hint::black_box(conv_mm(
                &mm_in, &w_mm, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1,
            ));
        });
        let packed_rw = bench(format!("packed-rowwalk-u{u}"), cfg, || {
            std::hint::black_box(conv_mm_packed(
                &mm_in, &w_pack, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1, row_walk,
            ));
        });
        let packed_tiled = bench(format!("packed-tiled-u{u}"), cfg, || {
            std::hint::black_box(conv_mm_packed(
                &mm_in, &w_pack, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1, model,
            ));
        });
        packed_table.row(&[
            "unpacked row-walk".into(),
            u.to_string(),
            ms(unpacked.mean_ms),
            "1.00x".into(),
        ]);
        packed_table.row(&[
            "packed row-walk".into(),
            u.to_string(),
            ms(packed_rw.mean_ms),
            format!("{:.2}x", unpacked.mean_ms / packed_rw.mean_ms),
        ]);
        packed_table.row(&[
            format!("packed tiled (tm={}, th={})", model.tm, model.th),
            u.to_string(),
            ms(packed_tiled.mean_ms),
            format!("{:.2}x", unpacked.mean_ms / packed_tiled.mean_ms),
        ]);
    }
    println!("\n# Ablation — packed panels & row-tile macro-kernel\n");
    packed_table.print();
    println!("(packed row-walk isolates the weight-streaming win; packed tiled");
    println!("adds the input-row reuse of the macro-kernel on top)");

    // -- Pinned vs unpinned pool (ISSUE 4 affinity contribution) ---------
    {
        let topo = Topology::probe();
        let threads = topo.cpu_count().max(2);
        let pinned = ThreadPool::with_topology(&topo, true);
        let unpinned = ThreadPool::with_topology(&topo, false);
        let u = 4usize;
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        let w_mm = cast_weights(
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            ArithMode::Imprecise,
        );
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let (mb, cb) = (ceil_div(m, u), ceil_div(c, u));
        let w_pack = layout::pack_conv_panels(&w_mm, mb, cb, k, u);
        let ho = (h + 2 * p - k) / s + 1;
        let model = ConvTiling::choose(cb, w + 2 * p, u, k, s, mb, ho);

        let mut aff_table = Table::new(&["pool", "clusters", "time(ms)", "vs unpinned"]);
        let mut base_ms = f64::NAN;
        for (name, pool) in [("unpinned", &unpinned), ("pinned", &pinned)] {
            let meas = bench(format!("{name}-packed-tiled"), cfg, || {
                with_pool(pool, || {
                    std::hint::black_box(conv_mm_packed(
                        &mm_in,
                        &w_pack,
                        &b_mm,
                        m,
                        k,
                        s,
                        p,
                        true,
                        ArithMode::Imprecise,
                        threads,
                        model,
                    ));
                });
            });
            if name == "unpinned" {
                base_ms = meas.mean_ms;
            }
            aff_table.row(&[
                name.into(),
                pool.clusters().len().to_string(),
                ms(meas.mean_ms),
                format!("{:.2}x", base_ms / meas.mean_ms),
            ]);
        }
        println!(
            "\n# Ablation — pinned vs unpinned pool (threads={threads}, pinnable={})\n",
            topo.probed
        );
        aff_table.print();
        println!("(same packed tiled kernel on two private pools via with_pool; the");
        println!("delta is worker pinning alone — uniform-fallback hosts show ~1.00x)");
    }

    println!("ablation_layout bench OK");
}
