//! Bench: regenerate paper Table II (energy consumption, SqueezeNet on
//! the Nexus 5 — baseline vs Cappuccino, 2 x 1000 runs each).
//!
//! The paper reports 26.39 J (baseline) vs 3.38 J (Cappuccino) = 7.81x.
//! The bench prints the same row structure (first 1000 / second 1000 /
//! average / ratio) and asserts the coarse band.

use cappuccino::bench::Table;
use cappuccino::model::zoo;
use cappuccino::soc::{self, energy_table2};

fn main() {
    let net = zoo::squeezenet();
    let device = soc::devices::nexus5();
    let t = energy_table2(&net, &device, 11);

    let mut table = Table::new(&[
        "program", "first-1000 (J)", "second-1000 (J)", "average (J)",
    ]);
    table.row(&[
        "baseline (1-thread)".into(),
        format!("{:.2}", t.baseline_first),
        format!("{:.2}", t.baseline_second),
        format!("{:.2}", t.baseline_avg()),
    ]);
    table.row(&[
        "cappuccino (parallel)".into(),
        format!("{:.2}", t.cappuccino_first),
        format!("{:.2}", t.cappuccino_second),
        format!("{:.2}", t.cappuccino_avg()),
    ]);

    println!("# Table II — energy, SqueezeNet on Nexus 5 (2 x 1000 runs)\n");
    table.print();
    println!(
        "\nratio: {:.2}x   (paper: baseline 26.39 J, cappuccino 3.38 J, ratio 7.81x)",
        t.ratio()
    );

    // Repeatability (the reason the paper measures twice).
    let rep_base = (t.baseline_first / t.baseline_second - 1.0).abs();
    let rep_capp = (t.cappuccino_first / t.cappuccino_second - 1.0).abs();
    println!("repeatability: baseline {:.3}%, cappuccino {:.3}%", rep_base * 100.0, rep_capp * 100.0);

    assert!((3.0..20.0).contains(&t.ratio()), "energy ratio {:.2} out of band", t.ratio());
    assert!(rep_base < 0.01 && rep_capp < 0.01, "blocks not repeatable");
    println!("table2 bench OK");
}
