//! Validation dataset — Cappuccino's third input (paper Fig. 3).
//!
//! Reads the `dataset.bin` emitted by `python/compile/dataset.py` (the
//! ILSVRC-validation substitute; see DESIGN.md) and provides a native
//! generator producing *structurally identical* synthetic data for
//! standalone tests and workload generation (the two generators share
//! class semantics, not bit-exact pixels — the file is the ground truth
//! the accuracy analysis runs on).

use std::io::Read;
use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"CAPPDATA";
const VERSION: u32 = 1;

/// Number of pattern classes in the synthetic dataset.
pub const NUM_CLASSES: usize = 8;

/// An image classification dataset: NCHW f32 images + labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub classes: usize,
    /// Leading `n_train` images were used for build-time training; the
    /// remainder is the validation split the mode analysis must use.
    pub n_train: usize,
    pub images: Vec<Vec<f32>>,
    pub labels: Vec<u16>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Validation split (images, labels) — what the paper feeds the
    /// inexact-computing analysis.
    pub fn validation(&self) -> (&[Vec<f32>], &[u16]) {
        (&self.images[self.n_train..], &self.labels[self.n_train..])
    }

    /// Image element count.
    pub fn image_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Load `dataset.bin`.
    pub fn read_from(path: impl AsRef<Path>) -> Result<Dataset> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())?.read_to_end(&mut buf)?;
        Self::parse(&buf)
    }

    pub fn parse(buf: &[u8]) -> Result<Dataset> {
        if buf.len() < 36 || &buf[..8] != MAGIC {
            return Err(Error::parse("dataset", "bad magic or truncated header"));
        }
        let u32_at = |off: usize| -> u32 {
            u32::from_le_bytes(buf[off..off + 4].try_into().unwrap())
        };
        let version = u32_at(8);
        if version != VERSION {
            return Err(Error::parse("dataset", format!("version {version}")));
        }
        let n = u32_at(12) as usize;
        let n_train = u32_at(16) as usize;
        let (c, h, w) = (u32_at(20) as usize, u32_at(24) as usize, u32_at(28) as usize);
        let classes = u32_at(32) as usize;
        let img_len = c * h * w;
        let pixels_off = 36;
        let labels_off = pixels_off + 4 * n * img_len;
        if buf.len() < labels_off + 2 * n {
            return Err(Error::parse("dataset", "truncated payload"));
        }
        let mut images = Vec::with_capacity(n);
        for i in 0..n {
            let base = pixels_off + 4 * i * img_len;
            let img: Vec<f32> = buf[base..base + 4 * img_len]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            images.push(img);
        }
        let labels: Vec<u16> = buf[labels_off..labels_off + 2 * n]
            .chunks_exact(2)
            .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
            .collect();
        if labels.iter().any(|&l| (l as usize) >= classes) {
            return Err(Error::parse("dataset", "label out of range"));
        }
        Ok(Dataset { c, h, w, classes, n_train, images, labels })
    }

    /// Native synthetic generator (mirrors the Python pattern classes).
    pub fn generate(n: usize, seed: u64) -> Dataset {
        let (c, h, w) = (3, 16, 16);
        let mut rng = Rng::new(seed);
        let mut labels: Vec<u16> = (0..n).map(|i| (i % NUM_CLASSES) as u16).collect();
        rng.shuffle(&mut labels);
        let images = labels
            .iter()
            .map(|&cls| generate_image(cls as usize, c, h, w, &mut rng))
            .collect();
        Dataset { c, h, w, classes: NUM_CLASSES, n_train: 0, images, labels }
    }
}

/// One synthetic image: class pattern + colour tint + noise (mirrors
/// `python/compile/dataset.py`'s class semantics).
fn generate_image(cls: usize, c: usize, h: usize, w: usize, rng: &mut Rng) -> Vec<f32> {
    let freq = rng.range_f32(0.8, 1.6);
    let phase = rng.range_f32(0.0, 2.0 * std::f32::consts::PI);
    let mut base = vec![0.0f32; h * w];
    match cls {
        0 => fill(&mut base, h, w, |y, _| (y as f32 * freq + phase).sin()),
        1 => fill(&mut base, h, w, |_, x| (x as f32 * freq + phase).sin()),
        2 => fill(&mut base, h, w, |y, x| ((x + y) as f32 * freq * 0.7 + phase).sin()),
        3 => fill(&mut base, h, w, |y, x| {
            (x as f32 * freq + phase).sin() * (y as f32 * freq + phase).sin()
        }),
        4 => {
            let cy = rng.range_f32(5.0, 11.0);
            let cx = rng.range_f32(5.0, 11.0);
            let spread = rng.range_f32(8.0, 20.0);
            fill(&mut base, h, w, |y, x| {
                let dy = y as f32 - cy;
                let dx = x as f32 - cx;
                (-(dy * dy + dx * dx) / spread).exp()
            })
        }
        5 => {
            let sy = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
            let sx = if rng.f32() < 0.5 { -1.0 } else { 1.0 };
            fill(&mut base, h, w, |y, x| {
                (sy * y as f32 / h as f32 + sx * x as f32 / w as f32) * 0.5
            })
        }
        6 => {
            let cy = rng.range_f32(6.0, 10.0);
            let cx = rng.range_f32(6.0, 10.0);
            fill(&mut base, h, w, |y, x| {
                let dy = y as f32 - cy;
                let dx = x as f32 - cx;
                ((dy * dy + dx * dx).sqrt() * freq * 1.5 + phase).sin()
            })
        }
        7 => {
            // 4x4 blocky random field
            let coarse: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
            fill(&mut base, h, w, |y, x| coarse[(y / 4) * 4 + (x / 4)])
        }
        _ => panic!("class {cls} out of range"),
    }
    // Normalise to [0,1].
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in &base {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let range = (hi - lo).max(1e-8);
    for v in &mut base {
        *v = (*v - lo) / range;
    }
    // Colour tint + noise, zero-centred.
    let mut img = Vec::with_capacity(c * h * w);
    for _ in 0..c {
        let tint = rng.range_f32(0.4, 1.0);
        for &v in &base {
            img.push(v * tint + rng.normal() * 0.15 - 0.5);
        }
    }
    img
}

fn fill(buf: &mut [f32], h: usize, w: usize, f: impl Fn(usize, usize) -> f32) {
    for y in 0..h {
        for x in 0..w {
            buf[y * w + x] = f(y, x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic_and_balanced() {
        let a = Dataset::generate(64, 3);
        let b = Dataset::generate(64, 3);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images[0], b.images[0]);
        let mut counts = [0usize; NUM_CLASSES];
        for &l in &a.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 64 / NUM_CLASSES));
    }

    #[test]
    fn image_values_reasonable() {
        let d = Dataset::generate(16, 5);
        for img in &d.images {
            assert_eq!(img.len(), d.image_len());
            assert!(img.iter().all(|v| v.is_finite()));
            let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
            assert!(mean.abs() < 1.0, "mean {mean}");
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Dataset::parse(b"NOPE").is_err());
        let mut ok_header = Vec::new();
        ok_header.extend_from_slice(MAGIC);
        ok_header.extend_from_slice(&2u32.to_le_bytes()); // bad version
        ok_header.extend_from_slice(&[0u8; 24]);
        assert!(Dataset::parse(&ok_header).is_err());
    }

    #[test]
    fn roundtrip_via_python_format() {
        // Serialise a native dataset in the python format and parse it.
        let d = Dataset::generate(8, 1);
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        for v in [1u32, 8, 6, d.c as u32, d.h as u32, d.w as u32, d.classes as u32] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for img in &d.images {
            for &p in img {
                buf.extend_from_slice(&p.to_le_bytes());
            }
        }
        for &l in &d.labels {
            buf.extend_from_slice(&l.to_le_bytes());
        }
        let back = Dataset::parse(&buf).unwrap();
        assert_eq!(back.len(), 8);
        assert_eq!(back.n_train, 6);
        assert_eq!(back.validation().0.len(), 2);
        assert_eq!(back.images[3], d.images[3]);
    }
}
