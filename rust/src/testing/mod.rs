//! In-repo property-testing helper (proptest is not in the vendored
//! crate set).
//!
//! [`check`] runs a property over `n` pseudo-random cases built from a
//! seeded [`Gen`]; on failure it reports the case index and seed so the
//! exact inputs reproduce deterministically. Shrinking is deliberately
//! out of scope — generators here produce small cases by construction.

use crate::util::rng::Rng;

/// Case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Case index (0-based) — handy for size scaling.
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    /// One of the listed values.
    pub fn choose<T: Copy>(&mut self, options: &[T]) -> T {
        options[self.rng.below(options.len())]
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    /// Standard-normal vector.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.rng.f32() < 0.5
    }
}

/// Run `property` over `n` generated cases. Panics (failing the test)
/// with seed + case number on the first violation.
pub fn check(name: &str, n: usize, seed: u64, mut property: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..n {
        let mut gen = Gen { rng: Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)), case };
        if let Err(msg) = property(&mut gen) {
            panic!("property {name:?} failed at case {case} (seed {seed}): {msg}");
        }
    }
}

/// Assert two f32 slices agree within `tol` (absolute + relative).
pub fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("elem {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_valid_property() {
        check("add-commutes", 50, 42, |g| {
            let a = g.f32(-10.0, 10.0);
            let b = g.f32(-10.0, 10.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "failed at case")]
    fn check_reports_failures() {
        check("always-false", 3, 1, |_| Err("nope".into()));
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen-ranges", 100, 7, |g| {
            let v = g.int(3, 9);
            if !(3..=9).contains(&v) {
                return Err(format!("int out of range: {v}"));
            }
            let c = g.choose(&[1, 2, 4, 8]);
            if ![1, 2, 4, 8].contains(&c) {
                return Err(format!("choose out of set: {c}"));
            }
            Ok(())
        });
    }

    #[test]
    fn close_detects_divergence() {
        assert!(close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut first = Vec::new();
        check("record", 5, 99, |g| {
            first.push(g.int(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        check("record", 5, 99, |g| {
            second.push(g.int(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
