//! Serving front-end: request router, dynamic batcher, model workers.
//!
//! Cappuccino synthesizes *inference software*; this module is the
//! deployment harness around it — the vLLM-router-shaped L3 that makes
//! the synthesized program a service:
//!
//! * [`Router`] — routes requests to per-model bounded queues
//!   (backpressure: a full queue rejects instead of buffering without
//!   bound).
//! * dynamic batcher — each worker drains its queue into the smallest
//!   adequate AOT-compiled batch capacity within a latency budget
//!   ([`BatchPolicy`]). A drained batch executes as **one** backend
//!   call. The native engine backend runs only the `len <= capacity`
//!   live rows of a partial batch — padded lanes are never computed, so
//!   stale or duplicated data cannot reach replies. The PJRT backend's
//!   fixed-shape executables still zero-pad to capacity and truncate
//!   the reply rows to `len` (device programs have static shapes).
//! * [`worker`] threads — own the execution backend. PJRT objects are
//!   not `Send`, so the backend is constructed *on* the worker thread
//!   from a `Send` factory; weights stay device-resident across
//!   requests. A worker may request a [`CoreSet`] ([`BatchPolicy`]):
//!   its thread is then pinned via `sched_setaffinity` (no-op off
//!   Linux), and co-hosted models given **disjoint** sets
//!   ([`crate::engine::Topology::partition`]) stop trampling each
//!   other's caches.
//! * **shutdown drains**: a worker that observes the shutdown signal
//!   first executes every request already accepted into its queue —
//!   the router never admits a request that is then silently dropped.
//!
//! Python never appears anywhere on this path.

pub mod workload;

pub use workload::ArrivalProcess;

pub use crate::engine::topology::CoreSet;

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::{LatencyHistogram, ServeCounters, Throughput};
use crate::util::error::{Error, Result};

/// An inference request: one image (conventional NCHW layout).
pub struct ServeRequest {
    pub image: Vec<f32>,
    enqueued: Instant,
    reply: mpsc::SyncSender<ServeResponse>,
}

/// The reply: logits + measured latency + the batch it rode in.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
}

/// Execution backend run by a worker thread.
pub trait Backend {
    /// Expected per-image input element count.
    fn input_len(&self) -> usize;
    /// AOT-available batch capacities, ascending (native backends may
    /// return any set; `[1]` means no batching).
    fn batch_sizes(&self) -> &[usize];
    /// Run a batch (`images.len() <= capacity`) at the given capacity;
    /// returns one logits row per input image.
    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>>;
}

/// Factory constructing a backend *on* the worker thread (PJRT is not
/// `Send`).
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>;

/// Dynamic batching policy (plus the worker's placement request).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Upper bound on batch size (further capped by the backend).
    pub max_batch: usize,
    /// How long to wait for more requests after the first arrives.
    pub max_delay: Duration,
    /// Bound of the per-model request queue (backpressure limit).
    pub queue_depth: usize,
    /// Optional core set the model's worker thread is pinned to
    /// (`sched_setaffinity`; silently a no-op off Linux or when the
    /// kernel rejects the mask). Co-hosted models should request
    /// **disjoint** sets — [`crate::engine::Topology::partition`] hands
    /// them out. With `threads = 1` the whole inference runs inline on
    /// the pinned worker thread; multi-chunk parallel regions still run
    /// on the shared engine pool.
    pub cores: Option<CoreSet>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            queue_depth: 64,
            cores: None,
        }
    }
}

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub counters: ServeCounters,
    pub latency: LatencyHistogram,
    pub throughput: Throughput,
}

impl ServeMetrics {
    pub fn summary(&self) -> String {
        format!(
            "requests={} completed={} rejected={} batches={} mean_batch={:.2} rps={:.1} latency[{}]",
            self.counters.requests.load(Ordering::Relaxed),
            self.counters.completed.load(Ordering::Relaxed),
            self.counters.rejected.load(Ordering::Relaxed),
            self.counters.batches.load(Ordering::Relaxed),
            self.counters.mean_batch_size(),
            self.throughput.per_second(),
            self.latency.summary(),
        )
    }
}

enum Job {
    Infer(ServeRequest),
    Shutdown,
}

/// Routes requests to per-model worker queues.
pub struct Router {
    queues: HashMap<String, mpsc::SyncSender<Job>>,
    metrics: Arc<ServeMetrics>,
}

impl Router {
    /// Submit an image for inference on `model`; returns the response
    /// receiver. Full queues reject immediately (backpressure).
    pub fn submit(&self, model: &str, image: Vec<f32>) -> Result<mpsc::Receiver<ServeResponse>> {
        let queue = self
            .queues
            .get(model)
            .ok_or_else(|| Error::Serve(format!("unknown model {model:?}")))?;
        self.metrics.counters.requests.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::sync_channel(1);
        let req = ServeRequest { image, enqueued: Instant::now(), reply: reply_tx };
        match queue.try_send(Job::Infer(req)) {
            Ok(()) => Ok(reply_rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::Serve(format!("model {model:?}: queue full (backpressure)")))
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                Err(Error::Serve(format!("model {model:?}: worker gone")))
            }
        }
    }

    /// Submit and wait for the response.
    pub fn infer_blocking(&self, model: &str, image: Vec<f32>) -> Result<ServeResponse> {
        let rx = self.submit(model, image)?;
        rx.recv()
            .map_err(|_| Error::Serve("worker dropped the request".into()))
    }
}

/// A running server: router + worker threads.
pub struct Server {
    router: Router,
    handles: Vec<std::thread::JoinHandle<()>>,
    shutdown_txs: Vec<mpsc::SyncSender<Job>>,
    metrics: Arc<ServeMetrics>,
}

impl Server {
    /// Start a server hosting the given `(model name, backend factory,
    /// policy)` triples — one worker thread per model.
    pub fn start(models: Vec<(String, BackendFactory, BatchPolicy)>) -> Result<Server> {
        let metrics = Arc::new(ServeMetrics::default());
        let mut queues = HashMap::new();
        let mut handles = Vec::new();
        let mut shutdown_txs = Vec::new();
        for (name, factory, policy) in models {
            let (tx, rx) = mpsc::sync_channel::<Job>(policy.queue_depth);
            // Construct the backend on the worker thread and report
            // failures back through a startup channel.
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let m = Arc::clone(&metrics);
            let handle = std::thread::Builder::new()
                .name(format!("cappuccino-worker-{name}"))
                .spawn(move || worker_loop(factory, rx, policy, m, ready_tx))
                .map_err(|e| Error::Serve(format!("spawn worker: {e}")))?;
            ready_rx
                .recv()
                .map_err(|_| Error::Serve(format!("worker {name} died during startup")))??;
            queues.insert(name, tx.clone());
            shutdown_txs.push(tx);
            handles.push(handle);
        }
        Ok(Server {
            router: Router { queues, metrics: Arc::clone(&metrics) },
            handles,
            shutdown_txs,
            metrics,
        })
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        for tx in &self.shutdown_txs {
            let _ = tx.send(Job::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker: pin if requested, construct backend, then batch-and-execute
/// until shutdown — and **drain** on shutdown (see
/// [`drain_after_shutdown`]).
fn worker_loop(
    factory: BackendFactory,
    rx: mpsc::Receiver<Job>,
    policy: BatchPolicy,
    metrics: Arc<ServeMetrics>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    if let Some(cores) = policy.cores {
        // Placement hint only: failure (or a non-Linux host) leaves the
        // worker unpinned and everything else identical.
        let _ = crate::engine::topology::pin_current_thread(&cores.cpus());
    }
    let mut backend = match factory() {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let max_capacity = backend
        .batch_sizes()
        .last()
        .copied()
        .unwrap_or(1)
        .min(policy.max_batch)
        .max(1);

    loop {
        // Block for the first request.
        let first = match rx.recv() {
            Ok(Job::Infer(r)) => r,
            Ok(Job::Shutdown) => {
                drain_after_shutdown(&mut *backend, &rx, max_capacity, &metrics);
                return;
            }
            Err(_) => return,
        };
        let mut batch = vec![first];
        // Dynamic batching: wait up to max_delay for more work.
        let deadline = Instant::now() + policy.max_delay;
        while batch.len() < max_capacity {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::Infer(r)) => batch.push(r),
                Ok(Job::Shutdown) => {
                    run_batch(&mut *backend, &batch, &metrics);
                    drain_after_shutdown(&mut *backend, &rx, max_capacity, &metrics);
                    return;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    run_batch(&mut *backend, &batch, &metrics);
                    return;
                }
            }
        }
        run_batch(&mut *backend, &batch, &metrics);
    }
}

/// Post-shutdown drain: execute every request already sitting in the
/// queue, in arrival order, batched at the worker's capacity.
///
/// Without this, a worker observing `Job::Shutdown` returned
/// immediately and dropped every `Infer` job queued behind the signal —
/// requests the router had *accepted* (clients were already waiting on
/// a reply channel) surfaced as "worker dropped the request". A
/// shutdown now closes the door to new work (the router's sender is
/// dropped by [`Server::shutdown`]) but always finishes work it let in.
fn drain_after_shutdown(
    backend: &mut dyn Backend,
    rx: &mpsc::Receiver<Job>,
    max_capacity: usize,
    metrics: &ServeMetrics,
) {
    let mut batch: Vec<ServeRequest> = Vec::new();
    loop {
        match rx.try_recv() {
            Ok(Job::Infer(r)) => {
                batch.push(r);
                if batch.len() >= max_capacity {
                    run_batch(backend, &batch, metrics);
                    batch.clear();
                }
            }
            // Duplicate shutdown signals fold into the first.
            Ok(Job::Shutdown) => {}
            Err(mpsc::TryRecvError::Empty) | Err(mpsc::TryRecvError::Disconnected) => break,
        }
    }
    if !batch.is_empty() {
        run_batch(backend, &batch, metrics);
    }
}

/// Execute one formed batch at the smallest adequate AOT capacity.
fn run_batch(backend: &mut dyn Backend, batch: &[ServeRequest], metrics: &ServeMetrics) {
    // Pick the smallest compiled capacity that fits the batch; fall back
    // to the largest (callers never exceed it by construction).
    let capacity = backend
        .batch_sizes()
        .iter()
        .copied()
        .find(|&b| b >= batch.len())
        .unwrap_or_else(|| backend.batch_sizes().last().copied().unwrap_or(1));

    let images: Vec<&[f32]> = batch.iter().map(|r| r.image.as_slice()).collect();
    let result = backend.infer_batch(&images, capacity);
    metrics.counters.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .counters
        .batched_items
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    match result {
        Ok(rows) => {
            for (req, logits) in batch.iter().zip(rows) {
                let latency = req.enqueued.elapsed();
                metrics.latency.record(latency);
                metrics.counters.completed.fetch_add(1, Ordering::Relaxed);
                metrics.throughput.add(1);
                let _ = req.reply.send(ServeResponse {
                    logits,
                    latency,
                    batch_size: batch.len(),
                });
            }
        }
        Err(e) => {
            // Drop the reply senders: receivers observe RecvError.
            eprintln!("worker batch failed: {e}");
        }
    }
}

// ---------------------------------------------------------------------------
// Backends
// ---------------------------------------------------------------------------

/// Native-engine backend configuration (no artifacts needed). The
/// factory builds one batch-capacity [`crate::engine::ExecutionPlan`]
/// per AOT batch size on the worker thread (baked weights `Arc`-shared
/// across capacities via
/// [`crate::engine::ExecutionPlan::with_capacity`] — parameters are
/// never duplicated), so weights and the `B x`-sized buffer arenas stay
/// resident across requests — the native analogue of the PJRT backend's
/// device-resident executables. A drained dynamic batch executes as
/// **one** plan walk ([`crate::engine::ExecutionPlan::run_batch`]), not
/// a per-image loop; partial batches only walk live rows.
pub struct EngineBackend {
    net: crate::model::Network,
    params: crate::engine::EngineParams,
    modes: crate::engine::ModeAssignment,
    threads: usize,
    /// Explicit per-layer schedule (a `schedule.json` artifact from
    /// `cappuccino tune`); `None` lowers the uniform modes/threads
    /// configuration. Either way plan compilation goes through the one
    /// [`crate::engine::Schedule`] surface.
    schedule: Option<crate::engine::Schedule>,
    batches: Vec<usize>,
    input_len: usize,
}

impl EngineBackend {
    pub fn new(
        net: crate::model::Network,
        params: crate::engine::EngineParams,
        modes: crate::engine::ModeAssignment,
        threads: usize,
        max_batch: usize,
    ) -> Self {
        let input_len = net.input.elements();
        EngineBackend {
            net,
            params,
            modes,
            threads,
            schedule: None,
            batches: (0..).map(|i| 1 << i).take_while(|&b| b <= max_batch.max(1)).collect(),
            input_len,
        }
    }

    /// Serve a tuned schedule artifact: per-layer parallelism, packing,
    /// tiling, modes, and the pool settings all come from `schedule`
    /// (validated against the net at worker startup). This is the
    /// `serve --schedule schedule.json` path — the configuration
    /// measured by `cappuccino tune` runs unchanged in production.
    pub fn with_schedule(
        net: crate::model::Network,
        params: crate::engine::EngineParams,
        schedule: crate::engine::Schedule,
        max_batch: usize,
    ) -> Self {
        let modes = schedule.mode_assignment();
        let threads = schedule.pool.threads;
        let mut backend = EngineBackend::new(net, params, modes, threads, max_batch);
        backend.schedule = Some(schedule);
        backend
    }

    /// Factory for [`Server::start`]: plan compilation happens on the
    /// worker thread (mirroring the PJRT startup path) and failures
    /// propagate through the server's startup channel. The network is
    /// compiled **once** at the largest capacity; every other capacity
    /// is derived with `with_capacity`, sharing the baked weights.
    pub fn factory(self) -> BackendFactory {
        Box::new(move || {
            let max_capacity = self.batches.last().copied().unwrap_or(1);
            // Either way the builder lowers into the one Schedule
            // surface; an explicit artifact is applied verbatim, the
            // uniform configuration through the fluent sugar.
            let mut builder = crate::engine::PlanBuilder::new(&self.net, &self.params)
                .modes(&self.modes)
                .threads(self.threads)
                .batch(max_capacity);
            if let Some(s) = self.schedule.clone() {
                builder = builder.schedule(s);
            }
            let base = builder.build()?;
            // Derive the smaller capacities, then reuse `base` as the
            // largest — no throwaway duplicate of the biggest arena.
            let smaller = self.batches.len().saturating_sub(1);
            let mut plans: Vec<crate::engine::ExecutionPlan> = self.batches[..smaller]
                .iter()
                .map(|&b| base.with_capacity(b))
                .collect();
            plans.push(base);
            Ok(Box::new(CompiledEngineBackend {
                plans,
                batches: self.batches,
                input_len: self.input_len,
            }) as Box<dyn Backend>)
        })
    }
}

/// The worker-resident form of [`EngineBackend`]: compiled plans only.
struct CompiledEngineBackend {
    plans: Vec<crate::engine::ExecutionPlan>,
    batches: Vec<usize>,
    input_len: usize,
}

impl Backend for CompiledEngineBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>> {
        let idx = self
            .batches
            .iter()
            .position(|&b| b == capacity)
            .unwrap_or(self.batches.len().saturating_sub(1));
        let plan = self
            .plans
            .get_mut(idx)
            .ok_or_else(|| Error::Serve("engine backend has no compiled plans".into()))?;
        // One plan walk for the whole drained batch: only the
        // `images.len() <= capacity` live rows are computed, so padded
        // lanes can never surface stale or duplicated data in replies.
        plan.run_batch(images)
    }
}

/// PJRT backend: one compiled executable per AOT batch size, weights
/// device-resident. Constructed on the worker thread via
/// [`pjrt_factory`].
pub struct PjrtBackend {
    models: Vec<crate::runtime::LoadedModel>, // ascending batch
    batches: Vec<usize>,
    c: usize,
    h: usize,
    w: usize,
    u: usize,
}

impl Backend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.c * self.h * self.w
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>> {
        let idx = self
            .batches
            .iter()
            .position(|&b| b == capacity)
            .ok_or_else(|| Error::Serve(format!("no artifact with batch {capacity}")))?;
        let model = &self.models[idx];
        let x = crate::runtime::batch_to_mapmajor(images, self.c, self.h, self.w, self.u, capacity);
        let rows = model.infer_rows(&x)?;
        Ok(rows.into_iter().take(images.len()).collect())
    }
}

/// Build a PJRT backend factory for `(net, mode)` using every batch size
/// in the manifest.
pub fn pjrt_factory(
    artifacts_dir: std::path::PathBuf,
    net: String,
    mode: String,
    source_seed: Option<u64>,
) -> BackendFactory {
    Box::new(move || {
        let manifest = crate::runtime::Manifest::load(&artifacts_dir)?;
        let network = manifest
            .nets
            .get(&net)
            .ok_or_else(|| Error::Invalid(format!("manifest has no net {net:?}")))?;
        let (c, h, w) = network.input.as_maps()?;
        let runtime = crate::runtime::Runtime::new()?;
        let source = match source_seed {
            Some(seed) => crate::runtime::ParamSource::Random(seed),
            None => crate::runtime::ParamSource::MapMajorFile(
                crate::config::ModelFile::read_from(
                    artifacts_dir.join(format!("{net}_mm.capp")),
                )?,
            ),
        };
        let batches = manifest.batch_sizes(&net, &mode);
        if batches.is_empty() {
            return Err(Error::Invalid(format!("no artifacts for {net}/{mode}")));
        }
        let mut models = Vec::new();
        for &b in &batches {
            let spec = manifest.find(&net, &mode, b)?;
            models.push(runtime.load(&manifest, spec, &source)?);
        }
        Ok(Box::new(PjrtBackend { models, batches, c, h, w, u: manifest.u }) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArithMode, EngineParams, ModeAssignment};
    use crate::model::zoo;
    use crate::util::rng::Rng;

    fn engine_server(max_batch: usize, policy: BatchPolicy) -> Server {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 7, 4).unwrap();
        let backend = EngineBackend::new(
            net,
            params,
            ModeAssignment::uniform(ArithMode::Imprecise),
            1,
            max_batch,
        );
        Server::start(vec![("tinynet".into(), backend.factory(), policy)]).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let server = engine_server(8, BatchPolicy::default());
        let mut rng = Rng::new(1);
        let img = rng.normal_vec(3 * 16 * 16);
        let resp = server.router().infer_blocking("tinynet", img).unwrap();
        assert_eq!(resp.logits.len(), 8);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
        server.shutdown();
    }

    #[test]
    fn unknown_model_rejected() {
        let server = engine_server(8, BatchPolicy::default());
        let err = server.router().submit("resnet", vec![0.0; 768]).unwrap_err();
        assert!(err.to_string().contains("unknown model"));
        server.shutdown();
    }

    #[test]
    fn burst_is_batched() {
        let server = engine_server(
            8,
            BatchPolicy {
                max_batch: 8,
                max_delay: Duration::from_millis(30),
                queue_depth: 64,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(2);
        let rxs: Vec<_> = (0..12)
            .map(|_| {
                server
                    .router()
                    .submit("tinynet", rng.normal_vec(3 * 16 * 16))
                    .unwrap()
            })
            .collect();
        let responses: Vec<ServeResponse> =
            rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(responses.len(), 12);
        // At least one response must have ridden a multi-request batch.
        assert!(
            responses.iter().any(|r| r.batch_size > 1),
            "batcher never formed a batch"
        );
        let m = server.metrics();
        assert_eq!(m.counters.completed.load(Ordering::Relaxed), 12);
        assert!(m.counters.batches.load(Ordering::Relaxed) < 12);
        server.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // Tiny queue + slow drain: flooding must produce rejections.
        let server = engine_server(
            1,
            BatchPolicy {
                max_batch: 1,
                max_delay: Duration::ZERO,
                queue_depth: 2,
                ..Default::default()
            },
        );
        let mut rng = Rng::new(3);
        let mut rejected = 0;
        let mut pending = Vec::new();
        for _ in 0..200 {
            match server.router().submit("tinynet", rng.normal_vec(3 * 16 * 16)) {
                Ok(rx) => pending.push(rx),
                Err(_) => rejected += 1,
            }
        }
        for rx in pending {
            let _ = rx.recv();
        }
        assert!(rejected > 0, "queue never filled");
        assert_eq!(
            server.metrics().counters.rejected.load(Ordering::Relaxed),
            rejected
        );
        server.shutdown();
    }

    #[test]
    fn partial_batch_at_capacity_matches_single_image_runs() {
        // Regression (batch-first redesign): a 3-request batch executed
        // at capacity 8 must reply with each request's own logits —
        // padded lanes (and stale rows from earlier full batches) must
        // never reach a reply. Exercised directly against the backend so
        // the capacity is pinned rather than left to the batcher's
        // smallest-adequate choice.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 11, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let backend =
            EngineBackend::new(net.clone(), params.clone(), modes.clone(), 2, 8);
        let mut backend = (backend.factory())().unwrap();
        assert_eq!(backend.batch_sizes().last(), Some(&8));

        let mut rng = Rng::new(12);
        let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(3 * 16 * 16)).collect();
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        // Prime every lane with a full batch, then run the partial one:
        // whatever the full batch left behind must not leak.
        let full = backend.infer_batch(&refs, 8).unwrap();
        assert_eq!(full.len(), 8);
        let partial = backend.infer_batch(&refs[..3], 8).unwrap();
        assert_eq!(partial.len(), 3, "one reply per live request, none for padding");

        // Oracle: fresh single-image plans.
        let mut single = crate::engine::PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        for (i, row) in partial.iter().enumerate() {
            assert_eq!(row, &single.run(&images[i]).unwrap(), "lane {i} leaked");
        }
    }

    #[test]
    fn schedule_backend_matches_uniform_backend() {
        // A serve worker fed a schedule artifact must produce bitwise
        // the logits of the equivalent uniform-setter backend — the
        // tune → serve artifact path cannot perturb numerics.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 21, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let uniform = EngineBackend::new(net.clone(), params.clone(), modes.clone(), 2, 4);
        let mut uniform = (uniform.factory())().unwrap();
        let sched = crate::engine::Schedule::from_uniform(
            &net,
            4,
            &modes,
            crate::engine::Parallelism::Olp,
            true,
            None,
            crate::engine::PoolSettings { threads: 2, affinity: false, cores: None },
        )
        .unwrap();
        let scheduled = EngineBackend::with_schedule(net, params, sched, 4);
        let mut scheduled = (scheduled.factory())().unwrap();
        let mut rng = Rng::new(22);
        let imgs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(3 * 16 * 16)).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            uniform.infer_batch(&refs, 4).unwrap(),
            scheduled.infer_batch(&refs, 4).unwrap()
        );
    }

    #[test]
    fn multi_model_routing() {
        let net = zoo::tinynet();
        let p1 = EngineParams::random(&net, 1, 4).unwrap();
        let p2 = EngineParams::random(&net, 2, 4).unwrap();
        let b1 = EngineBackend::new(
            net.clone(),
            p1,
            ModeAssignment::uniform(ArithMode::Precise),
            1,
            4,
        );
        let b2 = EngineBackend::new(
            net,
            p2,
            ModeAssignment::uniform(ArithMode::Precise),
            1,
            4,
        );
        let server = Server::start(vec![
            ("a".into(), b1.factory(), BatchPolicy::default()),
            ("b".into(), b2.factory(), BatchPolicy::default()),
        ])
        .unwrap();
        let mut rng = Rng::new(4);
        let img = rng.normal_vec(768);
        let ra = server.router().infer_blocking("a", img.clone()).unwrap();
        let rb = server.router().infer_blocking("b", img).unwrap();
        // Different weights → different logits.
        assert_ne!(ra.logits, rb.logits);
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_requests_queued_behind_the_signal() {
        // Regression: worker_loop used to return the moment it popped
        // Job::Shutdown, silently dropping every accepted Infer job
        // still queued behind the signal (clients saw "worker dropped
        // the request"). Drive the loop directly with a pre-filled
        // queue so the interleaving is deterministic: requests are
        // submitted past the shutdown signal in both positions the loop
        // can observe it (mid-batching and as the first job).
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 31, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut rng = Rng::new(32);

        for shutdown_first in [false, true] {
            let backend =
                EngineBackend::new(net.clone(), params.clone(), modes.clone(), 1, 4);
            let (tx, rx) = mpsc::sync_channel::<Job>(16);
            let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
            let metrics = Arc::new(ServeMetrics::default());

            let mut reply_rxs = Vec::new();
            let mut queue: Vec<Job> = Vec::new();
            for i in 0..3 {
                let (reply_tx, reply_rx) = mpsc::sync_channel(1);
                reply_rxs.push(reply_rx);
                let req = ServeRequest {
                    image: rng.normal_vec(3 * 16 * 16),
                    enqueued: Instant::now(),
                    reply: reply_tx,
                };
                queue.push(Job::Infer(req));
                // Mid-batching variant: shutdown lands after the first
                // request, with two more accepted behind it.
                if !shutdown_first && i == 0 {
                    queue.push(Job::Shutdown);
                }
            }
            if shutdown_first {
                queue.insert(0, Job::Shutdown);
            }
            for job in queue {
                tx.try_send(job).unwrap();
            }

            let policy = BatchPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(50),
                queue_depth: 16,
                ..Default::default()
            };
            worker_loop(backend.factory(), rx, policy, Arc::clone(&metrics), ready_tx);
            ready_rx.recv().unwrap().unwrap();

            for (i, reply_rx) in reply_rxs.into_iter().enumerate() {
                let resp = reply_rx.recv().unwrap_or_else(|_| {
                    panic!("shutdown_first={shutdown_first}: request {i} dropped at shutdown")
                });
                assert!(resp.logits.iter().all(|v| v.is_finite()));
            }
            assert_eq!(
                metrics.counters.completed.load(Ordering::Relaxed),
                3,
                "shutdown_first={shutdown_first}"
            );
        }
    }

    #[test]
    fn pinned_worker_roundtrips_and_partitions_are_disjoint() {
        // Core-set pinning is a placement hint: whatever the host (no
        // Linux, taskset mask, bad ids), serving must work identically.
        let sets = crate::engine::Topology::probe().partition(2);
        assert_eq!(sets.len(), 2);
        assert!(sets[0].disjoint(&sets[1]));
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 33, 4).unwrap();
        let backend = EngineBackend::new(
            net,
            params,
            ModeAssignment::uniform(ArithMode::Imprecise),
            1,
            4,
        );
        let policy = BatchPolicy { cores: Some(sets[0]), ..Default::default() };
        let server =
            Server::start(vec![("pinned".into(), backend.factory(), policy)]).unwrap();
        let mut rng = Rng::new(34);
        let resp = server
            .router()
            .infer_blocking("pinned", rng.normal_vec(3 * 16 * 16))
            .unwrap();
        assert_eq!(resp.logits.len(), 8);
        server.shutdown();
    }

    #[test]
    fn failed_backend_startup_propagates() {
        let factory: BackendFactory =
            Box::new(|| Err(Error::Serve("no artifacts".into())));
        let err = match Server::start(vec![("x".into(), factory, BatchPolicy::default())]) {
            Err(e) => e,
            Ok(_) => panic!("startup should have failed"),
        };
        assert!(err.to_string().contains("no artifacts"));
    }
}
