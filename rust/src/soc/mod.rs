//! Mobile SoC simulator — the paper's testbed substitute (DESIGN.md
//! substitution table).
//!
//! The paper measures three Android phones; none exist here, so Tables
//! I–III regenerate on an analytic per-layer roofline ([`latency`]),
//! a power-integral energy model ([`energy`]), and an implementation of
//! the CNNDroid prior-art execution strategy ([`cnndroid`]), all over a
//! small device catalog ([`devices`]) whose efficiency scalars are
//! calibrated once per device from the paper's own baseline column.

pub mod cnndroid;
pub mod devices;
pub mod energy;
pub mod latency;

pub use cnndroid::CnnDroidModel;
pub use devices::{by_name, catalog, DeviceModel, ProcessingMode};
pub use energy::{energy_joules, energy_table2, EnergyTable};
pub use latency::{measure_trimmed, simulate, SimReport};
