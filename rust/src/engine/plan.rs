//! Compiled execution plans — compile once, execute many, **batch
//! first**.
//!
//! Cappuccino's premise is that inference software is *synthesized*
//! ahead of time and then runs with no interpretive or allocation
//! overhead on the request path. [`ExecutionPlan`] is that executable
//! form for the native engine, and [`PlanBuilder`] is the one way to
//! make one: given a network, compiled parameters, a per-layer mode
//! assignment, an execution config, an executor family and a batch
//! capacity `B`, `build`:
//!
//! 1. runs shape inference **once** (every window/shape violation
//!    surfaces here as `Error::Shape`, never as a hot-path underflow),
//! 2. lowers the layer tree into a flat step sequence over an explicit
//!    register file of activation buffers,
//! 3. **bakes** every layer's weights into its arithmetic mode's domain
//!    (the per-call weight cast the legacy executor paid is gone) and
//!    **packs** them into streaming panels (below),
//! 4. picks per-conv-layer **tile sizes** from a small L1/L2 cost model
//!    ([`crate::engine::conv::ConvTiling::choose`]), stored on the
//!    lowered step, and
//! 5. sizes a buffer arena — per-step outputs, pad/cast scratch,
//!    per-thread FLP/KLP reduction buffers, and per-thread kernel
//!    scratch rows — with every activation register and scratch row
//!    sized `B x`, allocated once and reused across every batch.
//!
//! ## Packed weight panels
//!
//! Conv weights leave `build` as **tap-major panels** (mode-cast first,
//! then permuted — the two commute elementwise):
//! `w[((((ms*Cb + cs)*K + kh)*K + kw)*u + ol)*u + il]` is the weight of
//! output channel `ms*u + ol` against input channel `cs*u + il` at tap
//! `(kh, kw)`, so the conv kernel streams weights strictly sequentially
//! with zero per-tap gathers (see [`crate::layout::pack_conv_panels`]).
//! Dense weights become column-blocked panels
//! ([`crate::layout::pack_dense_panels`]):
//! `w[(ob*I + col)*B + ol]` feeds `B =`
//! [`crate::layout::DENSE_BLOCK`] output neurons per pass over the
//! activation vector. Packing is bitwise invisible — the packed kernels
//! keep the unpacked kernels' exact accumulation order, and the legacy
//! interpreters (unpacked layout) remain the parity oracle.
//! [`PlanBuilder::packing`]`(false)` compiles the previous unpacked
//! row-walk plan for the ablation bench;
//! [`PlanBuilder::tiling`] overrides the cost model's tile choice.
//!
//! ## Vector kernels and the quantized path
//!
//! Non-[`ArithMode::Precise`] packed layers additionally select the
//! SIMD row kernels ([`crate::engine::simd`]) over the same panels —
//! the packed layout *is* the vector layout, and the f32 vector
//! kernels are bitwise identical to their scalar fallback, so kernel
//! selection (including the per-layer
//! [`LayerSchedule::vector_width`] override) never perturbs output.
//! [`ArithMode::QuantI8`] layers go further: their panels are baked as
//! symmetric **int8** at plan compile (`scale = amax/127`, stored
//! beside the panel), activations are quantized per image into an `i8`
//! arena scratch, and the kernels accumulate in widening `i32` and
//! requantize back to f32 on store. QuantI8 lowers only through the
//! packed map-major path: `packing(false)`, row-major (FLP/KLP)
//! scheduling, or a width `u` that cannot be lane-padded (not 1, 2, 4
//! or 8) is rejected at `build` with [`Error::Config`].
//!
//! ## Tile cost model
//!
//! Per conv layer, [`crate::engine::conv::ConvTiling::choose`] sizes
//! `(tm, th)` so that `tm` stacks' packed panels and a `th`-row band's
//! padded input working set each fit in half of the modelled L2: one
//! `(batch row, stack tile)` macro item then walks rows in bands with
//! the stack loop innermost, so each padded input row loaded into cache
//! serves up to `ceil(k/s)` output rows across `tm` stacks before
//! eviction. Macro items own contiguous output blocks, and the pool
//! chunks on macro-item boundaries — tiles never straddle threads.
//!
//! The execution entry point is [`ExecutionPlan::run_batch`] (plus
//! [`ExecutionPlan::run_batch_into`] for caller-owned output rows): a
//! dynamic batch of `len <= B` images executes as **one** walk of the
//! step sequence. The batch loop is lowered *into* the steps — a conv
//! layer's OLP `parallel_for` chunks span the whole `B x alpha` item
//! space in a single parallel region, so region startup and dispatch
//! are paid once per layer per batch instead of once per layer per
//! image. Only live rows are walked: a partial batch never computes
//! (or leaks) padded lanes. Per-row numerics are independent of the
//! batch size and chunking, so `run_batch` of `N` images is **bitwise
//! identical** to `N` single-image runs (`rust/tests/batch_parity.rs`).
//! [`ExecutionPlan::run`] is the thin `B = 1` wrapper.
//!
//! `run_batch` is steady-state allocation-free apart from the returned
//! logits rows (metered through [`crate::metrics::AllocCounter`]);
//! multi-threaded walks additionally pay a handful of small dispatch
//! boxes per parallel section — and zero thread spawns (all parallel
//! sections run on the persistent [`crate::engine::parallel`] pool).
//!
//! Three lowering families share the machinery, selected on the
//! builder:
//!
//! * [`PlanBuilder::new`] (default) — map-major + OLP `conv_mm`: the
//!   synthesized program (what [`crate::engine::run_mapmajor`] wraps).
//! * [`PlanBuilder::baseline`] — row-major scalar, precise: the
//!   Table I baseline (what [`crate::engine::run_baseline`] wraps).
//! * [`PlanBuilder::policy`] — FLP/KLP network-level plans for the
//!   section IV.A ablation, with their per-thread partial buffers
//!   preallocated in the arena.
//!
//! Serve backends hold one plan per AOT batch capacity;
//! [`ExecutionPlan::with_capacity`] derives a sibling plan with a
//! different `B` that **shares the baked weights** (`Arc`) and only
//! re-sizes the arena — capacities never duplicate parameters.
//!
//! ## The schedule surface (setter → `Schedule` migration)
//!
//! Every tuning knob above is now a field of the
//! [`crate::engine::schedule::Schedule`] IR, and plan compilation has
//! exactly **one** entry: a `Schedule`. The fluent setters are sugar
//! that [`PlanBuilder::build`] lowers into a *uniform* schedule
//! ([`crate::engine::schedule::Schedule::from_uniform`]):
//!
//! | fluent setter | schedule field |
//! |---|---|
//! | `.modes(ma)` | `layers[name].mode` (per layer) |
//! | `.policy(p)` | `layers[*].parallelism` (uniform) |
//! | `.packing(b)` | `layers[*].packing` (uniform) |
//! | `.tiling(t)` | `layers[*].tiling` (uniform override) |
//! | `.threads(n)` / `.config(cfg)` | `pool.threads` |
//! | `.affinity(b)` | `pool.affinity` + `layers[*].placement` |
//!
//! [`PlanBuilder::schedule`] accepts a **heterogeneous** schedule
//! directly: parallelism, packing, tiling, mode, and placement are
//! honored *per layer*. A boundary between a map-major (OLP) layer and
//! a row-major (FLP/KLP) layer lowers to an exact layout-reorder step —
//! a pure permutation, so each layer stays bitwise faithful to its
//! uniform-plan kernel. Schedules serialize to JSON
//! (`cappuccino tune` → `schedule.json` → `serve --schedule`), and a
//! plan rebuilt from a reloaded schedule is bitwise identical to the
//! plan it was exported from ([`ExecutionPlan::schedule`] exposes the
//! lowered schedule for exactly that round trip).
//!
//! Degenerate configurations — `batch(0)`, `threads(0)`, mode or
//! schedule entries naming layers the network does not have, or a
//! schedule whose layer set / `u` does not match — are rejected at
//! `build` with [`Error::Config`] instead of panicking in compile.
//!
//! ## Static guarantees
//!
//! Every compiled plan is additionally proved sound by the static plan
//! verifier ([`crate::engine::verify`]) — at `build` time in debug
//! builds (and release builds with `CAPPUCCINO_VERIFY=1`), on every
//! autotuner candidate before it is timed, and on demand via
//! `cappuccino check`. Four rule classes:
//!
//! 1. **Race-freedom** — within each parallel region, the write ranges
//!    of distinct macro items (derived from the *same* tiling
//!    arithmetic the kernels dispatch with,
//!    [`crate::engine::conv::ConvTiling::dispatched`]) are pairwise
//!    disjoint, no item reads a register another item writes, and the
//!    per-thread `reduce` / `thread_scratch` row counts cover the
//!    pool's chunk count ([`crate::engine::parallel::chunk_ranges`]).
//! 2. **Def-before-use + layout consistency** — every register is
//!    written before it is read, and a symbolic layout state (map-major
//!    width `u` vs NCHW, tracked the way the lowerer's `nchw_ctx` is)
//!    matches every consumer, with `Reorder` the only legal transition.
//! 3. **Arena safety** — register extents and scratch / `qscratch` /
//!    `reduce` / `thread_scratch` rows fit the preallocated arena at
//!    the plan's capacity, so [`ExecutionPlan::with_capacity`]
//!    derivation can never silently under-size a sibling.
//! 4. **Mode/tile preconditions** — QuantI8 implies packed panels, a
//!    lane-paddable `u`, and baked `i8` panels present; tiles are the
//!    clamped shapes the kernels expect; placement working-set costs
//!    are present when affinity-weighted dispatch is on.
//!
//! What stays dynamic-only: **bitwise parity** (the numeric oracle
//! suites) — the verifier proves memory/layout safety, not numerics.
//! Violations surface as typed [`Error::Verify`] naming the step,
//! layer, and rule.
//!
//! ## Staged execution
//!
//! A schedule that places layers on more than one backend
//! ([`crate::engine::schedule::Schedule::is_staged`]) still compiles to
//! **one** flat plan here — staging is a view over it, built by
//! [`crate::engine::hetero::StagedPlan::from_plan`]:
//!
//! * **Stages** are contiguous step ranges cut at backend boundaries.
//!   Each stage runs end to end on one backend's executor
//!   ([`crate::runtime::backends::StageExecutor`]); structural steps
//!   (input prologue, reorders, pools) inherit the stage of the
//!   parameterised layer they follow.
//! * **Transfers** ([`Step::Transfer`]) are the only cross-stage data
//!   path: at each cut, every register a later stage reads is copied
//!   into a fresh *wire* register by a `Transfer` appended to the
//!   producing stage, and all downstream reads are remapped to the
//!   wire. Layout changes at a cut are ordinary [`Step::Reorder`] steps
//!   lowered *before* the transfer, so a `Transfer` is always a
//!   same-shape row copy — bitwise invisible. The stage-cut rules are
//!   proved statically by
//!   [`crate::engine::verify::verify_stage_cuts`]: every cross-stage
//!   def crosses through exactly one Transfer, and no stage reads
//!   another stage's arena registers directly.
//! * **Queues**: the pipelined executor
//!   ([`crate::engine::hetero::Pipeline`]) gives each stage a worker
//!   thread with its own arena clone, linked by bounded channels that
//!   carry only the wire registers' live rows. Submitting past the
//!   queue bound **backpressures** (blocks the producer); consecutive
//!   batches overlap across stages (batch *i* on stage 2 while batch
//!   *i + 1* runs stage 1) while results return strictly in submission
//!   order. Shutdown is **lossless**: dropping the pipeline closes the
//!   feed, drains every in-flight batch through all stages, then joins
//!   the workers — an accepted batch is never discarded.
//!
//! Per-row numerics are stage-count independent — a staged walk of the
//! same plan is bitwise identical to the single-backend walk
//! (`rust/tests/hetero.rs` holds that parity to the oracles).

use std::ops::Range;
use std::sync::Arc;

use crate::engine::conv::{self, ConvTiling};
use crate::engine::mode::{self, ArithMode};
use crate::engine::network::{EngineParams, ExecConfig, ModeAssignment};
use crate::engine::ops;
use crate::engine::parallel::{self, Parallelism};
use crate::engine::schedule::{LayerSchedule, PoolSettings, Schedule};
use crate::engine::tensor;
use crate::layout;
use crate::metrics::AllocCounter;
use crate::model::{shapes, Layer, LayerOp, Network};
use crate::util::ceil_div;
use crate::util::error::{Error, Result};

/// Row-major conv implementation a non-OLP layer lowers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum NchwConv {
    Scalar,
    Flp,
    Klp,
}

/// The stable step-kind vocabulary — **one** name per step kind, shared
/// by every subsystem that addresses steps: fault-injection sites
/// (`CAPPUCCINO_FAULTS=panic:conv:0.01` addresses every conv step, see
/// [`crate::faults`]), the label fallback in
/// [`crate::Error::TaskPanicked`], and the step names in
/// [`crate::Error::Verify`] diagnostics. Panic reports, chaos specs,
/// and verifier findings therefore always agree on what a step is
/// called.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepKind {
    Input,
    Conv,
    MaxPool,
    AvgPool,
    Lrn,
    Gap,
    Copy,
    Concat,
    Dense,
    Softmax,
    Reorder,
    Transfer,
}

impl StepKind {
    /// The wire name — what fault specs match on and error messages
    /// print.
    pub fn as_str(self) -> &'static str {
        match self {
            StepKind::Input => "input",
            StepKind::Conv => "conv",
            StepKind::MaxPool => "maxpool",
            StepKind::AvgPool => "avgpool",
            StepKind::Lrn => "lrn",
            StepKind::Gap => "gap",
            StepKind::Copy => "copy",
            StepKind::Concat => "concat",
            StepKind::Dense => "dense",
            StepKind::Softmax => "softmax",
            StepKind::Reorder => "reorder",
            StepKind::Transfer => "transfer",
        }
    }
}

impl std::fmt::Display for StepKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Static shape of one activation register (one batch row; the arena
/// allocates `B` rows per register).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SlotShape {
    /// Map-major `(ceil(c/u), h, w, u)` data; `u = 1` is row-major NCHW.
    Maps { c: usize, h: usize, w: usize, u: usize },
    Flat { len: usize },
}

impl SlotShape {
    pub(crate) fn len(&self) -> usize {
        match *self {
            SlotShape::Maps { c, h, w, u } => ceil_div(c, u) * h * w * u,
            SlotShape::Flat { len } => len,
        }
    }
}

fn maps_of(s: SlotShape) -> (usize, usize, usize, usize) {
    match s {
        SlotShape::Maps { c, h, w, u } => (c, h, w, u),
        SlotShape::Flat { .. } => unreachable!("plan step expected a maps register"),
    }
}

fn flat_of(s: SlotShape) -> usize {
    match s {
        SlotShape::Flat { len } => len,
        SlotShape::Maps { .. } => unreachable!("plan step expected a flat register"),
    }
}

/// Symmetric int8 weight panels of one [`ArithMode::QuantI8`] layer:
/// the quantized panel data plus the per-layer weight scale, both baked
/// at plan compile (`scale = amax/127`, zero-point 0).
pub(crate) struct QuantPanels {
    pub(crate) data: Vec<i8>,
    pub(crate) scale: f32,
}

/// One lowered instruction. Weights are baked (mode-cast at compile
/// time) and shared via `Arc` so cloning a plan — or deriving a sibling
/// capacity with [`ExecutionPlan::with_capacity`] — does not duplicate
/// parameters.
#[derive(Clone)]
pub(crate) enum Step {
    /// Prologue: conventional NCHW request rows into the input register.
    Input { dst: usize },
    ConvMm {
        src: usize,
        dst: usize,
        /// Packed tap-major panels when `packed`, else the unpacked
        /// `(Mb, u, Cb, K, K, u)` layout (ablation reference).
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
        mode: ArithMode,
        packed: bool,
        /// Run the SIMD row kernels (packed, vectorised f32 modes with
        /// no per-layer scalar override). Bitwise invisible.
        vec: bool,
        /// Present iff `mode` is [`ArithMode::QuantI8`]: the int8
        /// panels + weight scale (`w` is then empty).
        quant: Option<Arc<QuantPanels>>,
        /// Row-tile macro-kernel sizes (ignored by the unpacked core).
        tile: ConvTiling,
        /// Per-tile working-set bytes when cost-weighted cluster
        /// placement is on ([`PlanBuilder::affinity`]); `None` keeps
        /// the plain chunked dispatch.
        place: Option<usize>,
    },
    ConvNchw {
        src: usize,
        dst: usize,
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
        mode: ArithMode,
        policy: NchwConv,
    },
    PoolMm { src: usize, dst: usize, k: usize, s: usize, p: usize, is_max: bool },
    PoolNchw { src: usize, dst: usize, k: usize, s: usize, p: usize, is_max: bool },
    Lrn { src: usize, dst: usize, size: usize, alpha: f32, beta: f32 },
    Gap { src: usize, dst: usize },
    Copy { src: usize, dst: usize },
    Concat { srcs: Vec<usize>, dst: usize },
    Dense {
        src: usize,
        dst: usize,
        /// Column-blocked panels when `packed`, else row-major `(O, I)`.
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        relu: bool,
        mode: ArithMode,
        packed: bool,
        /// Run the SIMD column-block kernel (packed, vectorised f32
        /// modes with no per-layer scalar override). Bitwise invisible.
        vec: bool,
        /// Present iff `mode` is [`ArithMode::QuantI8`]: the int8
        /// panels + weight scale (`w` is then empty).
        quant: Option<Arc<QuantPanels>>,
    },
    Softmax { src: usize, dst: usize },
    /// Exact layout change between map-major widths (`u = 1` is
    /// row-major NCHW) at a heterogeneous-parallelism boundary. A pure
    /// permutation: bitwise invisible to every surrounding kernel.
    Reorder { src: usize, dst: usize },
    /// Cross-stage buffer handoff at a backend boundary (staged plans
    /// only — see the *Staged execution* section above): copies the
    /// live rows of `src` into the wire register `dst`, which is the
    /// only register a later stage may read. Shapes are identical by
    /// construction (layout changes at a cut are separate [`Step::Reorder`]
    /// steps), so a transfer is bitwise invisible.
    Transfer { src: usize, dst: usize },
}

impl Step {
    /// This step's [`StepKind`] — the fault-injection site it checks on
    /// the chaos path, the label fallback in
    /// [`crate::Error::TaskPanicked`], and the name
    /// [`crate::Error::Verify`] diagnostics print.
    pub(crate) fn kind(&self) -> StepKind {
        match self {
            Step::Input { .. } => StepKind::Input,
            Step::ConvMm { .. } | Step::ConvNchw { .. } => StepKind::Conv,
            Step::PoolMm { is_max, .. } | Step::PoolNchw { is_max, .. } => {
                if *is_max {
                    StepKind::MaxPool
                } else {
                    StepKind::AvgPool
                }
            }
            Step::Lrn { .. } => StepKind::Lrn,
            Step::Gap { .. } => StepKind::Gap,
            Step::Copy { .. } => StepKind::Copy,
            Step::Concat { .. } => StepKind::Concat,
            Step::Dense { .. } => StepKind::Dense,
            Step::Softmax { .. } => StepKind::Softmax,
            Step::Reorder { .. } => StepKind::Reorder,
            Step::Transfer { .. } => StepKind::Transfer,
        }
    }
}

/// The preallocated buffer arena: activation registers and pad/cast
/// scratch sized `B x` one row, per-thread FLP/KLP reduction buffers,
/// and per-thread kernel scratch rows (the generic-`u` conv kernels'
/// tap block / accumulator tile — zero allocations per inference at any
/// `u`). Compile-time sized, reused across every batch.
#[derive(Clone)]
pub(crate) struct Arena {
    pub(crate) bufs: Vec<Vec<f32>>,
    pub(crate) scratch: Vec<f32>,
    /// Per-image quantized activation rows for QuantI8 steps (empty
    /// when the plan has none).
    pub(crate) qscratch: Vec<i8>,
    /// Per-image activation quantization scales (one per batch row).
    pub(crate) qscales: Vec<f32>,
    pub(crate) reduce: Vec<Vec<f32>>,
    pub(crate) thread_scratch: Vec<Vec<f32>>,
}

impl Arena {
    pub(crate) fn sized(
        slots: &[SlotShape],
        scratch_row: usize,
        qscratch_row: usize,
        reduce_len: usize,
        threads: usize,
        batch: usize,
        thread_scratch_row: usize,
    ) -> Arena {
        let bufs = slots.iter().map(|s| vec![0.0f32; batch * s.len()]).collect();
        let scratch = vec![0.0f32; batch * scratch_row];
        let qscratch = vec![0i8; batch * qscratch_row];
        let qscales = vec![1.0f32; if qscratch_row > 0 { batch } else { 0 }];
        let n_reduce = if reduce_len > 0 { threads } else { 0 };
        let reduce = (0..n_reduce).map(|_| vec![0.0f32; reduce_len]).collect();
        // One row per pool chunk; rows are empty (no allocation) when
        // every kernel runs its register fast path (u = 4 / NCHW).
        let thread_scratch = (0..threads)
            .map(|_| vec![0.0f32; thread_scratch_row])
            .collect();
        Arena { bufs, scratch, qscratch, qscales, reduce, thread_scratch }
    }

    fn bytes(&self) -> usize {
        let elems: usize = self.bufs.iter().map(|b| b.len()).sum::<usize>()
            + self.scratch.len()
            + self.qscales.len()
            + self.reduce.iter().map(|b| b.len()).sum::<usize>()
            + self.thread_scratch.iter().map(|b| b.len()).sum::<usize>();
        4 * elems + self.qscratch.len()
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Fluent constructor for [`ExecutionPlan`] — the single entry point to
/// plan compilation (it replaced the old `compile` / `compile_baseline`
/// / `compile_policy` trio).
///
/// Defaults: map-major OLP family, all-precise modes, 1 thread, batch
/// capacity 1.
///
/// ```
/// use cappuccino::engine::{ArithMode, EngineParams, ModeAssignment, PlanBuilder};
/// use cappuccino::model::zoo;
///
/// let net = zoo::tinynet();
/// let params = EngineParams::random(&net, 1, 4).unwrap();
/// let mut plan = PlanBuilder::new(&net, &params)
///     .modes(&ModeAssignment::uniform(ArithMode::Imprecise))
///     .threads(2)
///     .batch(4)
///     .build()
///     .unwrap();
/// let img = vec![0.0f32; plan.input_len()];
/// let rows = plan.run_batch(&[&img[..], &img[..], &img[..]]).unwrap(); // 3 live rows
/// assert_eq!(rows.len(), 3);
/// ```
pub struct PlanBuilder<'a> {
    net: &'a Network,
    params: &'a EngineParams,
    modes: ModeAssignment,
    cfg: ExecConfig,
    policy: Parallelism,
    baseline: bool,
    batch: usize,
    packing: bool,
    tiling: Option<ConvTiling>,
    schedule: Option<Schedule>,
}

impl<'a> PlanBuilder<'a> {
    /// Start a builder for the map-major OLP family (the synthesized
    /// program), all layers precise, 1 thread, batch capacity 1.
    pub fn new(net: &'a Network, params: &'a EngineParams) -> PlanBuilder<'a> {
        PlanBuilder {
            net,
            params,
            modes: ModeAssignment::uniform(ArithMode::Precise),
            cfg: ExecConfig::default(),
            policy: Parallelism::Olp,
            baseline: false,
            batch: 1,
            packing: true,
            tiling: None,
            schedule: None,
        }
    }

    /// Per-layer arithmetic mode assignment (section IV.C).
    pub fn modes(mut self, modes: &ModeAssignment) -> Self {
        self.modes = modes.clone();
        self
    }

    /// Full execution config (currently: thread count).
    pub fn config(mut self, cfg: ExecConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Pool-chunk parallelism per parallel region.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Cost-weighted cluster placement (default **off**). When on — and
    /// the process pool spans more than one core cluster
    /// (big.LITTLE/multi-socket; see [`crate::engine::Topology`]) —
    /// each packed conv layer's macro items are split across clusters
    /// by per-cluster throughput weights, using the layer's
    /// [`ConvTiling`] working-set cost to decide compute- vs
    /// memory-bound weighting, and each chunk is submitted to its
    /// cluster's own work deque. Placement moves work between cores,
    /// never changes what is computed: output is bitwise identical with
    /// affinity on or off. Requires packing (the unpacked row-walk
    /// ablation plan ignores it); single-cluster hosts fall back to the
    /// plain dispatch at execution time.
    pub fn affinity(mut self, on: bool) -> Self {
        self.cfg.affinity = on;
        self
    }

    /// Batch capacity `B`: arena registers are sized `B x` and
    /// [`ExecutionPlan::run_batch`] accepts up to `B` images per walk.
    /// `batch(0)` is rejected at [`PlanBuilder::build`] with
    /// [`Error::Config`].
    pub fn batch(mut self, capacity: usize) -> Self {
        self.batch = capacity;
        self
    }

    /// Compile from an explicit (possibly heterogeneous) [`Schedule`]
    /// instead of the fluent setters: parallelism, packing, tiling,
    /// mode, and placement are honored **per layer**, and
    /// `pool.threads` / `pool.affinity` replace `.config()`. When a
    /// schedule is set, `.modes/.policy/.packing/.tiling/.config/`
    /// `.threads/.affinity` are ignored — the schedule *is* the whole
    /// tuning surface; only [`PlanBuilder::batch`] and
    /// [`PlanBuilder::baseline`] still apply. The schedule is validated
    /// against the network and parameter width at build
    /// ([`Schedule::validate_for`]).
    pub fn schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = Some(schedule);
        self
    }

    /// Weight packing on/off (default **on**). `packing(false)` keeps
    /// conv weights in the unpacked `(Mb, u, Cb, K, K, u)` layout and
    /// dense weights row-major, executed by the plain row-walk cores —
    /// exactly the pre-packing plan, kept so the ablation bench can
    /// isolate the packed-panel + tiling win. Output is bitwise
    /// identical either way.
    pub fn packing(mut self, on: bool) -> Self {
        self.packing = on;
        self
    }

    /// Override the per-layer tile cost model with fixed row-tile sizes
    /// (clamped per layer to its `Mb x Ho` grid). For the tiling
    /// ablation: `ConvTiling { tm: 1, th: 1 }` is the plain row-walk
    /// order. Ignored by `packing(false)` plans and non-conv steps.
    pub fn tiling(mut self, tile: ConvTiling) -> Self {
        self.tiling = Some(tile);
        self
    }

    /// Uniform thread-workload-allocation policy: OLP lowers map-major
    /// (the default), FLP/KLP lower row-major with per-thread reduction
    /// buffers in the arena — the section IV.A ablation executors.
    /// Per-layer mixtures go through [`PlanBuilder::schedule`].
    pub fn policy(mut self, policy: Parallelism) -> Self {
        self.policy = policy;
        self.baseline = false;
        self
    }

    /// The single-threaded scalar row-major baseline (Table I's
    /// "single-threaded Java" program, functionally). Selects the
    /// scalar family; [`PlanBuilder::build`] then pins precise modes
    /// and one thread for that family, so `.modes(..)`/`.threads(..)`
    /// in any order cannot subvert the baseline's contract. (Like any
    /// family selection, a *later* [`PlanBuilder::policy`] call
    /// supersedes it — last family choice wins.)
    pub fn baseline(mut self) -> Self {
        self.baseline = true;
        self
    }

    /// Compile: schedule normalization (the fluent setters lower into a
    /// uniform [`Schedule`] — the one path into compilation), shape
    /// inference, lowering, weight baking, arena sizing. Degenerate
    /// configurations surface here as [`Error::Config`].
    pub fn build(self) -> Result<ExecutionPlan> {
        if self.batch == 0 {
            return Err(Error::Config(
                "batch capacity 0: a plan must hold at least one image per walk".into(),
            ));
        }
        let (schedule, baseline) = if self.baseline {
            // The scalar-baseline family pins precise arithmetic and one
            // thread regardless of the order builder methods were
            // called in.
            (
                Schedule::from_uniform(
                    self.net,
                    self.params.u,
                    &ModeAssignment::uniform(ArithMode::Precise),
                    Parallelism::Olp,
                    self.packing,
                    None,
                    PoolSettings::default(),
                )?,
                true,
            )
        } else if let Some(s) = self.schedule {
            s.validate_for(self.net, self.params.u)?;
            (s, false)
        } else {
            (
                Schedule::from_uniform(
                    self.net,
                    self.params.u,
                    &self.modes,
                    self.policy,
                    self.packing,
                    self.tiling,
                    PoolSettings {
                        threads: self.cfg.threads,
                        affinity: self.cfg.affinity,
                        cores: None,
                    },
                )?,
                false,
            )
        };
        ExecutionPlan::compile_with(self.net, self.params, schedule, baseline, self.batch)
    }
}

/// A compiled, immediately executable inference program for the native
/// engine. Holds baked weights and a resident buffer arena sized for a
/// fixed batch capacity; `run_batch` executes a dynamic batch in one
/// walk, allocation-free apart from the returned logits rows.
#[derive(Clone)]
pub struct ExecutionPlan {
    pub(crate) u: usize,
    pub(crate) threads: usize,
    pub(crate) batch: usize,
    /// The (normalized) schedule this plan was compiled from — the
    /// exportable tuning surface ([`ExecutionPlan::schedule`]).
    pub(crate) sched: Schedule,
    pub(crate) input_shape: (usize, usize, usize),
    pub(crate) slots: Vec<SlotShape>,
    pub(crate) steps: Vec<Step>,
    /// One label per step (`layer name` for lowered layers, the step
    /// kind for structural steps) — the `layer` field of
    /// [`Error::TaskPanicked`] when a contained panic is surfaced.
    pub(crate) labels: Vec<String>,
    pub(crate) out_slot: usize,
    pub(crate) arena: Arena,
    /// Per-row pad/cast scratch length (row stride into `arena.scratch`).
    pub(crate) scratch_row: usize,
    /// Per-row i8 quantization scratch length (0 = no QuantI8 steps).
    pub(crate) qscratch_row: usize,
    /// Per-thread FLP/KLP reduction buffer length (0 = none needed).
    pub(crate) reduce_len: usize,
    /// Per-thread kernel scratch row length (0 = register fast paths).
    pub(crate) thread_scratch_row: usize,
    baked_param_bytes: usize,
    runs: u64,
    alloc: AllocCounter,
}

impl std::fmt::Debug for ExecutionPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecutionPlan")
            .field("u", &self.u)
            .field("threads", &self.threads)
            .field("batch", &self.batch)
            .field("steps", &self.steps.len())
            .field("registers", &self.slots.len())
            .field("arena_bytes", &self.arena.bytes())
            .field("baked_param_bytes", &self.baked_param_bytes)
            .field("runs", &self.runs)
            .finish()
    }
}

impl ExecutionPlan {
    fn compile_with(
        net: &Network,
        params: &EngineParams,
        schedule: Schedule,
        baseline: bool,
        batch: usize,
    ) -> Result<ExecutionPlan> {
        debug_assert!(batch >= 1 && schedule.pool.threads >= 1, "builder validates");
        // Shape inference once, up front: every undersized window or
        // malformed topology becomes Error::Shape here instead of an
        // arithmetic underflow on the request path.
        shapes::infer(net)?;
        let (c, h, w) = net.input.as_maps()?;
        // A plan whose every layer lowers row-major (FLP/KLP uniform, or
        // the scalar baseline) runs u = 1 end to end; any OLP layer
        // makes the plan map-major at the parameter width, with exact
        // reorder steps at row-major boundaries. When the *first* conv
        // is scheduled row-major the input also starts row-major — never
        // pay a map-major input transform just to reorder it straight
        // back before the first layer.
        let nchw_start =
            baseline || schedule.all_rowmajor() || first_conv_is_rowmajor(net, &schedule);
        let u = if nchw_start { 1 } else { params.u };
        let threads = schedule.pool.threads;
        let mut lw = Lowerer {
            params,
            schedule: &schedule,
            baseline,
            mm_u: params.u,
            nchw_ctx: nchw_start,
            flat_mm: false,
            slots: Vec::new(),
            steps: Vec::new(),
            labels: Vec::new(),
            scratch_len: 0,
            qscratch_len: 0,
            reduce_len: 0,
            thread_scratch_row: 0,
            baked_param_bytes: 0,
        };
        let in_slot = lw.slot(SlotShape::Maps { c, h, w, u });
        lw.push(None, Step::Input { dst: in_slot });
        let out_slot = lw.lower(&net.layers, in_slot)?;
        // End the lowerer's borrow of the schedule before moving it
        // into the plan.
        let Lowerer {
            slots,
            steps,
            labels,
            scratch_len,
            qscratch_len,
            reduce_len,
            thread_scratch_row,
            baked_param_bytes,
            ..
        } = lw;

        let arena = Arena::sized(
            &slots,
            scratch_len,
            qscratch_len,
            reduce_len,
            threads,
            batch,
            thread_scratch_row,
        );
        let plan = ExecutionPlan {
            u,
            threads,
            batch,
            sched: schedule,
            input_shape: (c, h, w),
            slots,
            steps,
            labels,
            out_slot,
            arena,
            scratch_row: scratch_len,
            qscratch_row: qscratch_len,
            reduce_len,
            thread_scratch_row,
            baked_param_bytes,
            runs: 0,
            alloc: AllocCounter::new(),
        };
        // Static verification at build time: always in debug builds
        // (so every plan the test suite compiles is proved race-free,
        // layout-sound, and arena-safe), opt-in for release builds via
        // CAPPUCCINO_VERIFY=1 (the `check` subcommand and the autotuner
        // call `verify()` explicitly instead).
        if cfg!(debug_assertions) || std::env::var_os("CAPPUCCINO_VERIFY").is_some_and(|v| v == "1")
        {
            plan.verify()?;
        }
        Ok(plan)
    }

    /// Run the static plan verifier ([`crate::engine::verify`]) over
    /// this plan: race-freedom of every parallel region, def-before-use
    /// and layout consistency of the register file, arena extents at
    /// this capacity, and mode/tile preconditions. `Ok(())` means the
    /// plan is proved safe to execute at any live batch `1..=B`.
    pub fn verify(&self) -> Result<()> {
        crate::engine::verify::verify_plan(self)
    }

    /// Test-only corruption hook for the verifier mutation suite: apply
    /// `m` to this plan in place, returning `false` when the plan has
    /// no site the mutation applies to. Never used on a plan that is
    /// subsequently executed.
    #[doc(hidden)]
    pub fn apply_mutation(&mut self, m: crate::engine::verify::PlanMutation) -> bool {
        crate::engine::verify::apply_mutation(self, m)
    }

    /// Derive a sibling plan with a different batch capacity. The step
    /// sequence and baked weights are **shared** (`Arc` — parameters
    /// are never duplicated per capacity); only the arena is re-sized.
    /// Run counters start fresh on the derived plan.
    pub fn with_capacity(&self, batch: usize) -> ExecutionPlan {
        let batch = batch.max(1);
        let plan = ExecutionPlan {
            u: self.u,
            threads: self.threads,
            batch,
            sched: self.sched.clone(),
            input_shape: self.input_shape,
            slots: self.slots.clone(),
            steps: self.steps.clone(),
            labels: self.labels.clone(),
            out_slot: self.out_slot,
            arena: Arena::sized(
                &self.slots,
                self.scratch_row,
                self.qscratch_row,
                self.reduce_len,
                self.threads,
                batch,
                self.thread_scratch_row,
            ),
            scratch_row: self.scratch_row,
            qscratch_row: self.qscratch_row,
            reduce_len: self.reduce_len,
            thread_scratch_row: self.thread_scratch_row,
            baked_param_bytes: self.baked_param_bytes,
            runs: 0,
            alloc: AllocCounter::new(),
        };
        // Re-prove the derived plan in debug builds: capacity
        // derivation re-sizes the arena, and the verifier's arena rule
        // is exactly the guard against a silently under-sized sibling.
        #[cfg(debug_assertions)]
        if let Err(e) = plan.verify() {
            panic!("with_capacity({batch}) produced an unsound sibling plan: {e}");
        }
        plan
    }

    /// Derive a sibling plan with a **rewritten step sequence** — the
    /// staged-plan partitioner's constructor
    /// ([`crate::engine::hetero::StagedPlan::from_plan`] appends
    /// [`Step::Transfer`] wires and remaps reads). Baked weights stay
    /// shared (the steps carry their `Arc`s); the arena is re-sized for
    /// the (possibly grown) register file; counters start fresh. The
    /// caller is responsible for re-verifying — the partitioner does.
    pub(crate) fn with_steps(
        &self,
        slots: Vec<SlotShape>,
        steps: Vec<Step>,
        labels: Vec<String>,
        out_slot: usize,
    ) -> ExecutionPlan {
        debug_assert_eq!(steps.len(), labels.len(), "one label per step");
        let arena = Arena::sized(
            &slots,
            self.scratch_row,
            self.qscratch_row,
            self.reduce_len,
            self.threads,
            self.batch,
            self.thread_scratch_row,
        );
        ExecutionPlan {
            u: self.u,
            threads: self.threads,
            batch: self.batch,
            sched: self.sched.clone(),
            input_shape: self.input_shape,
            slots,
            steps,
            labels,
            out_slot,
            arena,
            scratch_row: self.scratch_row,
            qscratch_row: self.qscratch_row,
            reduce_len: self.reduce_len,
            thread_scratch_row: self.thread_scratch_row,
            baked_param_bytes: self.baked_param_bytes,
            runs: 0,
            alloc: AllocCounter::new(),
        }
    }

    pub(crate) fn validate_batch(&self, images: &[&[f32]]) -> Result<()> {
        if images.len() > self.batch {
            return Err(Error::Invalid(format!(
                "batch of {} exceeds plan capacity {}",
                images.len(),
                self.batch
            )));
        }
        let (c, h, w) = self.input_shape;
        for (i, img) in images.iter().enumerate() {
            if img.len() != c * h * w {
                return Err(Error::Shape(format!(
                    "batch row {i}: input len {} vs expected {c}x{h}x{w}",
                    img.len()
                )));
            }
        }
        Ok(())
    }

    /// One walk of the step sequence over `images.len()` live rows.
    ///
    /// Every step runs under `catch_unwind`, and the pool's contained
    /// -panic flag is drained after each step, so a panic anywhere in a
    /// step — inline in this thread or inside any pool task — surfaces
    /// as a typed [`Error::TaskPanicked`] naming the step and layer
    /// instead of unwinding through the caller. The arena is left with
    /// partial data on the fault path, which is safe: the next walk
    /// rewrites every register from the input prologue on. The
    /// non-fault path is byte-for-byte the old walk (the injection
    /// check is one relaxed atomic load when chaos is off).
    fn exec(&mut self, images: &[&[f32]]) -> Result<()> {
        self.exec_range(images, images.len(), 0..self.steps.len())?;
        self.runs += images.len() as u64;
        Ok(())
    }

    /// Execute the steps in `range` (absolute indices) over `live` batch
    /// rows — the stage-granular walk staged execution is built from
    /// ([`crate::engine::hetero`]). `images` feeds [`Step::Input`]
    /// prologue steps only; a later stage's range has none and passes
    /// `&[]` with the batch's live count. Fault-injection and
    /// panic-containment semantics are per step, exactly as in a full
    /// walk; the run counter is **not** advanced (a batch counts once,
    /// in [`ExecutionPlan::run_batch`], however many stages walk it).
    pub(crate) fn exec_range(
        &mut self,
        images: &[&[f32]],
        live: usize,
        range: Range<usize>,
    ) -> Result<()> {
        // Drain any stale flag so step `i` is never blamed for an
        // earlier walk's contained panic.
        parallel::take_scope_panic();
        let slots = &self.slots;
        let arena = &mut self.arena;
        let (threads, scratch_row, qscratch_row) =
            (self.threads, self.scratch_row, self.qscratch_row);
        for i in range {
            let step = &self.steps[i];
            let injected = crate::faults::check(step.kind().as_str());
            if injected == Some(crate::faults::FaultKind::Err) {
                return Err(Error::Serve(format!(
                    "injected error at plan step {i} ({})",
                    self.labels[i]
                )));
            }
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if injected == Some(crate::faults::FaultKind::Panic) {
                    panic!("injected fault at plan step {i}");
                }
                exec_step(
                    step, slots, &mut *arena, images, live, threads, scratch_row, qscratch_row,
                );
            }))
            .is_err();
            if caught || parallel::take_scope_panic() {
                return Err(Error::TaskPanicked { step: i, layer: self.labels[i].clone() });
            }
        }
        Ok(())
    }

    /// Copy live row `row` of the output register into `out`
    /// (conventional NCHW order, padding lanes dropped).
    pub(crate) fn extract_row_into(&self, row: usize, out: &mut [f32]) {
        let slot_len = self.slots[self.out_slot].len();
        let data = &self.arena.bufs[self.out_slot][row * slot_len..(row + 1) * slot_len];
        match self.slots[self.out_slot] {
            SlotShape::Flat { .. } => out.copy_from_slice(data),
            SlotShape::Maps { c, h, w, u } => {
                layout::mapmajor_to_nchw_into(data, c, h, w, u, out)
            }
        }
    }

    /// Execute a dynamic batch (`images.len() <= capacity`) as **one**
    /// plan walk; returns one logits row per input image, in order.
    /// Each image is conventional `(C, H, W)` data; the map-major
    /// transform of every live row is the plan's prologue (the only
    /// dynamic reorder in the pipeline). Only live rows are computed —
    /// a partial batch never touches (or reads back) padded lanes.
    /// Bitwise identical to `images.len()` single-image [`ExecutionPlan::run`]
    /// calls. Steady-state allocation-free apart from the returned rows.
    pub fn run_batch(&mut self, images: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.validate_batch(images)?;
        if images.is_empty() {
            return Ok(Vec::new());
        }
        self.exec(images)?;
        let out_len = self.output_len();
        let mut rows = Vec::with_capacity(images.len());
        for r in 0..images.len() {
            let mut row = vec![0.0f32; out_len];
            self.extract_row_into(r, &mut row);
            rows.push(row);
        }
        self.alloc.record(4 * out_len * images.len());
        Ok(rows)
    }

    /// [`ExecutionPlan::run_batch`] into caller-owned output rows:
    /// `out` is `images.len() * output_len()` floats, row-major. Zero
    /// plan-side allocation — the fully arena-resident request path.
    pub fn run_batch_into(&mut self, images: &[&[f32]], out: &mut [f32]) -> Result<()> {
        self.validate_batch(images)?;
        let out_len = self.output_len();
        if out.len() != images.len() * out_len {
            return Err(Error::Shape(format!(
                "output buffer len {} vs expected {} ({} rows x {out_len})",
                out.len(),
                images.len() * out_len,
                images.len()
            )));
        }
        if images.is_empty() {
            return Ok(());
        }
        self.exec(images)?;
        for r in 0..images.len() {
            self.extract_row_into(r, &mut out[r * out_len..(r + 1) * out_len]);
        }
        Ok(())
    }

    /// Single-image inference — the thin `B = 1` wrapper over
    /// [`ExecutionPlan::run_batch`].
    pub fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let mut rows = self.run_batch(&[input])?;
        Ok(rows.pop().expect("batch of one yields one row"))
    }

    /// Vector width the plan was compiled for (1 for row-major plans).
    pub fn u(&self) -> usize {
        self.u
    }

    /// The normalized [`Schedule`] this plan was compiled from — fluent
    /// setters and explicit schedules converge here, so exporting it
    /// (`to_json`), reloading, and rebuilding via
    /// [`PlanBuilder::schedule`] reproduces this plan bitwise. (Baseline
    /// plans record their pinned uniform precise schedule; the scalar
    /// family itself is not a schedule knob.)
    pub fn schedule(&self) -> &Schedule {
        &self.sched
    }

    /// Pool-chunk parallelism the plan executes with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Batch capacity `B` the arena is sized for.
    pub fn capacity(&self) -> usize {
        self.batch
    }

    /// Expected per-image input element count.
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Per-image logits row length.
    pub fn output_len(&self) -> usize {
        match self.slots[self.out_slot] {
            SlotShape::Flat { len } => len,
            SlotShape::Maps { c, h, w, .. } => c * h * w,
        }
    }

    /// Lowered step count (prologue included).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// The lowered step-kind sequence, in walk order — the observable
    /// shape of the compiled program, exposed so tests can assert
    /// step-sequence equality (e.g. a degenerate single-stage plan is
    /// exactly the non-staged lowering). Kinds, not steps: weights and
    /// register indices stay internal.
    #[doc(hidden)]
    pub fn step_kinds(&self) -> Vec<StepKind> {
        self.steps.iter().map(|s| s.kind()).collect()
    }

    /// Resident arena bytes (activation registers + scratch + reduction
    /// buffers, all batch rows) — what the legacy executor re-allocated
    /// every inference.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Bytes of baked (mode-cast) parameters the plan holds — what the
    /// legacy executor re-cast every inference for inexact layers.
    /// Shared (not duplicated) across [`ExecutionPlan::with_capacity`]
    /// siblings.
    pub fn baked_param_bytes(&self) -> usize {
        self.baked_param_bytes
    }

    /// Images inferred so far (every live batch row counts).
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Request-path allocation meter (logits rows only, by design).
    pub fn alloc(&self) -> &AllocCounter {
        &self.alloc
    }

    /// Mean request-path bytes allocated per image.
    pub fn alloc_bytes_per_run(&self) -> f64 {
        self.alloc.per_inference(self.runs)
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

/// Is the first conv layer (in lowering order) scheduled row-major
/// (FLP/KLP)? Decides the input register's starting layout for mixed
/// plans; `false` for conv-free nets.
fn first_conv_is_rowmajor(net: &Network, schedule: &Schedule) -> bool {
    let mut first: Option<bool> = None;
    net.visit(&mut |l| {
        if first.is_none() {
            if let LayerOp::Conv { .. } = l.op {
                let rm = schedule
                    .layers
                    .get(&l.name)
                    .is_some_and(|ls| ls.parallelism != Parallelism::Olp);
                first = Some(rm);
            }
        }
    });
    first.unwrap_or(false)
}

struct Lowerer<'a> {
    params: &'a EngineParams,
    /// Per-layer tuning surface (validated against the net upstream).
    schedule: &'a Schedule,
    /// Scalar-baseline plans force every conv to the scalar row-major
    /// kernel regardless of the schedule's parallelism.
    baseline: bool,
    /// Map-major vector width OLP layers run at (`params.u`).
    mm_u: usize,
    /// Is the current activation in row-major (FLP/KLP/baseline)
    /// context? Decides which kernels non-parameterised layers lower to
    /// and whether a flat activation carries map-major flatten order.
    nchw_ctx: bool,
    /// Did the most recent flatten/gap consume a map-major activation?
    /// (Picks the permuted `w_mm` vs conventional `w_conv` dense
    /// weights.)
    flat_mm: bool,
    slots: Vec<SlotShape>,
    steps: Vec<Step>,
    /// Parallel to `steps`: the layer name each step lowered from
    /// (step kind for structural steps) — fault-report labels.
    labels: Vec<String>,
    scratch_len: usize,
    /// Per-row i8 activation scratch (max over QuantI8 layers; 0 = none).
    qscratch_len: usize,
    reduce_len: usize,
    thread_scratch_row: usize,
    baked_param_bytes: usize,
}

impl Lowerer<'_> {
    fn slot(&mut self, shape: SlotShape) -> usize {
        self.slots.push(shape);
        self.slots.len() - 1
    }

    /// Append a step with its label (the lowered layer's name, or the
    /// step kind when no layer is in scope — input prologue, reorders).
    fn push(&mut self, layer: Option<&str>, step: Step) {
        self.labels.push(match layer {
            Some(name) => name.to_string(),
            None => step.kind().to_string(),
        });
        self.steps.push(step);
    }

    /// The schedule entry for a parameterised layer (guaranteed present
    /// by [`Schedule::validate_for`] / [`Schedule::from_uniform`]).
    fn layer_schedule(&self, name: &str) -> Result<LayerSchedule> {
        match self.schedule.layers.get(name) {
            Some(ls) => Ok(*ls),
            None => Err(Error::Config(format!("schedule has no entry for layer {name:?}"))),
        }
    }

    /// Ensure the activation in `cur` has map-major width `target`
    /// (`1` = row-major NCHW), inserting exact layout-reorder steps at
    /// heterogeneous-parallelism boundaries. Scheduled targets are
    /// always `1` or the plan's map-major width; a hypothetical
    /// wide-to-wide change goes through a row-major intermediate so the
    /// executor only ever performs single-sided permutations.
    fn ensure_u(&mut self, cur: usize, layer: &Layer, target: usize) -> Result<usize> {
        let (c, h, w, u) = self.require_maps(cur, layer)?;
        if u == target {
            return Ok(cur);
        }
        let mut src = cur;
        if u != 1 && target != 1 {
            let mid = self.slot(SlotShape::Maps { c, h, w, u: 1 });
            self.push(Some(&layer.name), Step::Reorder { src, dst: mid });
            src = mid;
        }
        let dst = self.slot(SlotShape::Maps { c, h, w, u: target });
        self.push(Some(&layer.name), Step::Reorder { src, dst });
        Ok(dst)
    }

    fn bake(&mut self, w: &[f32], mode: ArithMode) -> Arc<Vec<f32>> {
        self.baked_param_bytes += 4 * w.len();
        Arc::new(conv::cast_weights(w, mode))
    }

    /// Bake + repack conv weights into tap-major panels. Mode-cast is
    /// elementwise and packing a permutation, so this equals casting the
    /// packed layout — packing cannot perturb numerics.
    fn bake_conv_panels(
        &mut self,
        w_mm: &[f32],
        mode: ArithMode,
        mb: usize,
        cb: usize,
        k: usize,
        u: usize,
    ) -> Arc<Vec<f32>> {
        self.baked_param_bytes += 4 * w_mm.len();
        let baked = conv::cast_weights(w_mm, mode);
        Arc::new(layout::pack_conv_panels(&baked, mb, cb, k, u))
    }

    /// Bake + repack dense weights into column-blocked panels.
    fn bake_dense_panels(
        &mut self,
        w: &[f32],
        mode: ArithMode,
        o: usize,
        len: usize,
    ) -> Arc<Vec<f32>> {
        let baked = conv::cast_weights(w, mode);
        let packed = layout::pack_dense_panels(&baked, o, len);
        self.baked_param_bytes += 4 * packed.len();
        Arc::new(packed)
    }

    /// Quantize + repack conv weights into symmetric int8 tap-major
    /// panels (QuantI8 layers); the per-layer weight scale rides along.
    fn bake_conv_panels_i8(
        &mut self,
        w_mm: &[f32],
        mb: usize,
        cb: usize,
        k: usize,
        u: usize,
    ) -> Arc<QuantPanels> {
        let (q, scale) = mode::quantize_symmetric(w_mm);
        let data = layout::pack_conv_panels_i8(&q, mb, cb, k, u);
        self.baked_param_bytes += data.len();
        Arc::new(QuantPanels { data, scale })
    }

    /// Quantize + repack dense weights into symmetric int8
    /// column-blocked panels (QuantI8 layers).
    fn bake_dense_panels_i8(&mut self, w: &[f32], o: usize, len: usize) -> Arc<QuantPanels> {
        let (q, scale) = mode::quantize_symmetric(w);
        let data = layout::pack_dense_panels_i8(&q, o, len);
        self.baked_param_bytes += data.len();
        Arc::new(QuantPanels { data, scale })
    }

    fn bias(&mut self, b: &[f32]) -> Arc<Vec<f32>> {
        self.baked_param_bytes += 4 * b.len();
        Arc::new(b.to_vec())
    }

    fn lower(&mut self, layers: &[Layer], mut cur: usize) -> Result<usize> {
        for layer in layers {
            cur = self.lower_layer(layer, cur)?;
        }
        Ok(cur)
    }

    fn lower_layer(&mut self, layer: &Layer, cur: usize) -> Result<usize> {
        let named = |e: Error| Error::Shape(format!("layer {}: {e}", layer.name));
        match &layer.op {
            LayerOp::Conv { m, k, s, p, relu } => {
                let ls = self.layer_schedule(&layer.name)?;
                // Per-layer family: OLP lowers map-major at the plan's
                // vector width; FLP/KLP (and the baseline's scalar)
                // lower row-major. An exact reorder step bridges
                // heterogeneous boundaries.
                let rowmajor = self.baseline || ls.parallelism != Parallelism::Olp;
                let quant = ls.mode.quantized();
                if quant && rowmajor {
                    return Err(Error::Config(format!(
                        "layer {}: quant_i8 lowers only through the packed map-major \
                         path — schedule it olp, not {}",
                        layer.name, ls.parallelism
                    )));
                }
                if quant && !ls.packing {
                    return Err(Error::Config(format!(
                        "layer {}: quant_i8 requires packing (the int8 panels are \
                         the packed layout)",
                        layer.name
                    )));
                }
                let cur = self.ensure_u(cur, layer, if rowmajor { 1 } else { self.mm_u })?;
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                if quant && !matches!(u, 1 | 2 | 4 | 8) {
                    return Err(Error::Config(format!(
                        "layer {}: quant_i8 needs a lane-paddable width — \
                         u must be 1, 2, 4 or 8, got {u}",
                        layer.name
                    )));
                }
                let ho = shapes::conv_out(h, *k, *s, *p).map_err(named)?;
                let wo = shapes::conv_out(w, *k, *s, *p).map_err(named)?;
                let lp = self.params.layer_params(&layer.name)?;
                let mode = ls.mode;
                let dst = self.slot(SlotShape::Maps { c: *m, h: ho, w: wo, u });
                if !rowmajor {
                    let (mb, cb) = (ceil_div(*m, u), ceil_div(c, u));
                    if lp.w_mm.len() != mb * u * cb * k * k * u || lp.b_mm.len() != mb * u {
                        return Err(Error::Shape(format!(
                            "layer {}: map-major params {}x{} vs expected {}x{}",
                            layer.name,
                            lp.w_mm.len(),
                            lp.b_mm.len(),
                            mb * u * cb * k * k * u,
                            mb * u
                        )));
                    }
                    if *p > 0 || mode != ArithMode::Precise {
                        let padded = cb * (h + 2 * p) * (w + 2 * p) * u;
                        self.scratch_len = self.scratch_len.max(padded);
                        // QuantI8 quantizes the padded f32 row into a
                        // parallel i8 scratch row per image.
                        if quant {
                            self.qscratch_len = self.qscratch_len.max(padded);
                        }
                    }
                    // Generic-u kernels keep their tap block /
                    // accumulator tile in per-thread arena scratch
                    // (u = 4 runs fully in registers).
                    if u != 4 {
                        self.thread_scratch_row =
                            self.thread_scratch_row.max((u * u).max(conv::OW_TILE * u));
                    }
                    // Tile sizes: schedule override or the L1/L2 cost
                    // model, clamped to this layer's Mb x Ho grid.
                    let tile = ls
                        .tiling
                        .unwrap_or_else(|| {
                            ConvTiling::choose(cb, w + 2 * p, u, *k, *s, mb, ho)
                        })
                        .clamped(mb, ho);
                    // Cost-weighted placement consumes the tile's
                    // working-set bytes (packed path only — the
                    // unpacked row walk is the placement-free
                    // ablation reference).
                    let place = if ls.placement && ls.packing {
                        Some(tile.working_set_bytes(cb, w + 2 * p, u, *k, *s))
                    } else {
                        None
                    };
                    let (wgt, quant_panels) = if quant {
                        (
                            Arc::new(Vec::new()),
                            Some(self.bake_conv_panels_i8(&lp.w_mm, mb, cb, *k, u)),
                        )
                    } else if ls.packing {
                        (self.bake_conv_panels(&lp.w_mm, mode, mb, cb, *k, u), None)
                    } else {
                        (self.bake(&lp.w_mm, mode), None)
                    };
                    // SIMD kernel selection: packed panels, a
                    // vectorised f32 mode, and no per-layer scalar
                    // override. (QuantI8 picks its own int8 backend.)
                    let vec =
                        !quant && mode.vectorized() && ls.packing && ls.vector_width != 1;
                    let b = self.bias(&lp.b_mm);
                    self.push(Some(&layer.name), Step::ConvMm {
                        src: cur,
                        dst,
                        w: wgt,
                        b,
                        k: *k,
                        s: *s,
                        p: *p,
                        relu: *relu,
                        mode,
                        packed: ls.packing,
                        vec,
                        quant: quant_panels,
                        tile,
                        place,
                    });
                    self.nchw_ctx = false;
                } else {
                    let policy = if self.baseline {
                        NchwConv::Scalar
                    } else {
                        match ls.parallelism {
                            Parallelism::Flp => NchwConv::Flp,
                            Parallelism::Klp => NchwConv::Klp,
                            Parallelism::Olp => unreachable!("rowmajor implies non-OLP"),
                        }
                    };
                    if lp.w_conv.len() != m * c * k * k || lp.b_conv.len() != *m {
                        return Err(Error::Shape(format!(
                            "layer {}: params {}x{} vs expected {}x{}",
                            layer.name,
                            lp.w_conv.len(),
                            lp.b_conv.len(),
                            m * c * k * k,
                            m
                        )));
                    }
                    if mode != ArithMode::Precise {
                        self.scratch_len = self.scratch_len.max(c * h * w);
                    }
                    if policy != NchwConv::Scalar {
                        self.reduce_len = self.reduce_len.max(m * ho * wo);
                    }
                    let (wgt, b) = (self.bake(&lp.w_conv, mode), self.bias(&lp.b_conv));
                    self.push(Some(&layer.name), Step::ConvNchw {
                        src: cur,
                        dst,
                        w: wgt,
                        b,
                        k: *k,
                        s: *s,
                        p: *p,
                        relu: *relu,
                        mode,
                        policy,
                    });
                    self.nchw_ctx = true;
                }
                Ok(dst)
            }
            LayerOp::MaxPool { k, s, p } | LayerOp::AvgPool { k, s, p } => {
                let is_max = matches!(layer.op, LayerOp::MaxPool { .. });
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                let ho = shapes::conv_out(h, *k, *s, *p).map_err(named)?;
                let wo = shapes::conv_out(w, *k, *s, *p).map_err(named)?;
                let dst = self.slot(SlotShape::Maps { c, h: ho, w: wo, u });
                // Non-parameterised layers run at whatever layout the
                // surrounding scheduled layers left the activation in.
                if !self.nchw_ctx {
                    if *p > 0 {
                        let padded = ceil_div(c, u) * (h + 2 * p) * (w + 2 * p) * u;
                        self.scratch_len = self.scratch_len.max(padded);
                    }
                    self.push(Some(&layer.name), Step::PoolMm {
                        src: cur,
                        dst,
                        k: *k,
                        s: *s,
                        p: *p,
                        is_max,
                    });
                } else {
                    self.push(Some(&layer.name), Step::PoolNchw {
                        src: cur,
                        dst,
                        k: *k,
                        s: *s,
                        p: *p,
                        is_max,
                    });
                }
                Ok(dst)
            }
            LayerOp::Lrn { size, alpha, beta } => {
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                let dst = self.slot(SlotShape::Maps { c, h, w, u });
                self.push(Some(&layer.name), Step::Lrn {
                    src: cur,
                    dst,
                    size: *size,
                    alpha: *alpha,
                    beta: *beta,
                });
                Ok(dst)
            }
            LayerOp::Fork { branches } => {
                self.require_maps(cur, layer)?;
                // Every branch starts from the pre-fork layout context;
                // channel concat requires the branches to agree on the
                // layout they end in (schedule heterogeneity *within* a
                // branch is fine, *across* the join it must line up).
                let ctx_before = self.nchw_ctx;
                let mut outs = Vec::with_capacity(branches.len());
                let mut ctx_after = true;
                for br in branches {
                    self.nchw_ctx = ctx_before;
                    outs.push(self.lower(br, cur)?);
                    ctx_after &= self.nchw_ctx;
                }
                let mut total_c = 0;
                let mut hw: Option<(usize, usize)> = None;
                let mut join_u: Option<usize> = None;
                for &o in &outs {
                    let (bc, bh, bw, bu) = match self.slots[o] {
                        SlotShape::Maps { c, h, w, u } => (c, h, w, u),
                        SlotShape::Flat { .. } => {
                            return Err(Error::Invalid(format!(
                                "fork {}: branch produced flat activation",
                                layer.name
                            )))
                        }
                    };
                    if let Some((ph, pw)) = hw {
                        if (bh, bw) != (ph, pw) {
                            return Err(Error::Shape(format!(
                                "fork {}: branch spatial mismatch {bh}x{bw} vs {ph}x{pw}",
                                layer.name
                            )));
                        }
                    } else {
                        hw = Some((bh, bw));
                    }
                    match join_u {
                        Some(pu) if pu != bu => {
                            return Err(Error::Config(format!(
                                "fork {}: branches end in different layouts \
                                 (u={bu} vs u={pu}); schedule the last conv of \
                                 every branch with the same parallelism family",
                                layer.name
                            )))
                        }
                        _ => join_u = Some(bu),
                    }
                    if bc % bu != 0 {
                        return Err(Error::Invalid(format!(
                            "fork {}: branch width {bc} not aligned to u={bu}",
                            layer.name
                        )));
                    }
                    total_c += bc;
                }
                let (h, w) = hw.ok_or_else(|| {
                    Error::Invalid(format!("fork {}: no branches", layer.name))
                })?;
                let u = join_u.expect("hw implies at least one branch");
                self.nchw_ctx = ctx_after;
                let dst = self.slot(SlotShape::Maps { c: total_c, h, w, u });
                self.push(Some(&layer.name), Step::Concat { srcs: outs, dst });
                Ok(dst)
            }
            LayerOp::Flatten => {
                self.flat_mm = !self.nchw_ctx;
                let len = self.slots[cur].len();
                let dst = self.slot(SlotShape::Flat { len });
                self.push(Some(&layer.name), Step::Copy { src: cur, dst });
                Ok(dst)
            }
            LayerOp::Gap => {
                self.flat_mm = !self.nchw_ctx;
                let (c, ..) = self.require_maps(cur, layer)?;
                let dst = self.slot(SlotShape::Flat { len: c });
                self.push(Some(&layer.name), Step::Gap { src: cur, dst });
                Ok(dst)
            }
            LayerOp::Dense { o, relu } => {
                let len = match self.slots[cur] {
                    SlotShape::Flat { len } => len,
                    SlotShape::Maps { .. } => {
                        return Err(Error::Invalid(format!(
                            "layer {}: dense/softmax requires flatten or gap first",
                            layer.name
                        )))
                    }
                };
                let ls = self.layer_schedule(&layer.name)?;
                let lp = self.params.layer_params(&layer.name)?;
                let mode = ls.mode;
                // The flat activation's element order is fixed by the
                // layout the flatten/gap consumed: map-major flattens
                // need the column-permuted `w_mm`, row-major flattens
                // the conventional `w_conv` (they coincide after gap and
                // at u = 1).
                let (w_src, b_src) = if self.flat_mm {
                    (&lp.w_mm, &lp.b_mm)
                } else {
                    (&lp.w_conv, &lp.b_conv)
                };
                if w_src.len() != o * len || b_src.len() != *o {
                    return Err(Error::Shape(format!(
                        "layer {}: dense params {}x{} vs expected {}x{}",
                        layer.name,
                        w_src.len(),
                        b_src.len(),
                        o * len,
                        o
                    )));
                }
                if mode != ArithMode::Precise {
                    self.scratch_len = self.scratch_len.max(len);
                }
                let quant = mode.quantized();
                if quant && !ls.packing {
                    return Err(Error::Config(format!(
                        "layer {}: quant_i8 requires packing (the int8 panels are \
                         the packed layout)",
                        layer.name
                    )));
                }
                if quant {
                    self.qscratch_len = self.qscratch_len.max(len);
                }
                let (wgt, quant_panels) = if quant {
                    (Arc::new(Vec::new()), Some(self.bake_dense_panels_i8(w_src, *o, len)))
                } else if ls.packing {
                    (self.bake_dense_panels(w_src, mode, *o, len), None)
                } else {
                    (self.bake(w_src, mode), None)
                };
                let vec = !quant && mode.vectorized() && ls.packing && ls.vector_width != 1;
                let b = self.bias(b_src);
                let dst = self.slot(SlotShape::Flat { len: *o });
                self.push(Some(&layer.name), Step::Dense {
                    src: cur,
                    dst,
                    w: wgt,
                    b,
                    relu: *relu,
                    mode,
                    packed: ls.packing,
                    vec,
                    quant: quant_panels,
                });
                Ok(dst)
            }
            LayerOp::Softmax => {
                let len = match self.slots[cur] {
                    SlotShape::Flat { len } => len,
                    SlotShape::Maps { .. } => {
                        return Err(Error::Invalid(format!(
                            "layer {}: dense/softmax requires flatten or gap first",
                            layer.name
                        )))
                    }
                };
                let dst = self.slot(SlotShape::Flat { len });
                self.push(Some(&layer.name), Step::Softmax { src: cur, dst });
                Ok(dst)
            }
        }
    }

    fn require_maps(&self, slot: usize, layer: &Layer) -> Result<(usize, usize, usize, usize)> {
        match self.slots[slot] {
            SlotShape::Maps { c, h, w, u } => Ok((c, h, w, u)),
            SlotShape::Flat { .. } => Err(Error::Invalid(format!(
                "layer {}: op {:?} cannot consume a flat activation",
                layer.name, layer.op
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Disjoint (read, write) access into the register file.
fn pair_mut(bufs: &mut [Vec<f32>], read: usize, write: usize) -> (&[f32], &mut [f32]) {
    assert_ne!(read, write, "plan step reads and writes the same register");
    if read < write {
        let (lo, hi) = bufs.split_at_mut(write);
        (lo[read].as_slice(), hi[0].as_mut_slice())
    } else {
        let (lo, hi) = bufs.split_at_mut(read);
        (hi[0].as_slice(), lo[write].as_mut_slice())
    }
}

/// Execute one step over `live` batch rows. Registers hold `B` rows at
/// a fixed per-row stride (`slots[i].len()`); scratch rows are
/// `scratch_row` apart. Conv (map-major) and dense lower the batch loop
/// into a single parallel region; the remaining (memory-bound) steps
/// walk rows sequentially with per-row kernels, so numerics never
/// depend on the batch size. `images` feeds [`Step::Input`] only — a
/// staged walk's later stages pass `&[]` (their ranges hold no input
/// prologue) with the batch's live count.
#[allow(clippy::too_many_arguments)]
fn exec_step(
    step: &Step,
    slots: &[SlotShape],
    arena: &mut Arena,
    images: &[&[f32]],
    live: usize,
    threads: usize,
    scratch_row: usize,
    qscratch_row: usize,
) {
    match step {
        Step::Input { dst } => {
            let (c, h, w, u) = maps_of(slots[*dst]);
            let len = slots[*dst].len();
            for (r, img) in images.iter().enumerate() {
                layout::nchw_to_mapmajor_into(
                    img,
                    c,
                    h,
                    w,
                    u,
                    &mut arena.bufs[*dst][r * len..(r + 1) * len],
                );
            }
        }
        Step::ConvMm { src, dst, w, b, k, s, p, relu, mode, packed, vec, quant, tile, place } => {
            let (cin, h, wd, u) = maps_of(slots[*src]);
            let (m, ho, wo, _) = maps_of(slots[*dst]);
            let (cb, mb) = (ceil_div(cin, u), ceil_div(m, u));
            let (hp, wp) = (h + 2 * p, wd + 2 * p);
            let src_len = slots[*src].len();
            if let Some(q) = quant {
                // Quantized path: pad into the f32 scratch (the QuantI8
                // elementwise cast is the identity), then symmetric
                // per-image i8 quantization into the i8 scratch rows.
                let plen = cb * hp * wp * u;
                for r in 0..live {
                    tensor::pad_cast_into(
                        &arena.bufs[*src][r * src_len..(r + 1) * src_len],
                        cb,
                        h,
                        wd,
                        u,
                        *p,
                        0.0,
                        *mode,
                        &mut arena.scratch[r * scratch_row..][..plen],
                    );
                    arena.qscales[r] = mode::quantize_symmetric_into(
                        &arena.scratch[r * scratch_row..][..plen],
                        &mut arena.qscratch[r * qscratch_row..][..plen],
                    );
                }
                conv::conv_i8_packed_core(
                    &arena.qscratch,
                    &arena.qscales,
                    qscratch_row,
                    hp,
                    wp,
                    cb,
                    u,
                    &q.data,
                    q.scale,
                    b,
                    &mut arena.bufs[*dst],
                    mb,
                    *k,
                    *s,
                    ho,
                    wo,
                    *relu,
                    threads,
                    live,
                    *tile,
                    *place,
                    &mut arena.thread_scratch,
                );
            } else if *p > 0 || *mode != ArithMode::Precise {
                let plen = cb * hp * wp * u;
                for r in 0..live {
                    tensor::pad_cast_into(
                        &arena.bufs[*src][r * src_len..(r + 1) * src_len],
                        cb,
                        h,
                        wd,
                        u,
                        *p,
                        0.0,
                        *mode,
                        &mut arena.scratch[r * scratch_row..][..plen],
                    );
                }
                // One parallel region spanning every macro item of the
                // live batch.
                if *packed {
                    conv::conv_mm_packed_core(
                        &arena.scratch,
                        scratch_row,
                        hp,
                        wp,
                        cb,
                        u,
                        w,
                        b,
                        &mut arena.bufs[*dst],
                        mb,
                        *k,
                        *s,
                        ho,
                        wo,
                        *relu,
                        *vec,
                        threads,
                        live,
                        *tile,
                        *place,
                        &mut arena.thread_scratch,
                    );
                } else {
                    conv::conv_mm_core(
                        &arena.scratch,
                        scratch_row,
                        hp,
                        wp,
                        cb,
                        u,
                        w,
                        b,
                        &mut arena.bufs[*dst],
                        mb,
                        *k,
                        *s,
                        ho,
                        wo,
                        *relu,
                        threads,
                        live,
                        &mut arena.thread_scratch,
                    );
                }
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                if *packed {
                    conv::conv_mm_packed_core(
                        x,
                        src_len,
                        hp,
                        wp,
                        cb,
                        u,
                        w,
                        b,
                        out,
                        mb,
                        *k,
                        *s,
                        ho,
                        wo,
                        *relu,
                        *vec,
                        threads,
                        live,
                        *tile,
                        *place,
                        &mut arena.thread_scratch,
                    );
                } else {
                    conv::conv_mm_core(
                        x, src_len, hp, wp, cb, u, w, b, out, mb, *k, *s, ho, wo, *relu,
                        threads, live, &mut arena.thread_scratch,
                    );
                }
            }
        }
        Step::ConvNchw { src, dst, w, b, k, s, p, relu, mode, policy } => {
            let (cin, h, wd, _) = maps_of(slots[*src]);
            let (m, ho, wo, _) = maps_of(slots[*dst]);
            let x_len = cin * h * wd;
            let src_len = slots[*src].len();
            let dst_len = slots[*dst].len();
            if *mode != ArithMode::Precise {
                for r in 0..live {
                    mode::cast_slice_into(
                        &arena.bufs[*src][r * src_len..(r + 1) * src_len],
                        *mode,
                        &mut arena.scratch[r * scratch_row..][..x_len],
                    );
                }
            }
            match policy {
                NchwConv::Scalar => {
                    if *mode != ArithMode::Precise {
                        for r in 0..live {
                            let x = &arena.scratch[r * scratch_row..][..x_len];
                            conv::conv_nchw_scalar_into(
                                x, cin, h, wd, w, b, m, *k, *s, *p, *relu, ho, wo,
                                &mut arena.bufs[*dst][r * dst_len..(r + 1) * dst_len],
                            );
                        }
                    } else {
                        let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                        for r in 0..live {
                            conv::conv_nchw_scalar_into(
                                &x[r * src_len..(r + 1) * src_len],
                                cin,
                                h,
                                wd,
                                w,
                                b,
                                m,
                                *k,
                                *s,
                                *p,
                                *relu,
                                ho,
                                wo,
                                &mut out[r * dst_len..(r + 1) * dst_len],
                            );
                        }
                    }
                }
                NchwConv::Flp | NchwConv::Klp => {
                    let is_flp = matches!(policy, NchwConv::Flp);
                    let items = if is_flp { m * cin } else { cin * k };
                    let buf_len = m * ho * wo;
                    for r in 0..live {
                        {
                            let x: &[f32] = if *mode != ArithMode::Precise {
                                &arena.scratch[r * scratch_row..][..x_len]
                            } else {
                                &arena.bufs[*src][r * src_len..(r + 1) * src_len]
                            };
                            let wgt: &[f32] = w;
                            let (kk, ss, pp) = (*k, *s, *p);
                            parallel::parallel_reduce_with(
                                items,
                                threads,
                                buf_len,
                                &mut arena.reduce,
                                &|_i, range: Range<usize>, buf: &mut [f32]| {
                                    if is_flp {
                                        conv::flp_accumulate(
                                            x, cin, h, wd, wgt, kk, ss, pp, ho, wo, range, buf,
                                        );
                                    } else {
                                        conv::klp_accumulate(
                                            x, cin, h, wd, wgt, m, kk, ss, pp, ho, wo, range,
                                            buf,
                                        );
                                    }
                                },
                            );
                        }
                        let out = &mut arena.bufs[*dst][r * dst_len..(r + 1) * dst_len];
                        out.copy_from_slice(&arena.reduce[0][..buf_len]);
                        conv::finish_bias_relu(out, b, m, ho * wo, *relu);
                    }
                }
            }
        }
        Step::PoolMm { src, dst, k, s, p, is_max } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let (_, ho, wo, _) = maps_of(slots[*dst]);
            let cb = ceil_div(c, u);
            let src_len = slots[*src].len();
            let dst_len = slots[*dst].len();
            let fill = if *is_max { f32::NEG_INFINITY } else { 0.0 };
            if *p > 0 {
                let (hp, wp) = (h + 2 * p, wd + 2 * p);
                let plen = cb * hp * wp * u;
                for r in 0..live {
                    tensor::pad_spatial_into(
                        &arena.bufs[*src][r * src_len..(r + 1) * src_len],
                        cb,
                        h,
                        wd,
                        u,
                        *p,
                        fill,
                        &mut arena.scratch[r * scratch_row..][..plen],
                    );
                }
                for r in 0..live {
                    ops::pool_mm_core(
                        &arena.scratch[r * scratch_row..][..plen],
                        hp,
                        wp,
                        u,
                        cb,
                        &mut arena.bufs[*dst][r * dst_len..(r + 1) * dst_len],
                        ho,
                        wo,
                        *k,
                        *s,
                        *is_max,
                    );
                }
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                for r in 0..live {
                    ops::pool_mm_core(
                        &x[r * src_len..(r + 1) * src_len],
                        h,
                        wd,
                        u,
                        cb,
                        &mut out[r * dst_len..(r + 1) * dst_len],
                        ho,
                        wo,
                        *k,
                        *s,
                        *is_max,
                    );
                }
            }
        }
        Step::PoolNchw { src, dst, k, s, p, is_max } => {
            let (c, h, wd, _) = maps_of(slots[*src]);
            let (_, ho, wo, _) = maps_of(slots[*dst]);
            let src_len = slots[*src].len();
            let dst_len = slots[*dst].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            for r in 0..live {
                ops::pool_nchw_into(
                    &x[r * src_len..(r + 1) * src_len],
                    c,
                    h,
                    wd,
                    *k,
                    *s,
                    *p,
                    *is_max,
                    ho,
                    wo,
                    &mut out[r * dst_len..(r + 1) * dst_len],
                );
            }
        }
        Step::Lrn { src, dst, size, alpha, beta } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let len = slots[*src].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            for r in 0..live {
                ops::lrn_mm_into(
                    &x[r * len..(r + 1) * len],
                    c,
                    h,
                    wd,
                    u,
                    *size,
                    *alpha,
                    *beta,
                    &mut out[r * len..(r + 1) * len],
                );
            }
        }
        Step::Gap { src, dst } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let src_len = slots[*src].len();
            let dst_len = slots[*dst].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            for r in 0..live {
                ops::gap_mm_into(
                    &x[r * src_len..(r + 1) * src_len],
                    c,
                    h,
                    wd,
                    u,
                    &mut out[r * dst_len..(r + 1) * dst_len],
                );
            }
        }
        Step::Copy { src, dst } => {
            let len = slots[*src].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            out[..live * len].copy_from_slice(&x[..live * len]);
        }
        Step::Concat { srcs, dst } => {
            let dst_total = slots[*dst].len();
            let mut off = 0;
            for &sidx in srcs {
                let part_len = slots[sidx].len();
                let (x, out) = pair_mut(&mut arena.bufs, sidx, *dst);
                for r in 0..live {
                    out[r * dst_total + off..r * dst_total + off + part_len]
                        .copy_from_slice(&x[r * part_len..(r + 1) * part_len]);
                }
                off += part_len;
            }
        }
        Step::Dense { src, dst, w, b, relu, mode, packed, vec, quant } => {
            let o = flat_of(slots[*dst]);
            let len = flat_of(slots[*src]);
            if let Some(q) = quant {
                // Quantized path: symmetric per-image quantization of
                // the flat activation, then the widening-i32 kernel.
                for r in 0..live {
                    arena.qscales[r] = mode::quantize_symmetric_into(
                        &arena.bufs[*src][r * len..(r + 1) * len],
                        &mut arena.qscratch[r * qscratch_row..][..len],
                    );
                }
                ops::dense_i8_rows_packed_into(
                    &arena.qscratch,
                    &arena.qscales,
                    qscratch_row,
                    len,
                    &q.data,
                    q.scale,
                    b,
                    o,
                    *relu,
                    &mut arena.bufs[*dst],
                    live,
                    threads,
                );
            } else if *mode != ArithMode::Precise {
                for r in 0..live {
                    mode::cast_slice_into(
                        &arena.bufs[*src][r * len..(r + 1) * len],
                        *mode,
                        &mut arena.scratch[r * scratch_row..][..len],
                    );
                }
                if *packed {
                    ops::dense_rows_packed_into(
                        &arena.scratch,
                        scratch_row,
                        len,
                        w,
                        b,
                        o,
                        *relu,
                        *vec,
                        &mut arena.bufs[*dst],
                        live,
                        threads,
                    );
                } else {
                    ops::dense_rows_into(
                        &arena.scratch,
                        scratch_row,
                        len,
                        w,
                        b,
                        o,
                        *relu,
                        &mut arena.bufs[*dst],
                        live,
                        threads,
                    );
                }
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                if *packed {
                    ops::dense_rows_packed_into(
                        x, len, len, w, b, o, *relu, *vec, out, live, threads,
                    );
                } else {
                    ops::dense_rows_into(x, len, len, w, b, o, *relu, out, live, threads);
                }
            }
        }
        Step::Softmax { src, dst } => {
            let len = flat_of(slots[*src]);
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            for r in 0..live {
                ops::softmax_into(&x[r * len..(r + 1) * len], &mut out[r * len..(r + 1) * len]);
            }
        }
        Step::Reorder { src, dst } => {
            // Exact permutation between map-major widths; lowering
            // guarantees one side is row-major (u = 1).
            let (c, h, wd, su) = maps_of(slots[*src]);
            let (.., du) = maps_of(slots[*dst]);
            let src_len = slots[*src].len();
            let dst_len = slots[*dst].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            for r in 0..live {
                let s_row = &x[r * src_len..(r + 1) * src_len];
                let d_row = &mut out[r * dst_len..(r + 1) * dst_len];
                if su == 1 {
                    layout::nchw_to_mapmajor_into(s_row, c, h, wd, du, d_row);
                } else {
                    assert_eq!(du, 1, "reorder steps always cross u = 1");
                    layout::mapmajor_to_nchw_into(s_row, c, h, wd, su, d_row);
                }
            }
        }
        Step::Transfer { src, dst } => {
            // Same-shape handoff into a wire register (layout changes
            // at a cut are separate Reorder steps). Only live rows
            // cross: a partial batch never forwards padded lanes.
            let len = slots[*src].len();
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            out[..live * len].copy_from_slice(&x[..live * len]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_cappnet;
    use crate::model::zoo;
    use crate::util::rng::Rng;

    fn rand_input(net: &Network, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(net.input.elements())
    }

    #[test]
    fn plan_compiles_and_runs_tinynet() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 42, 4).unwrap();
        let mut plan = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
        let input = rand_input(&net, 7);
        let a = plan.run(&input).unwrap();
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|v| v.is_finite()));
        // Re-running the same plan with the same input is bitwise stable
        // (the arena leaks no state between inferences).
        let b = plan.run(&input).unwrap();
        assert_eq!(a, b);
        assert_eq!(plan.runs(), 2);
    }

    #[test]
    fn plan_interleaved_inputs_do_not_contaminate() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 1, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut plan = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        let x1 = rand_input(&net, 2);
        let x2 = rand_input(&net, 3);
        let a1 = plan.run(&x1).unwrap();
        let a2 = plan.run(&x2).unwrap();
        let a1_again = plan.run(&x1).unwrap();
        assert_ne!(a1, a2);
        assert_eq!(a1, a1_again, "arena state leaked between inferences");
    }

    #[test]
    fn plan_alloc_is_logits_only() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut plan = PlanBuilder::new(&net, &params).modes(&modes).build().unwrap();
        let input = rand_input(&net, 9);
        for _ in 0..4 {
            plan.run(&input).unwrap();
        }
        // 8 logits * 4 bytes per inference, nothing else.
        assert_eq!(plan.alloc_bytes_per_run(), 32.0);
        assert_eq!(plan.alloc().allocs(), 4);
        assert!(plan.arena_bytes() > 0);
        assert!(plan.baked_param_bytes() > 0);
    }

    #[test]
    fn plan_clone_shares_weights_not_arena() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let plan = PlanBuilder::new(&net, &params).build().unwrap();
        let mut a = plan.clone();
        let mut b = plan;
        let input = rand_input(&net, 11);
        assert_eq!(a.run(&input).unwrap(), b.run(&input).unwrap());
    }

    /// First baked weight tensor of a plan (for Arc-sharing checks).
    fn first_weight(plan: &ExecutionPlan) -> Arc<Vec<f32>> {
        plan.steps
            .iter()
            .find_map(|s| match s {
                Step::ConvMm { w, .. }
                | Step::ConvNchw { w, .. }
                | Step::Dense { w, .. } => Some(Arc::clone(w)),
                _ => None,
            })
            .expect("plan has at least one parameterised step")
    }

    #[test]
    fn with_capacity_shares_baked_weights_and_scales_arena() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 6, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let base = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .batch(8)
            .build()
            .unwrap();
        let small = base.with_capacity(2);
        assert_eq!(base.capacity(), 8);
        assert_eq!(small.capacity(), 2);
        // Baked parameters are the same Arc allocation, not a copy.
        assert!(Arc::ptr_eq(&first_weight(&base), &first_weight(&small)));
        assert_eq!(base.baked_param_bytes(), small.baked_param_bytes());
        // The arena scales with the capacity (registers are B x rows).
        assert!(base.arena_bytes() > small.arena_bytes());
        // And both capacities produce identical logits.
        let input = rand_input(&net, 12);
        let mut b8 = base;
        let mut b2 = small;
        assert_eq!(b8.run(&input).unwrap(), b2.run(&input).unwrap());
    }

    #[test]
    fn unpacked_plan_and_tiling_overrides_bitwise_match() {
        // packing(false) (the pre-packing plan) and any tiling override
        // must leave the numerics bitwise untouched.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 77, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let input = rand_input(&net, 78);
        let mut packed = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        let want = packed.run(&input).unwrap();
        let mut unpacked = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .packing(false)
            .build()
            .unwrap();
        assert_eq!(unpacked.run(&input).unwrap(), want, "packing(false) diverged");
        for tile in [
            ConvTiling { tm: 1, th: 1 },
            ConvTiling { tm: 3, th: 5 },
            ConvTiling { tm: 64, th: 64 },
        ] {
            let mut tiled = PlanBuilder::new(&net, &params)
                .modes(&modes)
                .threads(2)
                .tiling(tile)
                .build()
                .unwrap();
            assert_eq!(tiled.run(&input).unwrap(), want, "tile {tile:?} diverged");
        }
    }

    #[test]
    fn run_batch_matches_singles_and_skips_padded_lanes() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 13, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut batch_plan = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .batch(8)
            .build()
            .unwrap();
        let mut single = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        let inputs: Vec<Vec<f32>> = (0..8).map(|i| rand_input(&net, 20 + i)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        // Fill every lane, then run a partial batch: the stale rows from
        // the full batch must not reach the partial batch's replies.
        let full = batch_plan.run_batch(&refs).unwrap();
        assert_eq!(full.len(), 8);
        let partial = batch_plan.run_batch(&refs[..3]).unwrap();
        assert_eq!(partial.len(), 3);
        for (i, row) in partial.iter().enumerate() {
            assert_eq!(row, &single.run(&inputs[i]).unwrap(), "lane {i}");
            assert_eq!(row, &full[i], "lane {i} vs full batch");
        }
        assert_eq!(batch_plan.runs(), 11);
    }

    #[test]
    fn run_batch_into_writes_caller_rows() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 14, 4).unwrap();
        let mut plan = PlanBuilder::new(&net, &params).batch(4).build().unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|i| rand_input(&net, 30 + i)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let want = plan.run_batch(&refs).unwrap();
        let out_len = plan.output_len();
        let mut out = vec![0.0f32; 3 * out_len];
        plan.run_batch_into(&refs, &mut out).unwrap();
        for (r, row) in want.iter().enumerate() {
            assert_eq!(&out[r * out_len..(r + 1) * out_len], row.as_slice());
        }
        // Wrong-size output buffer is rejected before any compute.
        let mut short = vec![0.0f32; out_len];
        assert!(matches!(plan.run_batch_into(&refs, &mut short), Err(Error::Shape(_))));
    }

    #[test]
    fn over_capacity_batch_rejected() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 15, 4).unwrap();
        let mut plan = PlanBuilder::new(&net, &params).batch(2).build().unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|i| rand_input(&net, 40 + i)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        assert!(matches!(plan.run_batch(&refs), Err(Error::Invalid(_))));
        // Empty batches are a no-op.
        assert!(plan.run_batch(&[]).unwrap().is_empty());
        assert_eq!(plan.runs(), 0);
    }

    #[test]
    fn oversized_window_is_shape_error_not_panic() {
        let net = parse_cappnet(
            "net bad\ninput 3 4 4\nclasses 4\nconv c1 m=4 k=7 s=1 p=0\ngap\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 0, 4);
        // Shape inference fails before any parameter work.
        assert!(params.is_err() || {
            let p = params.unwrap();
            matches!(
                PlanBuilder::new(&net, &p).build(),
                Err(Error::Shape(_))
            )
        });
    }

    #[test]
    fn bad_input_len_rejected() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 0, 4).unwrap();
        let mut plan = PlanBuilder::new(&net, &params).build().unwrap();
        assert!(matches!(plan.run(&[0.0; 3]), Err(Error::Shape(_))));
    }

    #[test]
    fn baseline_plan_matches_mapmajor_plan() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 21, 4).unwrap();
        let mut base = PlanBuilder::new(&net, &params).baseline().build().unwrap();
        let mut opt = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
        let input = rand_input(&net, 22);
        let a = base.run(&input).unwrap();
        let b = opt.run(&input).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn degenerate_builder_inputs_are_config_errors() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 50, 4).unwrap();
        // batch(0): rejected before compilation, typed.
        assert!(matches!(
            PlanBuilder::new(&net, &params).batch(0).build(),
            Err(Error::Config(_))
        ));
        // threads(0), via both the setter and a raw ExecConfig.
        assert!(matches!(
            PlanBuilder::new(&net, &params).threads(0).build(),
            Err(Error::Config(_))
        ));
        assert!(matches!(
            PlanBuilder::new(&net, &params)
                .config(ExecConfig { threads: 0, affinity: false })
                .build(),
            Err(Error::Config(_))
        ));
        // A mode assignment naming a layer the net does not have.
        let bad = ModeAssignment::uniform(ArithMode::Precise).with("convX", ArithMode::Imprecise);
        assert!(matches!(
            PlanBuilder::new(&net, &params).modes(&bad).build(),
            Err(Error::Config(_))
        ));
        // A schedule whose layer set mismatches the net's layer count.
        let mut sched = Schedule::default_for(&net, 4);
        sched.layers.remove("conv1");
        assert!(matches!(
            PlanBuilder::new(&net, &params).schedule(sched).build(),
            Err(Error::Config(_))
        ));
        // A schedule built for a different vector width.
        let sched = Schedule::default_for(&net, 8);
        assert!(matches!(
            PlanBuilder::new(&net, &params).schedule(sched).build(),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn exported_schedule_rebuilds_bitwise_identically() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 51, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise).with("fc5", ArithMode::Precise);
        let mut fluent = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .batch(3)
            .build()
            .unwrap();
        let sched = fluent.schedule().clone();
        assert_eq!(sched.pool.threads, 2);
        let mut rebuilt = PlanBuilder::new(&net, &params)
            .schedule(sched)
            .batch(3)
            .build()
            .unwrap();
        let inputs: Vec<Vec<f32>> = (0..3).map(|i| rand_input(&net, 52 + i)).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            fluent.run_batch(&refs).unwrap(),
            rebuilt.run_batch(&refs).unwrap(),
            "schedule round trip changed the numerics"
        );
    }

    #[test]
    fn per_layer_packing_is_honored_and_bitwise_invisible() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 53, 4).unwrap();
        let input = rand_input(&net, 54);
        let mut all_packed = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
        let want = all_packed.run(&input).unwrap();
        let mut sched = Schedule::default_for(&net, 4);
        sched.pool.threads = 2;
        sched.layers.get_mut("conv1").unwrap().packing = false;
        let mut mixed = PlanBuilder::new(&net, &params).schedule(sched).build().unwrap();
        assert_eq!(mixed.run(&input).unwrap(), want, "per-layer packing perturbed output");
    }

    #[test]
    fn quant_i8_plan_runs_and_tracks_f32() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 60, 4).unwrap();
        let input = rand_input(&net, 61);
        let mut precise = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
        let want = precise.run(&input).unwrap();
        let mut sched = Schedule::default_for(&net, 4);
        sched.pool.threads = 2;
        for ls in sched.layers.values_mut() {
            ls.mode = ArithMode::QuantI8;
        }
        let mut quant = PlanBuilder::new(&net, &params)
            .schedule(sched)
            .batch(3)
            .build()
            .unwrap();
        let a = quant.run(&input).unwrap();
        assert_eq!(a.len(), want.len());
        // int8 is approximate (tolerance-gated, not bitwise): logits
        // stay finite and close to the f32 reference.
        for (x, y) in want.iter().zip(&a) {
            assert!(y.is_finite());
            assert!((x - y).abs() < 0.25 * (1.0 + x.abs()), "{x} vs {y}");
        }
        // Per-image quantization makes batches bitwise equal to
        // singles, and reruns bitwise stable (no arena state leaks).
        let b = quant.run(&input).unwrap();
        assert_eq!(a, b);
        let rows = quant.run_batch(&[&input[..], &input[..]]).unwrap();
        assert_eq!(rows[0], a);
        assert_eq!(rows[1], a);
        assert!(quant.baked_param_bytes() > 0);
    }

    #[test]
    fn quant_i8_rejections_are_config_errors() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 62, 4).unwrap();
        // Unpacked conv under quant.
        let mut s = Schedule::default_for(&net, 4);
        let c1 = s.layers.get_mut("conv1").unwrap();
        c1.mode = ArithMode::QuantI8;
        c1.packing = false;
        assert!(matches!(
            PlanBuilder::new(&net, &params).schedule(s).build(),
            Err(Error::Config(_))
        ));
        // Row-major (FLP) scheduling under quant.
        let mut s = Schedule::default_for(&net, 4);
        let c2 = s.layers.get_mut("conv2").unwrap();
        c2.mode = ArithMode::QuantI8;
        c2.parallelism = Parallelism::Flp;
        assert!(matches!(
            PlanBuilder::new(&net, &params).schedule(s).build(),
            Err(Error::Config(_))
        ));
        // Unpacked dense under quant.
        let mut s = Schedule::default_for(&net, 4);
        let fc = s.layers.get_mut("fc4").unwrap();
        fc.mode = ArithMode::QuantI8;
        fc.packing = false;
        assert!(matches!(
            PlanBuilder::new(&net, &params).schedule(s).build(),
            Err(Error::Config(_))
        ));
        // A width that cannot be lane-padded (u = 3).
        let params3 = EngineParams::random(&net, 63, 3).unwrap();
        let mut s = Schedule::default_for(&net, 3);
        s.layers.get_mut("conv1").unwrap().mode = ArithMode::QuantI8;
        assert!(matches!(
            PlanBuilder::new(&net, &params3).schedule(s).build(),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn forced_scalar_vector_width_is_bitwise_invisible() {
        // vector_width = 1 swaps the SIMD row kernels for their scalar
        // fallback; the contract is bitwise identity, so the knob must
        // be invisible in the output.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 64, 4).unwrap();
        let input = rand_input(&net, 65);
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut auto_w = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        let want = auto_w.run(&input).unwrap();
        let mut s = auto_w.schedule().clone();
        for ls in s.layers.values_mut() {
            ls.vector_width = 1;
        }
        let mut scalar = PlanBuilder::new(&net, &params).schedule(s).build().unwrap();
        assert_eq!(scalar.run(&input).unwrap(), want, "vector_width=1 diverged");
    }

    #[test]
    fn flp_klp_policy_plans_agree_with_baseline() {
        let net = parse_cappnet(
            "net mini\ninput 3 12 12\nclasses 8\n\
             conv c1 m=8 k=3 s=1 p=1\nmaxpool k=2 s=2\n\
             conv c2 m=8 k=3 s=1 p=0\ngap\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 8, 4).unwrap();
        let mut base = PlanBuilder::new(&net, &params).baseline().build().unwrap();
        let input = rand_input(&net, 13);
        let want = base.run(&input).unwrap();
        for policy in [Parallelism::Flp, Parallelism::Klp] {
            for threads in [1, 3] {
                let mut plan = PlanBuilder::new(&net, &params)
                    .threads(threads)
                    .policy(policy)
                    .build()
                    .unwrap();
                assert!(plan.arena_bytes() > 0);
                let got = plan.run(&input).unwrap();
                for (x, y) in want.iter().zip(&got) {
                    assert!(
                        (x - y).abs() < 1e-4 * (1.0 + x.abs()),
                        "{policy}/{threads}: {x} vs {y}"
                    );
                }
            }
        }
    }
}
