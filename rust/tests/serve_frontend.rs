//! Serve front-end integration tests over the public API: deterministic
//! deadline admission, per-tenant isolation with lossless shutdown, the
//! replay driver, and SLO classes.
//!
//! The backends here are synthetic and *gated*: `infer_batch` blocks on
//! a condvar until the test opens the gate, so the admission
//! controller's pending count is pinned exactly where the test put it —
//! no timing assumptions, the shed/admit split is arithmetic.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use cappuccino::serve::{
    replay, ArrivalProcess, Backend, BackendFactory, BatchPolicy, Rejected, ReplaySpec,
    RequestOptions, Server, SloTable, SupervisorPolicy, Tenant,
};
use cappuccino::Error;

type Gate = Arc<(Mutex<bool>, Condvar)>;

fn gate() -> Gate {
    Arc::new((Mutex::new(false), Condvar::new()))
}

fn open(gate: &Gate) {
    let (lock, cvar) = &**gate;
    *lock.lock().unwrap() = true;
    cvar.notify_all();
}

/// Blocks every `infer_batch` until the gate opens, then answers each
/// image with its element sum.
struct GatedBackend {
    gate: Gate,
    batches: Vec<usize>,
    delay: Duration,
}

impl Backend for GatedBackend {
    fn input_len(&self) -> usize {
        4
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(
        &mut self,
        images: &[&[f32]],
        _capacity: usize,
    ) -> cappuccino::Result<Vec<Vec<f32>>> {
        let (lock, cvar) = &*self.gate;
        let mut is_open = lock.lock().unwrap();
        while !*is_open {
            is_open = cvar.wait(is_open).unwrap();
        }
        drop(is_open);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(images.iter().map(|img| vec![img.iter().sum()]).collect())
    }
}

fn gated_factory(gate: Gate, max_batch: usize, delay: Duration) -> BackendFactory {
    // Factories are `Fn` now (the supervisor re-invokes them to
    // respawn), so the gate is cloned per instance.
    Box::new(move || {
        Ok(Box::new(GatedBackend { gate: gate.clone(), batches: vec![max_batch], delay })
            as Box<dyn Backend>)
    })
}

/// An always-open gate: the backend answers immediately (plus `delay`).
fn instant_factory(max_batch: usize, delay: Duration) -> BackendFactory {
    let g = gate();
    open(&g);
    gated_factory(g, max_batch, delay)
}

fn tenant(
    name: &str,
    factory: BackendFactory,
    policy: BatchPolicy,
    image_ms: Option<f64>,
) -> Tenant {
    Tenant {
        name: name.into(),
        factory,
        policy,
        image_ms,
        input_len: 4,
        fallback: None,
        supervision: SupervisorPolicy::default(),
    }
}

#[test]
fn admission_sheds_exactly_the_requests_whose_drain_exceeds_the_deadline() {
    // image_ms = 10, max_batch = 4: predicted drain with `p` pending is
    // (p/4 + 1) * 40 ms. A 100 ms deadline therefore admits while
    // p <= 7. The gate is closed, so pending only moves when *we*
    // submit: one no-deadline warm-up pins pending at 1, then exactly 7
    // of 20 deadline-tagged requests fit (pending 1..=7) and 13 shed.
    let g = gate();
    let policy = BatchPolicy { max_batch: 4, queue_depth: 64, ..BatchPolicy::default() };
    let t = tenant("m", gated_factory(g.clone(), 4, Duration::ZERO), policy, Some(10.0));
    let server = Server::start_tenants(vec![t], SloTable::default()).unwrap();

    let warmup = server.router().submit("m", vec![1.0; 4]).unwrap();

    let opts = RequestOptions {
        deadline: Some(Duration::from_millis(100)),
        ..RequestOptions::default()
    };
    let mut admitted = Vec::new();
    let mut shed = 0usize;
    for _ in 0..20 {
        match server.router().submit_with("m", vec![1.0; 4], opts.clone()) {
            Ok(rx) => admitted.push(rx),
            Err(Error::Rejected(Rejected::DeadlineInfeasible {
                predicted_ms,
                deadline_ms,
                ..
            })) => {
                // Every refusal sees the same saturated queue: 8 pending
                // -> ceil(9/4) = 3 batch walks of 40 ms.
                assert_eq!(predicted_ms, 120.0);
                assert!((deadline_ms - 100.0).abs() < 1e-9);
                shed += 1;
            }
            Err(e) => panic!("expected DeadlineInfeasible, got {e}"),
        }
    }
    assert_eq!(admitted.len(), 7, "deadline admits pending 1..=7 exactly");
    assert_eq!(shed, 13);
    assert_eq!(server.router().admission("m").unwrap().pending(), 8);

    // Open the gate: every admitted request — and nothing else — is
    // answered.
    open(&g);
    assert_eq!(warmup.recv().unwrap().unwrap().logits, vec![4.0]);
    for rx in admitted {
        assert_eq!(rx.recv().unwrap().unwrap().logits, vec![4.0]);
    }
    server.shutdown();
}

#[test]
fn tenants_are_isolated_and_shutdown_is_lossless_on_both() {
    // Tenant "a" is gated shut with a tiny queue: it backpressures.
    // Tenant "b" keeps serving at full rate regardless — then shutdown
    // answers every admitted "a" request before the workers exit.
    let g = gate();
    let a_policy = BatchPolicy { max_batch: 1, queue_depth: 4, ..BatchPolicy::default() };
    let tenants = vec![
        tenant("a", gated_factory(g.clone(), 1, Duration::ZERO), a_policy, None),
        tenant("b", instant_factory(8, Duration::ZERO), BatchPolicy::default(), None),
    ];
    let server = Server::start_tenants(tenants, SloTable::default()).unwrap();

    let mut a_admitted = Vec::new();
    let mut a_full = 0usize;
    for _ in 0..12 {
        match server.router().submit("a", vec![2.0; 4]) {
            Ok(rx) => a_admitted.push(rx),
            Err(Error::Rejected(Rejected::QueueFull { model, depth })) => {
                assert_eq!(model, "a");
                assert_eq!(depth, 4);
                a_full += 1;
            }
            Err(e) => panic!("expected QueueFull, got {e}"),
        }
    }
    assert!(a_full > 0, "tiny queue behind a closed gate must backpressure");
    assert_eq!(a_admitted.len() + a_full, 12);

    // "a" being saturated must not affect "b" at all.
    for _ in 0..16 {
        let resp = server.router().infer_blocking("b", vec![0.5; 4]).unwrap();
        assert_eq!(resp.logits, vec![2.0]);
    }

    // Lossless shutdown: open the gate and stop the server; every
    // admitted "a" request still gets its reply.
    open(&g);
    let m = server.metrics();
    let counters_rejected = m.counters.rejected.load(std::sync::atomic::Ordering::Relaxed);
    let counters_full = m.counters.rejected_queue_full.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(counters_rejected, a_full as u64);
    assert_eq!(counters_full, a_full as u64);
    server.shutdown();
    for rx in a_admitted {
        assert_eq!(
            rx.recv().unwrap().unwrap().logits,
            vec![8.0],
            "admitted request dropped at shutdown"
        );
    }
}

#[test]
fn replay_accounts_for_every_request_and_sheds_under_tight_deadlines() {
    // Two slow tenants (1 ms per batch walk), burst arrivals, and a
    // deadline of 2 batch walks: the burst saturates both admission
    // windows, so some requests shed while every accepted one is
    // answered. The outcome must account for all 64 exactly.
    let tenants = vec![
        tenant(
            "a",
            instant_factory(4, Duration::from_millis(1)),
            BatchPolicy { max_batch: 4, queue_depth: 256, ..BatchPolicy::default() },
            Some(5.0),
        ),
        tenant(
            "b",
            instant_factory(4, Duration::from_millis(1)),
            BatchPolicy { max_batch: 4, queue_depth: 256, ..BatchPolicy::default() },
            Some(5.0),
        ),
    ];
    let server = Server::start_tenants(tenants, SloTable::default()).unwrap();
    let spec = ReplaySpec {
        requests: 64,
        arrivals: ArrivalProcess::Burst,
        seed: 3,
        classes: Vec::new(),
        deadline: None,
        deadline_factor: Some(2.0),
    };
    let outcome = replay(&server, &spec);
    assert_eq!(outcome.submitted, 64);
    assert_eq!(
        outcome.completed
            + outcome.shed_deadline
            + outcome.rejected_queue_full
            + outcome.rejected_other,
        64,
        "unaccounted requests: {}",
        outcome.summary_line()
    );
    assert_eq!(outcome.dropped, 0, "replay must never lose an accepted request");
    assert!(outcome.completed > 0, "nothing completed: {}", outcome.summary_line());
    assert!(
        outcome.shed_deadline > 0,
        "a burst against a 2-batch deadline must shed: {}",
        outcome.summary_line()
    );
    let json = outcome.to_json().to_string();
    assert!(json.contains("\"bench\":"), "bench json missing tag: {json}");
    server.shutdown();
}

/// Sums each image and adds `bias` (so tests can tell primary and
/// fallback apart); panics or errs per the shared knobs.
struct FaultyBackend {
    bias: f32,
    /// Err on any call while set.
    bad: Option<Arc<AtomicBool>>,
    /// Panic on infer-call numbers in this set (shared across respawned
    /// instances, so "first call ever panics" is expressible).
    panic_calls: Option<(Arc<AtomicU32>, Vec<u32>)>,
    /// Err on any batch containing an image whose first element is 666.
    poison: bool,
}

impl Backend for FaultyBackend {
    fn input_len(&self) -> usize {
        4
    }

    fn batch_sizes(&self) -> &[usize] {
        &[4]
    }

    fn infer_batch(
        &mut self,
        images: &[&[f32]],
        _capacity: usize,
    ) -> cappuccino::Result<Vec<Vec<f32>>> {
        if let Some(bad) = &self.bad {
            if bad.load(Ordering::SeqCst) {
                return Err(Error::Serve("primary is bad".into()));
            }
        }
        if let Some((counter, at)) = &self.panic_calls {
            let n = counter.fetch_add(1, Ordering::SeqCst);
            if at.contains(&n) {
                panic!("flaky backend panicked on call {n}");
            }
        }
        if self.poison && images.iter().any(|img| img[0] == 666.0) {
            return Err(Error::Serve("poison pill".into()));
        }
        let bias = self.bias;
        Ok(images.iter().map(|img| vec![img.iter().sum::<f32>() + bias]).collect())
    }
}

#[test]
fn worker_respawns_after_contained_panic_and_answers_everything() {
    // The backend panics on its very first infer call (a startup poison
    // typical of real crash bugs). The supervisor must contain it,
    // respawn, retry the batch members, and answer all six requests —
    // zero drops, zero Err replies.
    let calls = Arc::new(AtomicU32::new(0));
    let calls2 = calls.clone();
    let factory: BackendFactory = Box::new(move || {
        Ok(Box::new(FaultyBackend {
            bias: 0.0,
            bad: None,
            panic_calls: Some((calls2.clone(), vec![0])),
            poison: false,
        }) as Box<dyn Backend>)
    });
    let t = tenant("m", factory, BatchPolicy::default(), None);
    let server = Server::start_tenants(vec![t], SloTable::default()).unwrap();

    let rxs: Vec<_> = (0..6)
        .map(|_| server.router().submit("m", vec![1.0; 4]).unwrap())
        .collect();
    for rx in rxs {
        let resp = rx.recv().expect("reply dropped").expect("retry should succeed");
        assert_eq!(resp.logits, vec![4.0]);
    }
    let stats = server.metrics().faults.stats("m").expect("tenant registered");
    assert!(stats.faults_contained.load(Ordering::Relaxed) >= 1, "panic was not counted");
    assert!(stats.worker_respawns.load(Ordering::Relaxed) >= 1, "no respawn recorded");
    assert_eq!(stats.requests_quarantined.load(Ordering::Relaxed), 0);
    assert_eq!(server.router().admission("m").unwrap().pending(), 0);
    let summary = server.metrics().summary();
    assert!(summary.contains("faults["), "fault breakout missing: {summary}");
    assert!(summary.contains("m[contained="), "per-tenant fragment missing: {summary}");
    server.shutdown();
}

#[test]
fn poison_pill_is_quarantined_without_harming_the_batch() {
    // One request deterministically faults the backend every time it is
    // in a batch. Its batch-mates must still complete; the pill itself
    // must be answered with a typed Rejected::Fault after its retry
    // budget (never a hang, never a drop).
    let factory: BackendFactory = Box::new(|| {
        Ok(Box::new(FaultyBackend { bias: 0.0, bad: None, panic_calls: None, poison: true })
            as Box<dyn Backend>)
    });
    let t = tenant("m", factory, BatchPolicy::default(), None);
    let server = Server::start_tenants(vec![t], SloTable::default()).unwrap();

    let good: Vec<_> = (0..5)
        .map(|_| server.router().submit("m", vec![1.0; 4]).unwrap())
        .collect();
    let pill = server.router().submit("m", vec![666.0, 0.0, 0.0, 0.0]).unwrap();

    for rx in good {
        let resp = rx.recv().expect("reply dropped").expect("batch-mates must survive");
        assert_eq!(resp.logits, vec![4.0]);
    }
    match pill.recv().expect("pill reply dropped") {
        Err(Error::Rejected(Rejected::Fault { model, error })) => {
            assert_eq!(model, "m");
            assert!(error.contains("poison"), "unexpected fault detail: {error}");
        }
        other => panic!("pill must be a typed fault, got ok={}", other.is_ok()),
    }
    let stats = server.metrics().faults.stats("m").unwrap();
    assert_eq!(stats.requests_quarantined.load(Ordering::Relaxed), 1);
    assert!(stats.faults_contained.load(Ordering::Relaxed) >= 2, "batch + retry faults");
    assert_eq!(server.router().admission("m").unwrap().pending(), 0);
    server.shutdown();
}

#[test]
fn burst_degrades_to_fallback_and_recovers_when_quiet() {
    // Primary errs while `bad` is set; the fallback (bias +100) always
    // works. degrade_after=1 + a short window make the sequence
    // deterministic: fault -> degrade -> serve on fallback -> flip the
    // primary healthy -> quiet window -> clean fallback batch triggers
    // recovery -> next reply comes from the primary again.
    let bad = Arc::new(AtomicBool::new(true));
    let bad2 = bad.clone();
    let factory: BackendFactory = Box::new(move || {
        Ok(Box::new(FaultyBackend {
            bias: 0.0,
            bad: Some(bad2.clone()),
            panic_calls: None,
            poison: false,
        }) as Box<dyn Backend>)
    });
    let fallback: BackendFactory = Box::new(|| {
        Ok(Box::new(FaultyBackend { bias: 100.0, bad: None, panic_calls: None, poison: false })
            as Box<dyn Backend>)
    });
    let mut t = tenant("m", factory, BatchPolicy::default(), None);
    t.fallback = Some(fallback);
    t.supervision = SupervisorPolicy {
        degrade_after: 1,
        fault_window: Duration::from_millis(50),
        ..SupervisorPolicy::default()
    };
    let server = Server::start_tenants(vec![t], SloTable::default()).unwrap();

    // Faults on the primary, retried to completion on the fallback.
    let r1 = server.router().infer_blocking("m", vec![1.0; 4]).unwrap();
    assert_eq!(r1.logits, vec![104.0], "first reply must come from the fallback");

    // Primary healthy again; wait out the fault window.
    bad.store(false, Ordering::SeqCst);
    std::thread::sleep(Duration::from_millis(60));

    // Still degraded for this batch (recovery happens after it)...
    let r2 = server.router().infer_blocking("m", vec![1.0; 4]).unwrap();
    assert_eq!(r2.logits, vec![104.0], "clean batch before recovery is on the fallback");
    // ...and the one after runs on the rebuilt primary.
    let r3 = server.router().infer_blocking("m", vec![1.0; 4]).unwrap();
    assert_eq!(r3.logits, vec![4.0], "post-recovery reply must come from the primary");

    let stats = server.metrics().faults.stats("m").unwrap();
    assert!(stats.degraded_ms.load(Ordering::Relaxed) >= 1, "degraded interval not recorded");
    assert!(stats.faults_contained.load(Ordering::Relaxed) >= 1);
    server.shutdown();
}

#[test]
fn concurrent_flood_with_faults_keeps_admission_accounting_exact() {
    // Four submitter threads flood a flaky tenant (panics on two infer
    // calls mid-stream) through a small queue. Invariants under fire:
    // every admitted request gets exactly one reply (Ok or typed
    // fault), rejections are all QueueFull, and the pending gauge
    // returns to zero — no leaked admission slots across respawns.
    let calls = Arc::new(AtomicU32::new(0));
    let calls2 = calls.clone();
    let factory: BackendFactory = Box::new(move || {
        Ok(Box::new(FaultyBackend {
            bias: 0.0,
            bad: None,
            panic_calls: Some((calls2.clone(), vec![2, 7])),
            poison: false,
        }) as Box<dyn Backend>)
    });
    let policy = BatchPolicy { max_batch: 4, queue_depth: 8, ..BatchPolicy::default() };
    let t = tenant("m", factory, policy, None);
    let server = Server::start_tenants(vec![t], SloTable::default()).unwrap();

    let (mut ok, mut faulted, mut queue_full) = (0usize, 0usize, 0usize);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..4 {
            let server = &server;
            handles.push(scope.spawn(move || {
                let (mut ok, mut faulted, mut queue_full) = (0usize, 0usize, 0usize);
                for _ in 0..25 {
                    match server.router().submit("m", vec![1.0; 4]) {
                        Ok(rx) => match rx.recv().expect("admitted request dropped") {
                            Ok(resp) => {
                                assert_eq!(resp.logits, vec![4.0]);
                                ok += 1;
                            }
                            Err(Error::Rejected(Rejected::Fault { .. })) => faulted += 1,
                            Err(e) => panic!("unexpected reply error: {e}"),
                        },
                        Err(Error::Rejected(Rejected::QueueFull { .. })) => queue_full += 1,
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
                (ok, faulted, queue_full)
            }));
        }
        for h in handles {
            let (o, f, q) = h.join().unwrap();
            ok += o;
            faulted += f;
            queue_full += q;
        }
    });
    assert_eq!(ok + faulted + queue_full, 100, "every request accounted for");
    assert!(ok > 0, "flood must mostly succeed");
    assert_eq!(server.router().admission("m").unwrap().pending(), 0, "leaked admission slots");

    let m = server.metrics();
    let rejected = m.counters.rejected.load(Ordering::Relaxed);
    let rejected_full = m.counters.rejected_queue_full.load(Ordering::Relaxed);
    assert_eq!(rejected, queue_full as u64);
    assert_eq!(rejected_full, queue_full as u64);
    assert_eq!(m.counters.completed.load(Ordering::Relaxed), ok as u64);
    let stats = m.faults.stats("m").unwrap();
    assert!(stats.faults_contained.load(Ordering::Relaxed) >= 2, "both panics contained");
    assert!(stats.worker_respawns.load(Ordering::Relaxed) >= 2);
    server.shutdown();
}

#[test]
fn slo_classes_gate_admission_and_route_latency_accounting() {
    // gold=5ms is infeasible even on an idle tenant (one batch walk is
    // 40 ms); bulk=10s always fits. Unknown classes are typed errors.
    let g = gate();
    let policy = BatchPolicy { max_batch: 4, ..BatchPolicy::default() };
    let t = tenant("m", gated_factory(g.clone(), 4, Duration::ZERO), policy, Some(10.0));
    let slo = SloTable::parse("gold=5,bulk=10000").unwrap();
    let server = Server::start_tenants(vec![t], slo).unwrap();

    let bulk = RequestOptions { class: Some("bulk".into()), ..RequestOptions::default() };
    let rx = server.router().submit_with("m", vec![1.0; 4], bulk).unwrap();

    let gold = RequestOptions { class: Some("gold".into()), ..RequestOptions::default() };
    match server.router().submit_with("m", vec![1.0; 4], gold) {
        Err(Error::Rejected(Rejected::DeadlineInfeasible { deadline_ms, .. })) => {
            assert!((deadline_ms - 5.0).abs() < 1e-9);
        }
        other => panic!("gold must shed on an idle-but-slow tenant, got {:?}", other.is_ok()),
    }

    let silver = RequestOptions { class: Some("silver".into()), ..RequestOptions::default() };
    match server.router().submit_with("m", vec![1.0; 4], silver) {
        Err(Error::Rejected(Rejected::UnknownClass { class })) => assert_eq!(class, "silver"),
        other => panic!("unknown class must be typed, got {:?}", other.is_ok()),
    }

    open(&g);
    let resp = rx.recv().unwrap().unwrap();
    assert!(resp.deadline_met, "a 10 s bulk deadline should be met");
    let m = server.metrics();
    assert_eq!(m.by_class.histogram("bulk").unwrap().count(), 1);
    assert_eq!(m.by_class.histogram("gold").unwrap().count(), 0);
    let summary = m.summary();
    assert!(summary.contains("deadline=1"), "per-reason breakdown missing: {summary}");
    server.shutdown();
}
