//! Map-major data layout (paper section IV.B) and the zero-overhead OFM
//! index equations (3)–(5).
//!
//! Conventional ("row-major") feature maps are `(C, H, W)` C-order;
//! map-major groups channels into stacks of `u` with the `u` channel
//! values of one spatial position contiguous: `(Cb, H, W, u)` with
//! `Cb = ceil(C/u)` (zero-padded). Weights reorder from `(M, C, K, K)`
//! to `(Mb, u, Cb, K, K, u)` at compile time. Mirrors
//! `python/compile/kernels/ref.py` exactly.
//!
//! ## Packed panels (compiled-plan layout)
//!
//! The `(Mb, u, Cb, K, K, u)` layout still makes the conv inner loop
//! gather its `u_out x u_in` tap block with `u` strided loads per tap
//! (the `ol` rows sit `Cb*K*K*u` apart). The compiled plan repacks one
//! step further at `PlanBuilder::build`:
//!
//! * [`pack_conv_panels`] — **tap-major panels**: for each output stack
//!   `ms`, the taps `(cs, kh, kw)` are laid out in exactly the order the
//!   kernel walks them, each tap a contiguous `u x u` block stored
//!   **input-lane-major**. Index formula:
//!   `packed[((((ms*Cb + cs)*K + kh)*K + kw)*u + il)*u + ol]`
//!   holds the weight of output channel `ms*u + ol` against input
//!   channel `cs*u + il` at tap `(kh, kw)` — the hot loop streams
//!   weights strictly sequentially, zero per-tap gathers, and each
//!   input lane's `u` output-lane weights are one contiguous
//!   lane-width register load ([`crate::engine::simd`]): the tap block
//!   *is* the vector register tile.
//! * [`pack_dense_panels`] — **column-blocked panels**: output rows are
//!   grouped in blocks of [`DENSE_BLOCK`] and interleaved by column:
//!   `packed[(ob*I + col)*B + ol]` = `w[(ob*B + ol)*I + col]`
//!   (zero-padded past `O`), so one pass over the activation vector
//!   feeds `B` output neurons from sequential weight reads.
//!
//! Both repacks are pure permutations (values untouched), so packing
//! commutes with the arithmetic-mode weight bake and the packed kernels
//! stay bitwise identical to the unpacked oracles.

use crate::util::{ceil_div, round_up};

/// Output-row block width of [`pack_dense_panels`]: how many dense
/// output neurons share one pass over the activation vector.
pub const DENSE_BLOCK: usize = 4;

/// Thread-id → `(w, h, m)` of the paper's equations (3), (4), (5).
///
/// Thread `x` writes its output at linear offset `x`, which by
/// construction is the map-major location of element `(m, h, w)` — the
/// "zero-overhead dynamic reordering of OFMs".
#[inline]
pub fn thread_index_to_whm(x: usize, u: usize, wout: usize, hout: usize) -> (usize, usize, usize) {
    let w = (x / u) % wout; // eq. (3)
    let h = (x / (u * wout)) % hout; // eq. (4)
    let m = (x % u) + (x / (u * wout * hout)) * u; // eq. (5)
    (w, h, m)
}

/// Inverse: map-major linear offset of element `(m, h, w)`.
#[inline]
pub fn whm_to_thread_index(w: usize, h: usize, m: usize, u: usize, wout: usize, hout: usize) -> usize {
    let stack = m / u;
    let lane = m % u;
    lane + u * (w + wout * (h + hout * stack))
}

/// `(C, H, W)` row-major → `(Cb, H, W, u)` map-major (channel-padded).
pub fn nchw_to_mapmajor(src: &[f32], c: usize, h: usize, w: usize, u: usize) -> Vec<f32> {
    let cb = ceil_div(c, u);
    let mut out = vec![0.0f32; cb * h * w * u];
    nchw_to_mapmajor_into(src, c, h, w, u, &mut out);
    out
}

/// In-place variant of [`nchw_to_mapmajor`] writing into a caller-owned
/// buffer — the compiled plan's input prologue. Overwrites `dst`
/// completely (channel-padding lanes are zeroed every call).
pub fn nchw_to_mapmajor_into(src: &[f32], c: usize, h: usize, w: usize, u: usize, dst: &mut [f32]) {
    assert_eq!(src.len(), c * h * w, "nchw_to_mapmajor: src len");
    let cb = ceil_div(c, u);
    assert_eq!(dst.len(), cb * h * w * u, "nchw_to_mapmajor: dst len");
    if c % u != 0 {
        dst.fill(0.0);
    }
    for ci in 0..c {
        let (stack, lane) = (ci / u, ci % u);
        for hi in 0..h {
            for wi in 0..w {
                dst[((stack * h + hi) * w + wi) * u + lane] = src[(ci * h + hi) * w + wi];
            }
        }
    }
}

/// `(Cb, H, W, u)` map-major → `(C, H, W)` row-major, dropping padding.
pub fn mapmajor_to_nchw(src: &[f32], c: usize, h: usize, w: usize, u: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; c * h * w];
    mapmajor_to_nchw_into(src, c, h, w, u, &mut out);
    out
}

/// In-place variant of [`mapmajor_to_nchw`] writing into a caller-owned
/// row — the compiled plan's batched output epilogue (one call per live
/// batch lane, zero allocation).
pub fn mapmajor_to_nchw_into(src: &[f32], c: usize, h: usize, w: usize, u: usize, dst: &mut [f32]) {
    let cb = ceil_div(c, u);
    assert_eq!(src.len(), cb * h * w * u, "mapmajor_to_nchw: src len");
    assert_eq!(dst.len(), c * h * w, "mapmajor_to_nchw: dst len");
    for ci in 0..c {
        let (stack, lane) = (ci / u, ci % u);
        for hi in 0..h {
            for wi in 0..w {
                dst[(ci * h + hi) * w + wi] = src[((stack * h + hi) * w + wi) * u + lane];
            }
        }
    }
}

/// Weights `(M, C, K, K)` → `(Mb, u, Cb, K, K, u)` (compile-time reorder,
/// paper section III: "parameter reordering ... occurs during
/// compile-time").
pub fn weights_to_mapmajor(src: &[f32], m: usize, c: usize, k: usize, u: usize) -> Vec<f32> {
    assert_eq!(src.len(), m * c * k * k, "weights_to_mapmajor: src len");
    let mb = ceil_div(m, u);
    let cb = ceil_div(c, u);
    let mut out = vec![0.0f32; mb * u * cb * k * k * u];
    for mi in 0..m {
        let (ms, ml) = (mi / u, mi % u);
        for ci in 0..c {
            let (cs, cl) = (ci / u, ci % u);
            for kh in 0..k {
                for kw in 0..k {
                    let dst = ((((ms * u + ml) * cb + cs) * k + kh) * k + kw) * u + cl;
                    out[dst] = src[((mi * c + ci) * k + kh) * k + kw];
                }
            }
        }
    }
    out
}

/// Map-major conv weights `(Mb, u, Cb, K, K, u)` → tap-major packed
/// panels `(Mb, Cb, K, K, u_in, u_out)` (see the module docs for the
/// index formula). Plan-compile time only: the packed kernels read each
/// tap's `u x u` block as one contiguous `u*u` slice and walk taps
/// sequentially, so the per-tap gather of the unpacked layout vanishes;
/// within the tap, input lane `il`'s `u` output-lane weights are
/// contiguous — one lane-width register load per input lane.
pub fn pack_conv_panels(w_mm: &[f32], mb: usize, cb: usize, k: usize, u: usize) -> Vec<f32> {
    pack_conv_panels_impl(w_mm, mb, cb, k, u)
}

/// [`pack_conv_panels`] over quantized `i8` weights — identical
/// permutation, so the int8 kernels walk the exact same panel order.
pub fn pack_conv_panels_i8(w_mm: &[i8], mb: usize, cb: usize, k: usize, u: usize) -> Vec<i8> {
    pack_conv_panels_impl(w_mm, mb, cb, k, u)
}

fn pack_conv_panels_impl<T: Copy + Default>(
    w_mm: &[T],
    mb: usize,
    cb: usize,
    k: usize,
    u: usize,
) -> Vec<T> {
    assert_eq!(w_mm.len(), mb * u * cb * k * k * u, "pack_conv_panels: src len");
    let mut out = vec![T::default(); w_mm.len()];
    for ms in 0..mb {
        for cs in 0..cb {
            for kh in 0..k {
                for kw in 0..k {
                    let tap = (((ms * cb + cs) * k + kh) * k + kw) * u * u;
                    for ol in 0..u {
                        let src = ((((ms * u + ol) * cb + cs) * k + kh) * k + kw) * u;
                        for il in 0..u {
                            out[tap + il * u + ol] = w_mm[src + il];
                        }
                    }
                }
            }
        }
    }
    out
}

/// Dense weights `(O, I)` row-major → column-blocked panels
/// `(Ob, I, B)` with `B =` [`DENSE_BLOCK`], `Ob = ceil(O/B)`,
/// zero-padded past `O` (see the module docs for the index formula).
pub fn pack_dense_panels(w: &[f32], o: usize, i: usize) -> Vec<f32> {
    pack_dense_panels_impl(w, o, i)
}

/// [`pack_dense_panels`] over quantized `i8` weights — identical
/// permutation.
pub fn pack_dense_panels_i8(w: &[i8], o: usize, i: usize) -> Vec<i8> {
    pack_dense_panels_impl(w, o, i)
}

fn pack_dense_panels_impl<T: Copy + Default>(w: &[T], o: usize, i: usize) -> Vec<T> {
    assert_eq!(w.len(), o * i, "pack_dense_panels: src len");
    let ob = ceil_div(o, DENSE_BLOCK);
    let mut out = vec![T::default(); ob * i * DENSE_BLOCK];
    for oi in 0..o {
        let (blk, ol) = (oi / DENSE_BLOCK, oi % DENSE_BLOCK);
        for col in 0..i {
            out[(blk * i + col) * DENSE_BLOCK + ol] = w[oi * i + col];
        }
    }
    out
}

/// Bias `(M,)` → `(Mb, u)` zero-padded.
pub fn bias_to_mapmajor(src: &[f32], u: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; round_up(src.len(), u)];
    out[..src.len()].copy_from_slice(src);
    out
}

/// FC weight columns `(O, I)` with `I = c*h*w` row-major-flatten order →
/// `(O, Ib)` consuming the map-major flatten order (`Ib = cb*u*h*w`).
/// Compile-time only; mirrors `kernels/dense.fc_weights_for_mapmajor`.
pub fn fc_weights_for_mapmajor(
    src: &[f32],
    o: usize,
    c: usize,
    h: usize,
    w: usize,
    u: usize,
) -> Vec<f32> {
    assert_eq!(src.len(), o * c * h * w, "fc_weights_for_mapmajor: src len");
    let cb = ceil_div(c, u);
    let ib = cb * h * w * u;
    let mut out = vec![0.0f32; o * ib];
    for oi in 0..o {
        for ci in 0..c {
            let (stack, lane) = (ci / u, ci % u);
            for hi in 0..h {
                for wi in 0..w {
                    let dst_col = ((stack * h + hi) * w + wi) * u + lane;
                    out[oi * ib + dst_col] = src[oi * c * h * w + (ci * h + hi) * w + wi];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn eqs_3_4_5_bijection() {
        for &(u, wout, hout, stacks) in &[(4, 5, 3, 2), (2, 7, 4, 3), (1, 3, 3, 1), (8, 2, 2, 2)] {
            let total = u * wout * hout * stacks;
            let mut seen = vec![false; total];
            for x in 0..total {
                let (w, h, m) = thread_index_to_whm(x, u, wout, hout);
                assert!(w < wout && h < hout && m < stacks * u);
                assert_eq!(whm_to_thread_index(w, h, m, u, wout, hout), x);
                let key = (m * hout + h) * wout + w;
                assert!(!seen[key], "duplicate mapping at x={x}");
                seen[key] = true;
            }
        }
    }

    #[test]
    fn paper_example_second_thread() {
        // Section IV.B.1: thread x=1 must produce (m=1, h=0, w=0).
        let (w, h, m) = thread_index_to_whm(1, 4, 5, 5);
        assert_eq!((m, h, w), (1, 0, 0));
    }

    #[test]
    fn nchw_mapmajor_roundtrip() {
        let mut rng = Rng::new(1);
        for &(c, h, w, u) in &[(3, 4, 5, 4), (8, 3, 3, 4), (5, 2, 2, 2), (7, 4, 4, 8)] {
            let src = rng.normal_vec(c * h * w);
            let mm = nchw_to_mapmajor(&src, c, h, w, u);
            assert_eq!(mm.len(), ceil_div(c, u) * h * w * u);
            let back = mapmajor_to_nchw(&mm, c, h, w, u);
            assert_eq!(back, src);
        }
    }

    #[test]
    fn mapmajor_matches_eq2_order() {
        // Paper eq. (2): (0,0,0),(1,0,0),(2,0,0),(3,0,0),(0,0,1),...
        let (c, h, w, u) = (8, 2, 3, 4);
        let src: Vec<f32> = (0..c * h * w).map(|i| i as f32).collect();
        let mm = nchw_to_mapmajor(&src, c, h, w, u);
        let elem = |ch: usize, row: usize, col: usize| src[(ch * h + row) * w + col];
        assert_eq!(&mm[..4], &[elem(0, 0, 0), elem(1, 0, 0), elem(2, 0, 0), elem(3, 0, 0)]);
        assert_eq!(&mm[4..8], &[elem(0, 0, 1), elem(1, 0, 1), elem(2, 0, 1), elem(3, 0, 1)]);
        // Second stack starts after the entire first stack.
        assert_eq!(mm[h * w * u], elem(4, 0, 0));
    }

    #[test]
    fn mapmajor_offset_agrees_with_index_equations() {
        let (m_total, hout, wout, u) = (8, 3, 4, 4);
        let src: Vec<f32> = (0..m_total * hout * wout).map(|i| i as f32).collect();
        let mm = nchw_to_mapmajor(&src, m_total, hout, wout, u);
        for (x, v) in mm.iter().enumerate() {
            let (w, h, m) = thread_index_to_whm(x, u, wout, hout);
            assert_eq!(*v, src[(m * hout + h) * wout + w]);
        }
    }

    #[test]
    fn weight_reorder_places_every_tap() {
        let mut rng = Rng::new(2);
        let (m, c, k, u) = (6, 5, 3, 4);
        let src = rng.normal_vec(m * c * k * k);
        let mm = weights_to_mapmajor(&src, m, c, k, u);
        let mb = ceil_div(m, u);
        let cb = ceil_div(c, u);
        assert_eq!(mm.len(), mb * u * cb * k * k * u);
        for mi in 0..m {
            for ci in 0..c {
                for kh in 0..k {
                    for kw in 0..k {
                        let dst = (((((mi / u) * u + mi % u) * cb + ci / u) * k + kh) * k + kw) * u
                            + ci % u;
                        assert_eq!(mm[dst], src[((mi * c + ci) * k + kh) * k + kw]);
                    }
                }
            }
        }
        // Padding lanes are zero.
        for cs in 0..cb {
            for lane in 0..u {
                let ci = cs * u + lane;
                if ci >= c {
                    for ms in 0..mb * u {
                        for kh in 0..k {
                            for kw in 0..k {
                                let dst = (((ms * cb + cs) * k + kh) * k + kw) * u + lane;
                                assert_eq!(mm[dst], 0.0);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn conv_panels_place_every_tap_contiguously() {
        let mut rng = Rng::new(7);
        for &(m, c, k, u) in &[(6usize, 5usize, 3usize, 4usize), (8, 8, 1, 4), (3, 7, 5, 2), (4, 4, 3, 1)] {
            let src = rng.normal_vec(m * c * k * k);
            let mm = weights_to_mapmajor(&src, m, c, k, u);
            let (mb, cb) = (ceil_div(m, u), ceil_div(c, u));
            let packed = pack_conv_panels(&mm, mb, cb, k, u);
            assert_eq!(packed.len(), mm.len());
            // Every (mi, ci, kh, kw) weight lands at the documented
            // packed index (input-lane-major tap block); padding lanes
            // stay zero.
            for ms in 0..mb {
                for cs in 0..cb {
                    for kh in 0..k {
                        for kw in 0..k {
                            for ol in 0..u {
                                for il in 0..u {
                                    let dst = ((((ms * cb + cs) * k + kh) * k + kw) * u + il)
                                        * u
                                        + ol;
                                    let (mi, ci) = (ms * u + ol, cs * u + il);
                                    let want = if mi < m && ci < c {
                                        src[((mi * c + ci) * k + kh) * k + kw]
                                    } else {
                                        0.0
                                    };
                                    assert_eq!(packed[dst], want, "m{mi} c{ci} {kh},{kw}");
                                }
                            }
                        }
                    }
                }
            }
            // The i8 packer applies the identical permutation.
            let q: Vec<i8> = (0..mm.len()).map(|v| (v % 251) as i8).collect();
            let qp = pack_conv_panels_i8(&q, mb, cb, k, u);
            let fp = pack_conv_panels(
                &q.iter().map(|&v| v as f32).collect::<Vec<_>>(),
                mb,
                cb,
                k,
                u,
            );
            assert!(qp.iter().zip(&fp).all(|(&a, &b)| a as f32 == b));
        }
    }

    #[test]
    fn dense_panels_preserve_dot_products() {
        let mut rng = Rng::new(8);
        for &(o, i) in &[(8usize, 12usize), (5, 7), (1, 3), (4, 4)] {
            let w = rng.normal_vec(o * i);
            let x = rng.normal_vec(i);
            let packed = pack_dense_panels(&w, o, i);
            assert_eq!(packed.len(), ceil_div(o, DENSE_BLOCK) * i * DENSE_BLOCK);
            for oi in 0..o {
                let want: f32 = (0..i).map(|col| w[oi * i + col] * x[col]).sum();
                let (blk, ol) = (oi / DENSE_BLOCK, oi % DENSE_BLOCK);
                let got: f32 = (0..i)
                    .map(|col| packed[(blk * i + col) * DENSE_BLOCK + ol] * x[col])
                    .sum();
                assert_eq!(got, want, "row {oi}");
            }
            // Padding rows are all-zero.
            for oi in o..ceil_div(o, DENSE_BLOCK) * DENSE_BLOCK {
                let (blk, ol) = (oi / DENSE_BLOCK, oi % DENSE_BLOCK);
                for col in 0..i {
                    assert_eq!(packed[(blk * i + col) * DENSE_BLOCK + ol], 0.0);
                }
            }
            // The i8 packer applies the identical permutation.
            let q: Vec<i8> = (0..o * i).map(|v| (v % 127) as i8).collect();
            let qp = pack_dense_panels_i8(&q, o, i);
            for oi in 0..o {
                let (blk, ol) = (oi / DENSE_BLOCK, oi % DENSE_BLOCK);
                for col in 0..i {
                    assert_eq!(qp[(blk * i + col) * DENSE_BLOCK + ol], q[oi * i + col]);
                }
            }
        }
    }

    #[test]
    fn bias_reorder_pads() {
        let b = bias_to_mapmajor(&[1.0, 2.0, 3.0, 4.0, 5.0], 4);
        assert_eq!(b, vec![1.0, 2.0, 3.0, 4.0, 5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn fc_reorder_preserves_dot_products() {
        let mut rng = Rng::new(3);
        let (o, c, h, w, u) = (5, 6, 3, 4, 4);
        let x = rng.normal_vec(c * h * w);
        let wt = rng.normal_vec(o * c * h * w);
        let x_mm = nchw_to_mapmajor(&x, c, h, w, u);
        let wt_mm = fc_weights_for_mapmajor(&wt, o, c, h, w, u);
        let ib = x_mm.len();
        for oi in 0..o {
            let want: f32 = (0..c * h * w).map(|i| wt[oi * c * h * w + i] * x[i]).sum();
            let got: f32 = (0..ib).map(|i| wt_mm[oi * ib + i] * x_mm[i]).sum();
            assert!((want - got).abs() < 1e-4, "row {oi}: {want} vs {got}");
        }
    }
}
