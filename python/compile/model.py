"""Layer-2 model zoo: the three CNNs of the paper's evaluation (AlexNet,
SqueezeNet v1.0, GoogLeNet) plus TinyNet, the small net trained at build
time for the inexact-computing study.

Each network is a declarative *spec* — a list of layer dicts — which is
the single source of truth shared with the Rust side: ``aot.py`` embeds
the spec in the artifact manifest, and ``rust/src/model`` mirrors the
same builders (cross-checked by integration tests). From a spec we
derive:

* shape inference (:func:`infer_shapes`),
* conventional-layout parameter initialisation (:func:`init_params`),
* compile-time map-major parameter reordering (:func:`reorder_params`),
* the jittable map-major forward function (:func:`build_apply`) whose
  conv / dense layers run the Layer-1 Pallas kernels.

Supported layer ops::

  {"op": "conv", "name", "m", "k", "s", "p", "relu"}
  {"op": "maxpool" | "avgpool", "k", "s", "p"}
  {"op": "lrn", "size", "alpha", "beta"}
  {"op": "fire", "name", "s1", "e1", "e3"}            # SqueezeNet
  {"op": "inception", "name", "b1", "b3r", "b3", "b5r", "b5", "pp"}
  {"op": "flatten"} | {"op": "gap"}
  {"op": "dense", "name", "o", "relu"}
  {"op": "softmax"}

``fire`` and ``inception`` are composites that expand into convs with
derived names (e.g. ``fire2/s1``, ``inc3a/b3``); mode assignments address
the expanded names.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import dense as kdense
from .kernels import ref


# ---------------------------------------------------------------------------
# Network specs
# ---------------------------------------------------------------------------

def conv_l(name, m, k, s=1, p=0, relu=True):
    return {"op": "conv", "name": name, "m": m, "k": k, "s": s, "p": p,
            "relu": relu}


def tinynet_spec():
    """Small CNN for the synthetic 8-class dataset; all widths divide 16."""
    return [
        conv_l("conv1", 16, 3, 1, 1),
        {"op": "maxpool", "k": 2, "s": 2, "p": 0},
        conv_l("conv2", 32, 3, 1, 1),
        {"op": "maxpool", "k": 2, "s": 2, "p": 0},
        conv_l("conv3", 32, 3, 1, 1),
        {"op": "flatten"},
        {"op": "dense", "name": "fc4", "o": 64, "relu": True},
        {"op": "dense", "name": "fc5", "o": 8, "relu": False},
    ]


def alexnet_spec():
    """AlexNet (CaffeNet single-tower variant, group=1 — see DESIGN.md)."""
    return [
        conv_l("conv1", 96, 11, 4, 0),
        {"op": "lrn", "size": 5, "alpha": 1e-4, "beta": 0.75},
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        conv_l("conv2", 256, 5, 1, 2),
        {"op": "lrn", "size": 5, "alpha": 1e-4, "beta": 0.75},
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        conv_l("conv3", 384, 3, 1, 1),
        conv_l("conv4", 384, 3, 1, 1),
        conv_l("conv5", 256, 3, 1, 1),
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        {"op": "flatten"},
        {"op": "dense", "name": "fc6", "o": 4096, "relu": True},
        {"op": "dense", "name": "fc7", "o": 4096, "relu": True},
        {"op": "dense", "name": "fc8", "o": 1000, "relu": False},
    ]


def squeezenet_spec():
    """SqueezeNet v1.0 (Iandola et al. 2016), as evaluated in the paper."""
    def fire(name, s1, e1, e3):
        return {"op": "fire", "name": name, "s1": s1, "e1": e1, "e3": e3}
    return [
        conv_l("conv1", 96, 7, 2, 0),
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        fire("fire2", 16, 64, 64),
        fire("fire3", 16, 64, 64),
        fire("fire4", 32, 128, 128),
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        fire("fire5", 32, 128, 128),
        fire("fire6", 48, 192, 192),
        fire("fire7", 48, 192, 192),
        fire("fire8", 64, 256, 256),
        {"op": "maxpool", "k": 3, "s": 2, "p": 0},
        fire("fire9", 64, 256, 256),
        conv_l("conv10", 1000, 1, 1, 0),
        {"op": "gap"},
    ]


def googlenet_spec():
    """GoogLeNet / Inception-v1 (Szegedy et al. 2015), main branch only.

    Caffe's ceil-mode pools are emulated with pad=1 floor pools so the
    spatial sizes match the reference (56/28/14/7); the auxiliary
    classifier heads are train-time only and omitted for inference.
    """
    def inc(name, b1, b3r, b3, b5r, b5, pp):
        return {"op": "inception", "name": name, "b1": b1, "b3r": b3r,
                "b3": b3, "b5r": b5r, "b5": b5, "pp": pp}
    return [
        conv_l("conv1", 64, 7, 2, 3),
        {"op": "maxpool", "k": 3, "s": 2, "p": 1},
        {"op": "lrn", "size": 5, "alpha": 1e-4, "beta": 0.75},
        conv_l("conv2r", 64, 1, 1, 0),
        conv_l("conv2", 192, 3, 1, 1),
        {"op": "lrn", "size": 5, "alpha": 1e-4, "beta": 0.75},
        {"op": "maxpool", "k": 3, "s": 2, "p": 1},
        inc("inc3a", 64, 96, 128, 16, 32, 32),
        inc("inc3b", 128, 128, 192, 32, 96, 64),
        {"op": "maxpool", "k": 3, "s": 2, "p": 1},
        inc("inc4a", 192, 96, 208, 16, 48, 64),
        inc("inc4b", 160, 112, 224, 24, 64, 64),
        inc("inc4c", 128, 128, 256, 24, 64, 64),
        inc("inc4d", 112, 144, 288, 32, 64, 64),
        inc("inc4e", 256, 160, 320, 32, 128, 128),
        {"op": "maxpool", "k": 3, "s": 2, "p": 1},
        inc("inc5a", 256, 160, 320, 32, 128, 128),
        inc("inc5b", 384, 192, 384, 48, 128, 128),
        {"op": "gap"},
        {"op": "dense", "name": "fc", "o": 1000, "relu": False},
    ]


NETS = {
    "tinynet": (tinynet_spec, (3, 16, 16), 8),
    "alexnet": (alexnet_spec, (3, 227, 227), 1000),
    "squeezenet": (squeezenet_spec, (3, 227, 227), 1000),
    "googlenet": (googlenet_spec, (3, 224, 224), 1000),
}


# ---------------------------------------------------------------------------
# Composite expansion: every spec reduces to primitive layers
# ---------------------------------------------------------------------------

def expand(spec):
    """Expand fire/inception composites into primitive layers.

    The result is a linear list whose only structural op is ``fork``:
    ``{"op":"fork", "name", "branches": [[primitive...], ...]}`` — the
    branch outputs are channel-concatenated. Both the JAX apply and the
    Rust IR interpret this identically.
    """
    out = []
    for lay in spec:
        op = lay["op"]
        if op == "fire":
            n = lay["name"]
            out.append(conv_l(f"{n}/s1", lay["s1"], 1))
            out.append({"op": "fork", "name": n, "branches": [
                [conv_l(f"{n}/e1", lay["e1"], 1)],
                [conv_l(f"{n}/e3", lay["e3"], 3, 1, 1)],
            ]})
        elif op == "inception":
            n = lay["name"]
            out.append({"op": "fork", "name": n, "branches": [
                [conv_l(f"{n}/b1", lay["b1"], 1)],
                [conv_l(f"{n}/b3r", lay["b3r"], 1),
                 conv_l(f"{n}/b3", lay["b3"], 3, 1, 1)],
                [conv_l(f"{n}/b5r", lay["b5r"], 1),
                 conv_l(f"{n}/b5", lay["b5"], 5, 1, 2)],
                [{"op": "maxpool", "k": 3, "s": 1, "p": 1},
                 conv_l(f"{n}/pp", lay["pp"], 1)],
            ]})
        else:
            out.append(dict(lay))
    return out


def conv_dense_names(spec):
    """Names of every mode-assignable (conv or dense) layer, in order."""
    names = []
    for lay in expand(spec):
        if lay["op"] in ("conv", "dense"):
            names.append(lay["name"])
        elif lay["op"] == "fork":
            for br in lay["branches"]:
                names.extend(l["name"] for l in br if l["op"] == "conv")
    return names


# ---------------------------------------------------------------------------
# Shape inference over a spec (conventional C,H,W bookkeeping)
# ---------------------------------------------------------------------------

def _infer_seq(lays, shape):
    """Run shape inference over a primitive-layer list; returns out shape."""
    for lay in lays:
        op = lay["op"]
        if op == "conv":
            c, h, w = shape
            ho = ref.conv_out_size(h, lay["k"], lay["s"], lay["p"])
            wo = ref.conv_out_size(w, lay["k"], lay["s"], lay["p"])
            shape = (lay["m"], ho, wo)
        elif op in ("maxpool", "avgpool"):
            c, h, w = shape
            ho = ref.conv_out_size(h, lay["k"], lay["s"], lay["p"])
            wo = ref.conv_out_size(w, lay["k"], lay["s"], lay["p"])
            shape = (c, ho, wo)
        elif op == "lrn":
            pass
        elif op == "fork":
            outs = [_infer_seq(br, shape) for br in lay["branches"]]
            h, w = outs[0][1], outs[0][2]
            assert all(o[1:] == (h, w) for o in outs), \
                f"fork {lay['name']}: branch spatial mismatch {outs}"
            shape = (sum(o[0] for o in outs), h, w)
        elif op == "flatten":
            c, h, w = shape
            shape = (c * h * w,)
        elif op == "gap":
            shape = (shape[0],)
        elif op == "dense":
            shape = (lay["o"],)
        elif op == "softmax":
            pass
        else:
            raise ValueError(f"unknown op {op}")
    return shape


def infer_shapes(spec, input_shape):
    """Per-layer *input* shapes keyed by conv/dense name.

    Returns ``(out_shape, by_name)`` where ``by_name[name]`` is the input
    shape ``(C, H, W)`` (or ``(I,)`` for dense) of that layer — what the
    parameter reorder needs.
    """
    by_name = {}

    def walk(lays, shape):
        for lay in lays:
            op = lay["op"]
            if op in ("conv", "dense"):
                by_name[lay["name"]] = shape
            if op == "fork":
                outs = [walk(br, shape) for br in lay["branches"]]
                shape = (sum(o[0] for o in outs), outs[0][1], outs[0][2])
            else:
                shape = _infer_seq([lay], shape)
        return shape

    out = walk(expand(spec), input_shape)
    return out, by_name


# ---------------------------------------------------------------------------
# Parameters: init (conventional), reorder (map-major)
# ---------------------------------------------------------------------------

def init_params(spec, input_shape, key):
    """He-normal conventional-layout params: ``{name: (w, b)}``."""
    _, by_name = infer_shapes(spec, input_shape)
    params = {}

    def walk(lays):
        nonlocal key
        for lay in lays:
            if lay["op"] == "conv":
                key, sub = jax.random.split(key)
                c = by_name[lay["name"]][0]
                params[lay["name"]] = L.init_conv(sub, lay["m"], c, lay["k"])
            elif lay["op"] == "dense":
                key, sub = jax.random.split(key)
                i = by_name[lay["name"]][0]
                params[lay["name"]] = L.init_dense(sub, lay["o"], i)
            elif lay["op"] == "fork":
                for br in lay["branches"]:
                    walk(br)

    walk(expand(spec))
    return params


def _first_dense_after_flatten(spec):
    seen_flatten = False
    for lay in expand(spec):
        if lay["op"] == "flatten":
            seen_flatten = True
        elif lay["op"] == "dense" and seen_flatten:
            return lay["name"]
    return None


def _shape_before_flatten(spec, input_shape):
    shape = input_shape
    for lay in expand(spec):
        if lay["op"] == "flatten":
            return shape
        shape = _infer_seq([lay], shape)
    return None


def reorder_params(spec, input_shape, params, u):
    """Compile-time parameter reordering (section III): conventional ->
    map-major. Conv weights become ``(Mb,u,Cb,K,K,u)``; the *first* dense
    after a flatten gets its columns permuted to consume the map-major
    flatten order; later dense layers are 1-D in / 1-D out and unchanged.
    """
    out = {}
    first_fc = _first_dense_after_flatten(spec)
    flat_shape = _shape_before_flatten(spec, input_shape)
    for name, (w, b) in params.items():
        if w.ndim == 4:
            out[name] = (ref.weights_to_mapmajor(w, u),
                         ref.bias_to_mapmajor(b, u))
        else:
            if name == first_fc:
                c, h, wd = flat_shape
                w = kdense.fc_weights_for_mapmajor(w, c, h, wd, u)
            out[name] = (w, b)
    return out


def param_order(spec):
    """Deterministic parameter flattening order for AOT argument lists."""
    return conv_dense_names(spec)


# ---------------------------------------------------------------------------
# Forward pass (map-major, Pallas kernels)
# ---------------------------------------------------------------------------

def build_apply(spec, input_shape, u):
    """Build the jittable map-major forward function.

    Returns ``apply(params_mm, x_mm, modes)`` where ``x_mm`` is
    ``(B, Cb, H, W, u)`` and ``modes`` is a ``{layer_name: mode}`` dict
    (missing names default to precise) or a single mode string for all
    layers. The returned logits are ``(B, num_classes)`` float32.
    """
    prim = expand(spec)

    def mode_of(modes, name):
        if isinstance(modes, str):
            return modes
        return (modes or {}).get(name, "precise")

    def run(lays, params, x, modes):
        for lay in lays:
            op = lay["op"]
            if op == "conv":
                w, b = params[lay["name"]]
                x = L.conv(x, w, b, stride=lay["s"], pad=lay["p"],
                           mode=mode_of(modes, lay["name"]),
                           relu=lay["relu"])
            elif op == "maxpool":
                x = L.maxpool(x, lay["k"], lay["s"], lay["p"])
            elif op == "avgpool":
                x = L.avgpool(x, lay["k"], lay["s"], lay["p"])
            elif op == "lrn":
                x = L.lrn(x, size=lay["size"], alpha=lay["alpha"],
                          beta=lay["beta"])
            elif op == "fork":
                outs = [run(br, params, x, modes) for br in lay["branches"]]
                x = L.concat_channels(outs)
            elif op == "flatten":
                x = L.flatten(x)
            elif op == "gap":
                x = L.global_avgpool(x)
            elif op == "dense":
                w, b = params[lay["name"]]
                x = L.dense(x, w, b, mode=mode_of(modes, lay["name"]),
                            relu=lay["relu"])
            elif op == "softmax":
                x = L.softmax(x)
        return x

    def apply(params_mm, x_mm, modes=None):
        return run(prim, params_mm, x_mm, modes)

    return apply


# ---------------------------------------------------------------------------
# Conventional-layout reference forward pass (oracle for tests)
# ---------------------------------------------------------------------------

def forward_nchw_ref(spec, params, x_nchw, mode="precise"):
    """Pure-jnp NCHW forward pass; must agree with the map-major Pallas
    path to float tolerance for every net."""
    prim = expand(spec)

    def run(lays, x):
        for lay in lays:
            op = lay["op"]
            if op == "conv":
                w, b = params[lay["name"]]
                x = jnp.stack([ref.conv2d_nchw(xi, w, b, stride=lay["s"],
                                               pad=lay["p"], mode=mode)
                               for xi in x])
                if lay["relu"]:
                    x = jnp.maximum(x, 0.0)
            elif op in ("maxpool", "avgpool"):
                k, s, p = lay["k"], lay["s"], lay["p"]
                pv = -jnp.inf if op == "maxpool" else 0.0
                xp = jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)),
                             constant_values=pv) if p else x
                h, w_ = xp.shape[2], xp.shape[3]
                ho, wo = (h - k) // s + 1, (w_ - k) // s + 1
                acc = None
                for kh in range(k):
                    for kw in range(k):
                        sl = xp[:, :, kh: kh + (ho - 1) * s + 1: s,
                                kw: kw + (wo - 1) * s + 1: s]
                        if op == "maxpool":
                            acc = sl if acc is None else jnp.maximum(acc, sl)
                        else:
                            acc = sl if acc is None else acc + sl
                x = acc if op == "maxpool" else acc / float(k * k)
            elif op == "lrn":
                size, alpha, beta = lay["size"], lay["alpha"], lay["beta"]
                sq = x * x
                half = size // 2
                pad = jnp.pad(sq, ((0, 0), (half, half), (0, 0), (0, 0)))
                ssum = jnp.zeros_like(x)
                for o in range(size):
                    ssum = ssum + pad[:, o: o + x.shape[1]]
                x = x / (1.0 + alpha / size * ssum) ** beta
            elif op == "fork":
                outs = [run(br, x) for br in lay["branches"]]
                x = jnp.concatenate(outs, axis=1)
            elif op == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif op == "gap":
                x = x.mean(axis=(2, 3))
            elif op == "dense":
                w, b = params[lay["name"]]
                x = jnp.stack([ref.dense_ref(xi, w, b, mode=mode)
                               for xi in x])
                if lay["relu"]:
                    x = jnp.maximum(x, 0.0)
            elif op == "softmax":
                x = jax.nn.softmax(x, axis=-1)
        return x

    return run(prim, x_nchw)
