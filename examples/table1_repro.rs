//! Table I reproduction, side by side with the paper's measurements.
//!
//! Regenerates the paper's main result table (execution time for
//! AlexNet / SqueezeNet / GoogLeNet on three phones under baseline /
//! parallel / imprecise, plus overall speedup) on the SoC simulator,
//! using the paper's 100-sample trimmed-mean protocol, and prints the
//! paper's numbers next to ours with the deviation ratio.
//!
//! Run: `cargo run --release --example table1_repro`

use cappuccino::bench::Table;
use cappuccino::model::zoo;
use cappuccino::soc::{self, ProcessingMode};

/// Paper Table I (ms): (net, device, baseline, parallel, imprecise).
pub const PAPER_TABLE1: &[(&str, &str, f64, f64, f64)] = &[
    ("alexnet", "Nexus 5", 33848.40, 947.15, 836.32),
    ("alexnet", "Nexus 6P", 8626.0, 512.72, 61.80),
    ("alexnet", "Galaxy S7", 8698.43, 442.97, 127.78),
    ("squeezenet", "Nexus 5", 43932.73, 1302.10, 161.50),
    ("squeezenet", "Nexus 6P", 17299.55, 671.46, 141.30),
    ("squeezenet", "Galaxy S7", 12331.82, 888.91, 150.24),
    ("googlenet", "Nexus 5", 84404.40, 2651.12, 2478.09),
    ("googlenet", "Nexus 6P", 25570.48, 1575.45, 602.28),
    ("googlenet", "Galaxy S7", 21917.67, 1699.42, 686.08),
];

fn main() {
    let mut table = Table::new(&[
        "net", "device", "base(paper)", "base(ours)", "par(paper)", "par(ours)",
        "imp(paper)", "imp(ours)", "speedup(paper)", "speedup(ours)",
    ]);
    let mut min_speedup = f64::INFINITY;
    let mut max_speedup: f64 = 0.0;
    for &(net_name, device_name, p_base, p_par, p_imp) in PAPER_TABLE1 {
        let net = zoo::by_name(net_name).unwrap();
        let device = soc::by_name(device_name).unwrap();
        // The paper's protocol: 100 repetitions, min/max dropped.
        let base = soc::measure_trimmed(&net, &device, ProcessingMode::JavaBaseline, 100, 0.01, 1);
        let par = soc::measure_trimmed(&net, &device, ProcessingMode::Parallel, 100, 0.01, 2);
        let imp = soc::measure_trimmed(&net, &device, ProcessingMode::Imprecise, 100, 0.01, 3);
        let ours_speedup = base / imp;
        min_speedup = min_speedup.min(ours_speedup);
        max_speedup = max_speedup.max(ours_speedup);
        table.row(&[
            net_name.into(),
            device_name.into(),
            format!("{p_base:.0}"),
            format!("{base:.0}"),
            format!("{p_par:.0}"),
            format!("{par:.0}"),
            format!("{p_imp:.0}"),
            format!("{imp:.0}"),
            format!("{:.2}x", p_base / p_imp),
            format!("{ours_speedup:.2}x"),
        ]);
    }
    println!("Table I reproduction (simulated devices; paper numbers inline):\n");
    table.print();
    println!(
        "\nspeedup band: ours {:.1}x..{:.1}x   paper 31.95x..272.03x",
        min_speedup, max_speedup
    );
    println!("(absolute ms are approximate by design — the simulator is an\n\
              analytic roofline calibrated only on the baseline column;\n\
              see DESIGN.md 'Calibration notes' and EXPERIMENTS.md.)");
}
