//! Steady-state allocation accounting, measured with a counting global
//! allocator. One `#[test]` in this binary **on purpose**: the counter
//! is process-global and libtest runs tests on concurrent threads, so a
//! sibling test could pollute the measurement.
//!
//! Contract under test (ISSUE 3 acceptance): after the first walk,
//! `run_batch_into` performs **zero** heap allocations at `threads = 1`
//! for any vector width `u` — the tap block / accumulator tile the
//! generic-`u` kernels used to allocate per output row now live in
//! per-thread arena scratch, and the packed panels need no tap gather
//! at all. The legacy `conv_mm` oracle is also checked to allocate a
//! small constant number of buffers per call instead of one per row.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cappuccino::engine::{ArithMode, EngineParams, MapTensor, ModeAssignment, PlanBuilder};
use cappuccino::layout;
use cappuccino::model::zoo;
use cappuccino::util::rng::Rng;

struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Heap allocation events anywhere in the process while `f` runs.
fn alloc_events(f: impl FnOnce()) -> u64 {
    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
    f();
    ALLOC_EVENTS.load(Ordering::Relaxed) - before
}

/// Minimum over a few repeats: if any single run sees zero events, the
/// measured path itself is allocation-free (stray events can only come
/// from other runtime threads, never be hidden).
fn min_alloc_events(reps: usize, mut f: impl FnMut()) -> u64 {
    (0..reps).map(|_| alloc_events(&mut f)).min().unwrap_or(0)
}

#[test]
fn steady_state_walks_are_alloc_free_for_all_u() {
    // -- Compiled plan: zero allocations per run_batch_into at any u --
    for u in [1usize, 2, 3, 4, 8] {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 7, u).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut plan = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(1)
            .batch(3)
            .build()
            .unwrap();
        let mut rng = Rng::new(11);
        let inputs: Vec<Vec<f32>> =
            (0..3).map(|_| rng.normal_vec(plan.input_len())).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0.0f32; 3 * plan.output_len()];
        plan.run_batch_into(&refs, &mut out).unwrap(); // warm
        let events = min_alloc_events(5, || {
            plan.run_batch_into(&refs, &mut out).unwrap();
        });
        assert_eq!(events, 0, "u={u}: heap allocations on the steady-state batch walk");
        // The plan-side meter agrees: run_batch_into hands out nothing.
        assert_eq!(plan.alloc().bytes(), 0, "u={u}: plan-side meter");
    }

    // -- Legacy generic-u oracle: tap scratch hoisted out of the row
    //    loop — a whole conv_mm call makes a small constant number of
    //    allocations regardless of the output row count --
    let (c, h, w, m, k, s, p, u) = (3usize, 40, 12, 6, 3, 1, 1, 3usize);
    let mut rng = Rng::new(12);
    let input = rng.normal_vec(c * h * w);
    let weights = rng.normal_vec(m * c * k * k);
    let bias = rng.normal_vec(m);
    let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
    let w_mm = layout::weights_to_mapmajor(&weights, m, c, k, u);
    let b_mm = layout::bias_to_mapmajor(&bias, u);
    let events = min_alloc_events(5, || {
        std::hint::black_box(cappuccino::engine::conv_mm(
            &mm_in, &w_mm, &b_mm, m, k, s, p, false, ArithMode::Precise, 1,
        ));
    });
    // ho = 40 output rows: the old per-row tap vec alone would be >= 40
    // events. Now: output tensor + padded input + hoisted scratch rows.
    assert!(
        events < 10,
        "legacy conv_mm allocates per output row again: {events} events for ho=40"
    );
}
