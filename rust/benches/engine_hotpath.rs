//! Bench: the engine's hot path (map-major vectorised convolution) plus
//! the PJRT artifact path, across representative layer geometries and
//! full networks. This is the profile target of the performance pass
//! (EXPERIMENTS.md section "Perf").

use cappuccino::bench::{bench, ms, BenchConfig, Table};
use cappuccino::engine::{conv_mm, ArithMode, EngineParams, ExecConfig, MapTensor, ModeAssignment};
use cappuccino::layout;
use cappuccino::model::zoo;
use cappuccino::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(0x401);

    // -- Kernel-level: conv_mm across geometry classes -------------------
    let mut table = Table::new(&["kernel", "geometry", "time(ms)", "GFLOP/s"]);
    let cases: &[(&str, usize, usize, usize, usize, usize, usize)] = &[
        // (name, c, h, m, k, s, p)
        ("1x1 channel-heavy", 128, 28, 128, 1, 1, 0),
        ("3x3 mid", 64, 28, 64, 3, 1, 1),
        ("5x5 wide", 48, 27, 64, 5, 1, 2),
        ("11x11 stride-4", 8, 55, 32, 11, 4, 0),
        ("3x3 deep", 256, 13, 256, 3, 1, 1),
    ];
    for &(name, c, h, m, k, s, p) in cases {
        let w = h;
        let input = rng.normal_vec(c * h * w);
        let weights = rng.normal_vec(m * c * k * k);
        let bias = rng.normal_vec(m);
        let u = 4;
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        let w_mm = layout::weights_to_mapmajor(&weights, m, c, k, u);
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let ho = (h + 2 * p - k) / s + 1;
        let flops = 2.0 * (m * c * k * k * ho * ho) as f64;
        let meas = bench(name, cfg, || {
            std::hint::black_box(conv_mm(
                &mm_in, &w_mm, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1,
            ));
        });
        table.row(&[
            "conv_mm".into(),
            name.into(),
            ms(meas.mean_ms),
            format!("{:.2}", flops / (meas.mean_ms / 1e3) / 1e9),
        ]);
    }
    println!("# Engine hot path — conv_mm kernel\n");
    table.print();

    // -- Network-level: native engine end-to-end -------------------------
    let mut net_table = Table::new(&["network", "path", "time(ms)"]);
    for net in [zoo::tinynet(), zoo::squeezenet()] {
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let input = rng.normal_vec(net.input.elements());
        let meas = bench(net.name.clone(), cfg, || {
            std::hint::black_box(
                cappuccino::engine::run_mapmajor(
                    &net,
                    &params,
                    &input,
                    &ModeAssignment::uniform(ArithMode::Imprecise),
                    ExecConfig { threads: 1 },
                )
                .unwrap(),
            );
        });
        net_table.row(&[net.name.clone(), "engine-mm".into(), ms(meas.mean_ms)]);
    }

    // -- PJRT path (needs artifacts) --------------------------------------
    let dir = cappuccino::artifacts_dir();
    if dir.join("manifest.json").exists() {
        let manifest = cappuccino::runtime::Manifest::load(&dir).unwrap();
        let rt = cappuccino::runtime::Runtime::new().unwrap();
        for (net, mode, batch) in
            [("tinynet", "precise", 8usize), ("tinynet", "imprecise", 8), ("squeezenet", "imprecise", 1)]
        {
            let spec = manifest.find(net, mode, batch).unwrap();
            let model = rt
                .load(&manifest, spec, &cappuccino::runtime::ParamSource::Random(1))
                .unwrap();
            let x = rng.normal_vec(spec.input_len());
            let meas = bench(format!("pjrt-{net}-{mode}"), cfg, || {
                std::hint::black_box(model.infer(&x).unwrap());
            });
            net_table.row(&[
                format!("{net} (b{batch})"),
                format!("pjrt-{mode}"),
                ms(meas.mean_ms),
            ]);
        }
    } else {
        eprintln!("(artifacts not built: skipping PJRT rows)");
    }
    println!("\n# End-to-end inference\n");
    net_table.print();
    println!("\nengine_hotpath bench OK");
}
