//! Cappuccino CLI — the leader entrypoint.
//!
//! Subcommands mirror the paper's workflow (Fig. 3) plus the serving
//! and simulation facilities:
//!
//! ```text
//! cappuccino info                          # nets, devices, artifacts
//! cappuccino synthesize --net squeezenet   # Fig. 3 flow -> plan JSON
//! cappuccino analyze   --net tinynet       # sec IV.C mode analysis
//! cappuccino simulate  --net alexnet       # Table I row on all devices
//! cappuccino serve     --net tinynet --requests 64   # PJRT serving demo
//! ```

use std::collections::HashMap;

use cappuccino::autotune::{self, TuneConfig};
use cappuccino::config::modelfile::ModelFile;
use cappuccino::data::Dataset;
use cappuccino::engine::{ArithMode, EngineParams, ModeAssignment, Schedule};
use cappuccino::inexact::{self, AnalysisConfig};
use cappuccino::model::zoo;
use cappuccino::serve::{
    build_engine_tenants, parse_models, pjrt_factory, replay, ArrivalProcess, BackendFactory,
    BatchPolicy, ReplaySpec, Server, SloTable, SupervisorPolicy, TenancyConfig, Tenant,
};
use cappuccino::soc::{self, ProcessingMode};
use cappuccino::synth::{finalize, PrimarySynthesizer};
use cappuccino::util::rng::Rng;
use cappuccino::{Error, Result};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Minimal `--key value` flag parser (clap is not in the vendored set).
struct Flags {
    cmd: String,
    kv: HashMap<String, String>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let cmd = args
            .first()
            .cloned()
            .unwrap_or_else(|| "help".to_string());
        let mut kv = HashMap::new();
        let mut i = 1;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| Error::Invalid(format!("expected --flag, got {:?}", args[i])))?;
            let value = args
                .get(i + 1)
                .ok_or_else(|| Error::Invalid(format!("--{key} needs a value")))?;
            kv.insert(key.to_string(), value.clone());
            i += 2;
        }
        Ok(Flags { cmd, kv })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.kv.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{key}: bad number {v:?}"))),
            None => Ok(default),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.kv.get(key) {
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{key}: bad number {v:?}"))),
            None => Ok(default),
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    let flags = Flags::parse(args)?;
    match flags.cmd.as_str() {
        "info" => cmd_info(),
        "synthesize" => cmd_synthesize(&flags),
        "check" => cmd_check(&flags),
        "tune" => cmd_tune(&flags),
        "analyze" => cmd_analyze(&flags),
        "simulate" => cmd_simulate(&flags),
        "serve" => cmd_serve(&flags),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => Err(Error::Invalid(format!("unknown command {other:?}; try `help`"))),
    }
}

const HELP: &str = "\
cappuccino — CNN inference software synthesis for mobile SoCs (reproduction)

USAGE: cappuccino <command> [--flag value ...]

COMMANDS:
  info                               list networks, devices, artifacts
  synthesize --net NAME              run the Fig. 3 synthesis flow; emits plan JSON
             [--u 4] [--threads 4] [--budget 0.01] [--out plan.json]
  check      [--net NAME|all]        statically verify compiled plans: race-freedom,
             [--schedule s.json] [--batch 8] [--strict 1]
             def-before-use + layout consistency, arena safety, and
             mode/tile preconditions over the lowered Step IR
             (engine::verify), across a representative schedule matrix
             per net and at sibling capacities {1, --batch}; with
             --schedule, lints the artifact pre-lowering and verifies
             the exact plan it compiles to (--strict 1 rejects unknown
             JSON keys instead of warning). A schedule placing layers
             on several backends additionally has its staged partition
             proved: stage-cut soundness of the real staged plan, plus
             a corruption sweep (dropped/doubled transfers, leaked
             cross-stage reads) that must be rejected. Exits nonzero
             with the rule name on stderr at the first violation.
  tune       --net tinynet           autotune a per-layer schedule ON THIS MACHINE
             [--batch 8] [--threads 4] [--budget 64] [--reps 5]
             [--warmup 2] [--mode imprecise] [--out schedule.json]
             [--backends native,mock]
             greedy search over per-layer parallelism/packing/tiling,
             vector width (SIMD vs forced-scalar rows), the quantized
             int8 kernels (mode quant_i8), and pool chunking; every
             candidate is compiled and timed for real (median of --reps
             walks), --budget caps measurements
             --backends adds the heterogeneous split search: every
             net-order cut between the two backends is partitioned,
             verified, and timed as a real staged plan, scored by its
             bottleneck stage (pipeline throughput model); the mock
             backend's per-layer latency comes from
             CAPPUCCINO_MOCK_LATENCY (e.g. \"conv2:300,*:50\", us)
  analyze    --net tinynet           per-layer inexact-computing analysis (sec IV.C)
             [--images 256] [--budget 0.01]
             tries quant_i8, then imprecise, then relaxed per layer;
             --mode on tune/serve also accepts quant_i8
  simulate   --net NAME              Table I row for NAME on the device catalog
  serve      --net tinynet           serve a synthetic workload
             [--backend engine|pjrt] [--mode imprecise] [--requests 64]
             [--batch 8] [--threads 1] [--cores 0,1] [--queue-depth 128]
             [--schedule schedule.json]
             [--models a=schedule_a.json,b=schedule_b.json]
             [--slo gold=5,bulk=50] [--device nexus5]
             [--replay N] [--arrivals burst|uniform:R|poisson:R|
              bursty:SIZE:GAPMS|pareto:R[:ALPHA[:CAP]]]
             [--class gold[,bulk]] [--deadline-ms X]
             [--deadline-factor F] [--seed 9] [--bench-out BENCH_serve.json]
             [--fallback-schedule fb.json] [--faults SPEC]
             engine: batch-compiled native plans (one plan walk per
             formed batch, no artifacts needed); pjrt: AOT artifacts
             --schedule serves a tuned artifact from `cappuccino tune`
             (engine backend only: modes, threads, per-layer schedule,
             and core set all come from the file); an artifact whose
             layers name several backends transparently serves through
             the staged pipeline (per-stage workers, bounded queues,
             batches overlapping across stages — engine::hetero), with
             admission estimated from the bottleneck stage and the mock
             backend's latency from CAPPUCCINO_MOCK_LATENCY
             --models hosts N engine tenants at once, one schedule
             artifact each, with disjoint core sets and per-tenant
             queues/admission; --slo names deadline classes (ms)
             --replay drives an open-loop arrival trace through the
             admission-controlled front-end (deadlines via --deadline-ms,
             --deadline-factor F = F batch walks, or an --slo class via
             --class) and writes p50/p99-under-load to --bench-out
             --cores pins the model worker to the given CPUs
             (sched_setaffinity; co-hosted models should use disjoint
             sets so they stop trampling each other's caches)
             --fallback-schedule names a known-good schedule the
             supervisor degrades to after repeated worker faults
             (engine backend; must be tuned for the same net)
             --faults installs deterministic fault injection for chaos
             runs, e.g. \"seed=42,panic:conv:0.01,err:backend:0.05\"
             (also readable from CAPPUCCINO_FAULTS; see src/faults)
";

fn cmd_info() -> Result<()> {
    println!("networks:");
    for net in zoo::all() {
        let info = cappuccino::model::shapes::infer(&net)?;
        println!(
            "  {:<11} {:>6.2} GFLOPs  {:>7} params  {} mode-layers",
            net.name,
            info.total_flops() / 1e9,
            cappuccino::util::eng(net.param_count() as f64),
            net.param_layer_names().len()
        );
    }
    println!("devices:");
    for d in soc::catalog() {
        println!(
            "  {:<10} {:<15} {} cores @ {:.2} GHz, {:.0} GB/s",
            d.name, d.soc, d.cores, d.ghz, d.mem_bw_gbs
        );
    }
    let dir = cappuccino::artifacts_dir();
    match cappuccino::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for a in &m.artifacts {
                println!("  {:<26} {:?}", a.name, a.input_shape);
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_synthesize(flags: &Flags) -> Result<()> {
    let net_name = flags.get("net", "tinynet");
    let net = zoo::by_name(&net_name)
        .ok_or_else(|| Error::Invalid(format!("unknown net {net_name:?}")))?;
    let u = flags.get_usize("u", cappuccino::DEFAULT_U)?;
    let threads = flags.get_usize("threads", 4)?;
    let budget = flags.get_f64("budget", 0.01)?;

    eprintln!("[1/3] primary program synthesis (OLP, map-major, u={u})");
    let primary = PrimarySynthesizer::new(u, threads).synthesize(&net)?;

    // Inexact analysis needs trained weights + the validation set; those
    // exist for tinynet. Other nets follow the paper's measured outcome
    // (imprecise everywhere, accuracy unchanged) as the default.
    let dir = cappuccino::artifacts_dir();
    let modes = if net_name == "tinynet" && dir.join("tinynet.capp").exists() {
        eprintln!("[2/3] inexact-computing analysis on the validation set");
        let mf = ModelFile::read_from(dir.join("tinynet.capp"))?;
        let params = EngineParams::compile(&net, &mf, u)?;
        let dataset = Dataset::read_from(dir.join("dataset.bin"))?;
        let cfg = AnalysisConfig {
            max_accuracy_drop: budget,
            max_images: flags.get_usize("images", 256)?,
            threads,
        };
        let report = inexact::analyze(&net, &params, &dataset, &cfg)?;
        eprintln!(
            "      baseline acc {:.4}, final acc {:.4}, {}/{} layers inexact",
            report.baseline_accuracy,
            report.final_accuracy,
            report.inexact_layers(),
            report.decisions.len()
        );
        report.assignment
    } else {
        eprintln!("[2/3] no trained weights for {net_name}: adopting the paper's");
        eprintln!("      measured outcome (imprecise in all layers)");
        ModeAssignment::uniform(ArithMode::Imprecise)
    };

    eprintln!("[3/3] software synthesis");
    let plan = finalize(&primary, &modes);
    let json = plan.to_json().to_string();
    let out = flags.get("out", "-");
    if out == "-" {
        println!("{json}");
    } else {
        cappuccino::util::write_atomic(&out, &json)?;
        eprintln!("wrote plan to {out}");
    }
    for d in soc::catalog() {
        eprintln!(
            "      predicted on {:<10} {:>9.2} ms",
            d.name,
            cappuccino::synth::predict_latency_ms(&plan, &net, &d)
        );
    }
    Ok(())
}

/// `cappuccino check` — run the static plan verifier
/// ([`cappuccino::engine::verify`]) over every plan a net's schedule
/// surface produces, or over one tuned schedule artifact.
fn cmd_check(flags: &Flags) -> Result<()> {
    use cappuccino::engine::{Parallelism, PlanBuilder, StagedMutation, StagedPlan};

    let batch = flags.get_usize("batch", 8)?;
    if batch == 0 {
        return Err(Error::Invalid("--batch 0: need at least one image of capacity".into()));
    }
    let schedule_path = flags.get("schedule", "");
    if !schedule_path.is_empty() {
        // One artifact: lint the schedule before lowering, then verify
        // the exact plan it compiles to, at full and unit capacity.
        let strict = matches!(flags.get("strict", "").as_str(), "1" | "true");
        let schedule = if strict {
            Schedule::load_strict(&schedule_path)?
        } else {
            Schedule::load(&schedule_path)?
        };
        cappuccino::engine::verify_schedule(&schedule)?;
        let network = zoo::by_name(&schedule.net)
            .ok_or_else(|| Error::Invalid(format!("unknown net {:?} in schedule", schedule.net)))?;
        let params = EngineParams::random(&network, 42, schedule.u)?;
        let staged_schedule = schedule.is_staged();
        let plan = PlanBuilder::new(&network, &params).schedule(schedule).batch(batch).build()?;
        plan.verify()?;
        plan.with_capacity(1).verify()?;
        if staged_schedule {
            // Prove stage-cut soundness of the real staged partition,
            // then show the verifier has teeth: every transfer-level
            // corruption of the staged plan must be rejected.
            let staged = StagedPlan::from_plan(&plan)?;
            staged.verify()?;
            let mut rejected = 0usize;
            for m in StagedMutation::ALL {
                let mut corrupt = StagedPlan::from_plan(&plan)?;
                if !corrupt.apply_staged_mutation(m) {
                    return Err(Error::Invalid(format!(
                        "staged plan has no site for corruption {:?}",
                        m.as_str()
                    )));
                }
                match corrupt.verify() {
                    Err(Error::Verify { rule, .. }) => {
                        eprintln!("  corruption {:<22} rejected ({rule})", m.as_str());
                        rejected += 1;
                    }
                    Err(e) => return Err(e),
                    Ok(()) => {
                        return Err(Error::Invalid(format!(
                            "staged-plan corruption {:?} was NOT rejected by the verifier",
                            m.as_str()
                        )))
                    }
                }
            }
            println!(
                "{schedule_path}: staged schedule over {} stages ({}); stage-cut soundness \
                 proven, {rejected}/{} corruptions rejected",
                staged.stage_count(),
                staged
                    .stage_backends()
                    .iter()
                    .map(|b| b.to_string())
                    .collect::<Vec<_>>()
                    .join("|"),
                StagedMutation::ALL.len()
            );
        }
        println!(
            "{schedule_path}: schedule lints clean, plan verifies at capacities {{1, {batch}}}"
        );
        return Ok(());
    }

    let net_name = flags.get("net", "all");
    let nets = if net_name == "all" {
        zoo::all()
    } else {
        let net = zoo::by_name(&net_name)
            .ok_or_else(|| Error::Invalid(format!("unknown net {net_name:?}")))?;
        vec![net]
    };
    // The representative schedule surface: every lowering family the
    // engine has (packed/unpacked OLP, row-major FLP/KLP, the vector
    // and quantized kernels, placement) at one and several pool chunks.
    let combos: &[(&str, ArithMode, Parallelism, bool, usize, bool)] = &[
        ("olp packed precise t1", ArithMode::Precise, Parallelism::Olp, true, 1, false),
        ("olp packed imprecise t4", ArithMode::Imprecise, Parallelism::Olp, true, 4, false),
        ("olp packed quant_i8 t4", ArithMode::QuantI8, Parallelism::Olp, true, 4, false),
        ("olp unpacked imprecise t4", ArithMode::Imprecise, Parallelism::Olp, false, 4, false),
        ("flp rowmajor imprecise t4", ArithMode::Imprecise, Parallelism::Flp, true, 4, false),
        ("klp rowmajor imprecise t4", ArithMode::Imprecise, Parallelism::Klp, true, 4, false),
        ("olp packed imprecise t4 +aff", ArithMode::Imprecise, Parallelism::Olp, true, 4, true),
    ];
    for network in &nets {
        let params = EngineParams::random(network, 42, cappuccino::DEFAULT_U)?;
        let mut checked = 0usize;
        for &(_label, mode, policy, packing, threads, affinity) in combos {
            let plan = PlanBuilder::new(network, &params)
                .modes(&ModeAssignment::uniform(mode))
                .policy(policy)
                .packing(packing)
                .threads(threads)
                .affinity(affinity)
                .batch(batch)
                .build()?;
            plan.verify()?;
            plan.with_capacity(1).verify()?;
            checked += 1;
        }
        println!(
            "{:<11} {checked} schedule families verify clean at capacities {{1, {batch}}}",
            network.name
        );
    }
    Ok(())
}

fn cmd_tune(flags: &Flags) -> Result<()> {
    let net_name = flags.get("net", "tinynet");
    let net = zoo::by_name(&net_name)
        .ok_or_else(|| Error::Invalid(format!("unknown net {net_name:?}")))?;
    let u = flags.get_usize("u", cappuccino::DEFAULT_U)?;
    if u == 0 {
        return Err(Error::Invalid("--u 0: the vector width must be at least 1".into()));
    }
    let mode: ArithMode = flags.get("mode", "imprecise").parse()?;
    let backends_flag = flags.get("backends", "");
    let backends = if backends_flag.is_empty() {
        Vec::new()
    } else {
        backends_flag
            .split(',')
            .map(|s| s.trim().parse::<cappuccino::engine::BackendTarget>())
            .collect::<Result<Vec<_>>>()?
    };
    let cfg = TuneConfig {
        batch: flags.get_usize("batch", 8)?,
        max_threads: flags.get_usize("threads", 4)?,
        warmup: flags.get_usize("warmup", 2)?,
        reps: flags.get_usize("reps", 5)?,
        budget: flags.get_usize("budget", 64)?,
        modes: ModeAssignment::uniform(mode),
        backends,
        ..Default::default()
    };
    // Weight values do not affect latency; random parameters make every
    // zoo net tunable without trained artifacts.
    let params = EngineParams::random(&net, 42, u)?;
    eprintln!(
        "tuning {net_name} on this machine (u={u}, batch={}, budget {} measurements) ...",
        cfg.batch,
        cfg.budget
    );
    let report = autotune::tune(&net, &params, &cfg)?;
    for t in &report.trials {
        eprintln!(
            "  {:<8} {:<22} {:>9.3} ms{}",
            t.layer,
            t.candidate,
            t.median_ms,
            if t.accepted { "  <- adopted" } else { "" }
        );
    }
    eprintln!(
        "default {:.3} ms/walk -> tuned {:.3} ms/walk ({:.2}x) in {} measurements",
        report.default_ms,
        report.tuned_ms,
        report.speedup(),
        report.measurements
    );
    if let Some(p) = report.predicted_ms {
        eprintln!("SoC-model prediction for the tuned schedule: {p:.2} ms/image");
    }
    if report.schedule.is_staged() {
        eprintln!("tuned schedule is staged: a heterogeneous backend split was adopted");
    }
    let out = flags.get("out", "schedule.json");
    if out == "-" {
        let text = report.schedule.to_json().to_string();
        println!("{text}");
    } else {
        report.schedule.save(&out)?;
        eprintln!("wrote schedule to {out}");
    }
    Ok(())
}

fn cmd_analyze(flags: &Flags) -> Result<()> {
    let net_name = flags.get("net", "tinynet");
    if net_name != "tinynet" {
        return Err(Error::Invalid(
            "analysis needs trained weights; only tinynet ships them".into(),
        ));
    }
    let dir = cappuccino::artifacts_dir();
    let net = zoo::tinynet();
    let mf = ModelFile::read_from(dir.join("tinynet.capp"))?;
    let params = EngineParams::compile(&net, &mf, cappuccino::DEFAULT_U)?;
    let dataset = Dataset::read_from(dir.join("dataset.bin"))?;
    let cfg = AnalysisConfig {
        max_accuracy_drop: flags.get_f64("budget", 0.01)?,
        max_images: flags.get_usize("images", 256)?,
        threads: flags.get_usize("threads", 1)?,
    };
    let report = inexact::analyze(&net, &params, &dataset, &cfg)?;
    println!("baseline accuracy: {:.4}", report.baseline_accuracy);
    for d in &report.decisions {
        println!(
            "  {:<8} -> {:<9} (cumulative acc {:.4}{})",
            d.layer,
            d.chosen.as_str(),
            d.accuracy,
            if d.rejected.is_empty() {
                String::new()
            } else {
                format!(
                    ", rejected: {}",
                    d.rejected
                        .iter()
                        .map(|(m, a)| format!("{}@{a:.4}", m.as_str()))
                        .collect::<Vec<_>>()
                        .join(" ")
                )
            }
        );
    }
    println!(
        "final accuracy: {:.4} ({} evaluations, {}/{} layers inexact)",
        report.final_accuracy,
        report.evaluations,
        report.inexact_layers(),
        report.decisions.len()
    );
    Ok(())
}

fn cmd_simulate(flags: &Flags) -> Result<()> {
    let net_name = flags.get("net", "squeezenet");
    let net = zoo::by_name(&net_name)
        .ok_or_else(|| Error::Invalid(format!("unknown net {net_name:?}")))?;
    println!("{net_name} on the device catalog (simulated, ms):");
    println!(
        "{:<11} {:>12} {:>10} {:>10} {:>9}",
        "device", "baseline", "parallel", "imprecise", "speedup"
    );
    for d in soc::catalog() {
        let base = soc::measure_trimmed(&net, &d, ProcessingMode::JavaBaseline, 100, 0.01, 1);
        let par = soc::measure_trimmed(&net, &d, ProcessingMode::Parallel, 100, 0.01, 2);
        let imp = soc::measure_trimmed(&net, &d, ProcessingMode::Imprecise, 100, 0.01, 3);
        println!(
            "{:<11} {:>12.2} {:>10.2} {:>10.2} {:>8.2}x",
            d.name,
            base,
            par,
            imp,
            base / imp
        );
    }
    Ok(())
}

/// Parse the `--arrivals` spec (colon-separated fields).
fn parse_arrivals(spec: &str) -> Result<ArrivalProcess> {
    let num = |s: &str, what: &str| -> Result<f64> {
        s.parse()
            .map_err(|_| Error::Invalid(format!("--arrivals: bad {what} {s:?}")))
    };
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        ["burst"] => Ok(ArrivalProcess::Burst),
        ["uniform", r] => Ok(ArrivalProcess::Uniform { rate_per_s: num(r, "rate")? }),
        ["poisson", r] => Ok(ArrivalProcess::Poisson { rate_per_s: num(r, "rate")? }),
        ["bursty", size, gap_ms] => Ok(ArrivalProcess::Bursty {
            size: num(size, "burst size")?.max(1.0) as usize,
            gap: std::time::Duration::from_secs_f64(num(gap_ms, "gap")? / 1e3),
        }),
        ["pareto", r] => Ok(ArrivalProcess::BoundedPareto {
            rate_per_s: num(r, "rate")?,
            alpha: 1.5,
            cap: 1000.0,
        }),
        ["pareto", r, a] => Ok(ArrivalProcess::BoundedPareto {
            rate_per_s: num(r, "rate")?,
            alpha: num(a, "alpha")?,
            cap: 1000.0,
        }),
        ["pareto", r, a, k] => Ok(ArrivalProcess::BoundedPareto {
            rate_per_s: num(r, "rate")?,
            alpha: num(a, "alpha")?,
            cap: num(k, "cap")?,
        }),
        _ => Err(Error::Invalid(format!(
            "--arrivals {spec:?}: expected burst, uniform:R, poisson:R, bursty:SIZE:GAPMS, \
             or pareto:R[:ALPHA[:CAP]]"
        ))),
    }
}

/// Build the single-model `--fallback-schedule` degraded-mode factory:
/// the fallback artifact with the primary's own weights (the same pairing
/// the tenancy path makes). The nets must match — a fallback for a
/// different model is a configuration error, not a silent no-op.
fn engine_fallback(
    path: &str,
    net: &str,
    network: &cappuccino::model::Network,
    params: &EngineParams,
    max_batch: usize,
) -> Result<Option<BackendFactory>> {
    if path.is_empty() {
        return Ok(None);
    }
    let fb = Schedule::load(path)?;
    if fb.net != net {
        return Err(Error::Invalid(format!(
            "fallback schedule {path:?} was tuned for net {:?}, serving {net:?}",
            fb.net
        )));
    }
    Ok(Some(
        cappuccino::serve::EngineBackend::with_schedule(
            network.clone(),
            params.clone(),
            fb,
            max_batch,
        )
        .factory(),
    ))
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let net = flags.get("net", "tinynet");
    let mode = flags.get("mode", "imprecise");
    let backend = flags.get("backend", "pjrt");
    let n_requests = flags.get_usize("requests", 64)?;
    let max_batch = flags.get_usize("batch", 8)?;
    let threads = flags.get_usize("threads", 1)?;
    let queue_depth = flags.get_usize("queue-depth", 128)?;
    let max_delay = std::time::Duration::from_secs_f64(
        flags.get_f64("max-delay-ms", 2.0)?.max(0.0) / 1e3,
    );
    let slo_flag = flags.get("slo", "");
    let slo = if slo_flag.is_empty() { SloTable::default() } else { SloTable::parse(&slo_flag)? };
    let device_name = flags.get("device", "nexus5");
    let device = soc::devices::by_name(&device_name)
        .ok_or_else(|| Error::Invalid(format!("unknown device {device_name:?}")))?;
    let models_flag = flags.get("models", "");
    let cores_flag = flags.get("cores", "");
    let cores = if cores_flag.is_empty() {
        None
    } else {
        let mut cpus = Vec::new();
        for part in cores_flag.split(',') {
            let cpu = part.trim().parse::<usize>().map_err(|_| {
                Error::Invalid(format!("--cores: bad cpu id {part:?}"))
            })?;
            // CoreSet is a 64-bit mask; reject out-of-range ids instead
            // of silently running the worker unpinned.
            if cpu >= 64 {
                return Err(Error::Invalid(format!(
                    "--cores: cpu id {cpu} out of range (serve core sets cover cpus 0-63)"
                )));
            }
            cpus.push(cpu);
        }
        Some(cappuccino::engine::CoreSet::of(&cpus))
    };
    let schedule_path = flags.get("schedule", "");
    let fallback_path = flags.get("fallback-schedule", "");
    let faults_flag = flags.get("faults", "");
    if !faults_flag.is_empty() {
        // Installed before any worker spawns so the whole run — including
        // backend construction — is under the injection config.
        let cfg = cappuccino::faults::FaultConfig::parse(&faults_flag)?;
        cappuccino::faults::install(Some(cfg));
        eprintln!("fault injection armed: {faults_flag}");
    }
    let dir = cappuccino::artifacts_dir();

    let server = if !models_flag.is_empty() {
        // Multi-model tenancy: one engine tenant per schedule artifact,
        // each with its own queues, admission estimate, and (with more
        // than one tenant) a disjoint partition of the host cores.
        if !schedule_path.is_empty() {
            return Err(Error::Invalid(
                "--models already names one schedule per tenant; drop --schedule".into(),
            ));
        }
        if flags.kv.contains_key("backend") && backend != "engine" {
            return Err(Error::Invalid(
                "--models hosts engine tenants (PJRT executables are fixed single-model \
                 artifacts); drop --backend or use --backend engine"
                    .into(),
            ));
        }
        let specs = parse_models(&models_flag)?;
        let cfg = TenancyConfig {
            max_batch,
            max_delay,
            queue_depth,
            partition_cores: cores.is_none(),
            device,
            seed: 42,
            fallback_schedule: if fallback_path.is_empty() {
                None
            } else {
                Some(fallback_path.clone())
            },
            supervision: SupervisorPolicy::default(),
        };
        eprintln!("compiling {} tenants (native engine) ...", specs.len());
        let mut tenants = build_engine_tenants(&specs, &cfg)?;
        if cores.is_some() {
            // An explicit --cores mask applies to every tenant (the user
            // is overriding partitioning wholesale).
            for t in &mut tenants {
                t.policy.cores = cores;
            }
        }
        for t in &tenants {
            eprintln!(
                "  {:<12} image_ms={:.3} max_batch={} cores={:?}",
                t.name,
                t.image_ms.unwrap_or(0.0),
                t.policy.max_batch,
                t.policy.cores,
            );
        }
        Server::start_tenants(tenants, slo)?
    } else {
        // Single-model path. A tuned schedule artifact may carry the
        // worker's core set; an explicit --cores flag still wins.
        let mut schedule_cores = None;
        let (factory, fallback, input_len, image_ms) = match backend.as_str() {
            "engine" => {
                // Native engine: batch-capacity plans compiled on the
                // worker thread; every formed batch is one plan walk.
                // Needs no artifacts — weights are random
                // (latency/throughput demo).
                let network = zoo::by_name(&net)
                    .ok_or_else(|| Error::Invalid(format!("unknown net {net:?}")))?;
                let input_len = network.input.elements();
                let (eb, fb, image_ms) = if !schedule_path.is_empty() {
                    // Serve the measured configuration exactly as tuned:
                    // per-layer schedule, modes, pool threads, and core
                    // set all come from the artifact.
                    let schedule = Schedule::load(&schedule_path)?;
                    if schedule.net != net {
                        return Err(Error::Invalid(format!(
                            "schedule {schedule_path:?} was tuned for net {:?}, serving {net:?} \
                             (pass --net {})",
                            schedule.net,
                            schedule.net
                        )));
                    }
                    schedule_cores = schedule.pool.cores;
                    // A staged schedule pipelines batches across its
                    // stages, so admission tracks the bottleneck stage
                    // rather than the end-to-end sum.
                    let image_ms = if schedule.is_staged() {
                        cappuccino::synth::predict_schedule_throughput_ms(
                            &schedule, &network, &device,
                        )?
                    } else {
                        cappuccino::synth::predict_schedule_latency_ms(
                            &schedule, &network, &device,
                        )?
                    };
                    let params = EngineParams::random(&network, 42, schedule.u)?;
                    let fb = engine_fallback(&fallback_path, &net, &network, &params, max_batch)?;
                    eprintln!(
                        "compiling {net} batch plans from {schedule_path} (native engine) ..."
                    );
                    let eb = cappuccino::serve::EngineBackend::with_schedule(
                        network,
                        params,
                        schedule,
                        max_batch,
                    );
                    (eb, fb, image_ms)
                } else {
                    let arith: ArithMode = mode.parse()?;
                    let modes = ModeAssignment::uniform(arith);
                    // Same estimate the tenancy path derives from an
                    // artifact, built from the uniform configuration.
                    let uniform = Schedule::from_uniform(
                        &network,
                        cappuccino::DEFAULT_U,
                        &modes,
                        cappuccino::engine::Parallelism::Olp,
                        true,
                        None,
                        cappuccino::engine::PoolSettings {
                            threads,
                            affinity: false,
                            cores: None,
                        },
                    )?;
                    let image_ms = cappuccino::synth::predict_schedule_latency_ms(
                        &uniform, &network, &device,
                    )?;
                    let params = EngineParams::random(&network, 42, cappuccino::DEFAULT_U)?;
                    let fb = engine_fallback(&fallback_path, &net, &network, &params, max_batch)?;
                    eprintln!("compiling {net}/{mode} batch plans (native engine) ...");
                    let eb = cappuccino::serve::EngineBackend::new(
                        network,
                        params,
                        modes,
                        threads,
                        max_batch,
                    );
                    (eb, fb, image_ms)
                };
                (eb.factory(), fb, input_len, Some(image_ms))
            }
            "pjrt" if !schedule_path.is_empty() => {
                return Err(Error::Invalid(
                    "--schedule applies to the engine backend (PJRT executables are fixed \
                     artifacts); drop --schedule or use --backend engine"
                        .into(),
                ))
            }
            "pjrt" if !fallback_path.is_empty() => {
                return Err(Error::Invalid(
                    "--fallback-schedule applies to the engine backend (PJRT executables are \
                     fixed artifacts); drop it or use --backend engine"
                        .into(),
                ))
            }
            "pjrt" => {
                // tinynet serves its trained weights; other nets get
                // random weights (latency-only serving demo). No analytic
                // estimate for device executables: deadline admission is
                // disabled (queue backpressure still applies).
                let seed = if net == "tinynet" { None } else { Some(42) };
                eprintln!("loading {net}/{mode} artifacts ...");
                let manifest = cappuccino::runtime::Manifest::load(&dir)?;
                let network = manifest
                    .nets
                    .get(&net)
                    .ok_or_else(|| Error::Invalid(format!("no net {net} in manifest")))?;
                let input_len = network.input.elements();
                (
                    pjrt_factory(dir.clone(), net.clone(), mode.clone(), seed),
                    None,
                    input_len,
                    None,
                )
            }
            other => {
                return Err(Error::Invalid(format!(
                    "--backend {other:?}: expected \"engine\" or \"pjrt\""
                )))
            }
        };
        let policy = BatchPolicy {
            max_batch,
            max_delay,
            queue_depth,
            cores: cores.or(schedule_cores),
        };
        let tenant = Tenant {
            name: net.clone(),
            factory,
            policy,
            image_ms,
            input_len,
            fallback,
            supervision: SupervisorPolicy::default(),
        };
        Server::start_tenants(vec![tenant], slo)?
    };

    // Open-loop replay driver: arrival-spaced requests round-robin over
    // the resident tenants, typed rejection accounting, p50/p99 to JSON.
    if let Some(replay_n) = flags.kv.get("replay") {
        let requests: usize = replay_n
            .parse()
            .map_err(|_| Error::Invalid(format!("--replay: bad request count {replay_n:?}")))?;
        let class_flag = flags.get("class", "");
        let classes: Vec<String> = if class_flag.is_empty() {
            Vec::new()
        } else {
            class_flag.split(',').map(|s| s.trim().to_string()).collect()
        };
        let deadline_ms = flags.get_f64("deadline-ms", 0.0)?;
        let spec = ReplaySpec {
            requests,
            arrivals: parse_arrivals(&flags.get("arrivals", "burst"))?,
            seed: flags.get_usize("seed", 9)? as u64,
            classes,
            deadline: if deadline_ms > 0.0 {
                Some(std::time::Duration::from_secs_f64(deadline_ms / 1e3))
            } else {
                None
            },
            deadline_factor: match flags.kv.get("deadline-factor") {
                Some(v) => Some(v.parse().map_err(|_| {
                    Error::Invalid(format!("--deadline-factor: bad number {v:?}"))
                })?),
                None => None,
            },
        };
        eprintln!("replaying {requests} requests ({}) ...", spec.arrivals.label());
        let outcome = replay(&server, &spec);
        println!("{}", outcome.summary_line());
        println!("{}", server.metrics().summary());
        let out = flags.get("bench-out", "BENCH_serve.json");
        cappuccino::util::write_atomic(&out, outcome.to_json().to_string())?;
        eprintln!("wrote {out}");
        server.shutdown();
        return Ok(());
    }

    // Closed-loop demo: submit everything up front against the first
    // tenant, wait for every reply. Synthetic client images: dataset
    // validation images (tinynet with artifacts) or noise.
    let first = server.tenants()[0].clone();
    let images: Vec<Vec<f32>> = if first.name == "tinynet" && dir.join("dataset.bin").exists() {
        let dataset = Dataset::read_from(dir.join("dataset.bin"))?;
        let (val, _) = dataset.validation();
        (0..n_requests).map(|i| val[i % val.len()].clone()).collect()
    } else {
        let mut rng = Rng::new(9);
        (0..n_requests).map(|_| rng.normal_vec(first.input_len.max(1))).collect()
    };

    eprintln!("serving {n_requests} requests ...");
    let mut receivers = Vec::with_capacity(n_requests);
    for img in images {
        receivers.push(server.router().submit(&first.name, img)?);
    }
    let mut ok = 0;
    for rx in receivers {
        // The reply itself is a Result: a contained worker fault answers
        // with a typed error instead of completing.
        if matches!(rx.recv(), Ok(Ok(_))) {
            ok += 1;
        }
    }
    println!("{ok}/{n_requests} completed");
    println!("{}", server.metrics().summary());
    server.shutdown();
    Ok(())
}
