"""Build-path plumbing: model file format, dataset format, manifest
shapes, and HLO-text emission."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, dataset as D, model as M, modelfile as MF
from compile import train_tiny as T
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestModelFile:
    def test_roundtrip(self, tmp_path):
        rng = np.random.default_rng(0)
        tensors = {
            "conv1/w": rng.standard_normal((4, 4, 1, 3, 3, 4)).astype("f4"),
            "conv1/b": rng.standard_normal((4, 4)).astype("f4"),
            "scalarish": rng.standard_normal((7,)).astype("f4"),
        }
        p = str(tmp_path / "m.capp")
        MF.write_modelfile(p, tensors)
        back = MF.read_modelfile(p)
        assert list(back) == list(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])

    def test_params_tensor_roundtrip(self):
        params = {"a": (np.ones((2, 3)), np.zeros(2)),
                  "b/c": (np.ones((4,)), np.full(4, 2.0))}
        back = MF.tensors_to_params(MF.params_to_tensors(params))
        assert set(back) == {"a", "b/c"}
        np.testing.assert_array_equal(back["a"][0], params["a"][0])
        np.testing.assert_array_equal(back["b/c"][1], params["b/c"][1])

    def test_bad_magic_rejected(self, tmp_path):
        p = str(tmp_path / "bad.capp")
        with open(p, "wb") as f:
            f.write(b"NOTMAGIC" + b"\x00" * 16)
        with pytest.raises(ValueError, match="magic"):
            MF.read_modelfile(p)


class TestDataset:
    def test_roundtrip(self, tmp_path):
        imgs, labels = D.generate(64, seed=1)
        p = str(tmp_path / "d.bin")
        D.write_dataset(p, imgs, labels, 48)
        i2, l2, nt = D.read_dataset(p)
        assert nt == 48
        np.testing.assert_array_equal(i2, imgs)
        np.testing.assert_array_equal(l2, labels)

    def test_balanced_classes(self):
        _, labels = D.generate(80, seed=2)
        counts = np.bincount(labels, minlength=D.NUM_CLASSES)
        assert counts.min() == counts.max() == 10

    def test_deterministic(self):
        a, la = D.generate(16, seed=3)
        b, lb = D.generate(16, seed=3)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_classes_learnable(self):
        # A tiny training run must beat chance by a wide margin — the
        # dataset substitution is only valid if decision boundaries are
        # real (DESIGN.md substitution table).
        imgs, labels = D.generate(512, seed=4)
        params = T.train(imgs[:384], labels[:384], steps=120,
                         log=lambda *_: None)
        acc = T.accuracy(params, imgs[384:], labels[384:])
        assert acc > 0.7, f"synthetic dataset not learnable: acc={acc}"


class TestAotHelpers:
    def test_mm_param_shapes_tinynet(self):
        shapes = aot.mm_param_shapes(M.tinynet_spec(), (3, 16, 16))
        d = {n: (w, b) for n, w, b in shapes}
        assert d["conv1"] == ((4, 4, 1, 3, 3, 4), (4, 4))
        assert d["conv3"] == ((8, 4, 8, 3, 3, 4), (8, 4))
        assert d["fc4"] == ((64, 512), (64,))
        assert d["fc5"] == ((8, 64), (8,))

    def test_mm_input_shape_pads_channels(self):
        assert aot.mm_input_shape((3, 16, 16), 2) == (2, 1, 16, 16, 4)
        assert aot.mm_input_shape((96, 55, 55), 1) == (1, 24, 55, 55, 4)

    def test_hlo_text_emission(self):
        def fn(x):
            return (x * 2.0 + 1.0,)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[4]" in text

    def test_export_spec_json_serializable(self):
        for net, (spec_fn, _, _) in M.NETS.items():
            exported = aot.export_spec(spec_fn())
            json.dumps(exported)  # must not raise
            ops = {l["op"] for l in exported}
            assert "fire" not in ops and "inception" not in ops


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first")


@needs_artifacts
class TestEmittedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_lists_existing_hlo_files(self, manifest):
        assert len(manifest["artifacts"]) >= 11
        for entry in manifest["artifacts"]:
            path = os.path.join(ARTIFACTS, entry["hlo"])
            assert os.path.exists(path), entry["name"]
            with open(path) as f:
                assert f.read(16).startswith("HloModule")

    def test_golden_logits_match_trained_model(self, manifest):
        """The golden file must reproduce from tinynet.capp + the spec —
        guards against artifact drift."""
        params = MF.tensors_to_params(
            MF.read_modelfile(os.path.join(ARTIFACTS, "tinynet.capp")))
        golden = MF.read_modelfile(
            os.path.join(ARTIFACTS, "golden_tinynet.capp"))
        spec = M.tinynet_spec()
        pmm = M.reorder_params(spec, (D.C, D.H, D.W),
                               {k: (jnp.asarray(w), jnp.asarray(b))
                                for k, (w, b) in params.items()}, aot.U)
        apply = M.build_apply(spec, (D.C, D.H, D.W), aot.U)
        got = apply(pmm, jnp.asarray(golden["x_mm"]), "precise")
        np.testing.assert_allclose(np.asarray(got),
                                   golden["logits_precise"],
                                   rtol=1e-5, atol=1e-5)

    def test_golden_classifies_correctly(self, manifest):
        golden = MF.read_modelfile(
            os.path.join(ARTIFACTS, "golden_tinynet.capp"))
        pred = golden["logits_precise"].argmax(axis=1)
        labels = golden["labels"].astype(np.int64)
        assert (pred == labels).mean() >= 0.75

    def test_imprecise_same_argmax_as_precise(self, manifest):
        # The paper's headline inexact-computing result: classification
        # accuracy under imprecise arithmetic is identical.
        golden = MF.read_modelfile(
            os.path.join(ARTIFACTS, "golden_tinynet.capp"))
        np.testing.assert_array_equal(
            golden["logits_precise"].argmax(axis=1),
            golden["logits_imprecise"].argmax(axis=1))

    def test_mm_modelfile_matches_reorder(self, manifest):
        conv = MF.read_modelfile(os.path.join(ARTIFACTS, "tinynet.capp"))
        mm = MF.read_modelfile(os.path.join(ARTIFACTS, "tinynet_mm.capp"))
        w_mm = ref.weights_to_mapmajor(jnp.asarray(conv["conv2/w"]), aot.U)
        np.testing.assert_allclose(np.asarray(w_mm), mm["conv2/w"],
                                   rtol=0, atol=0)
