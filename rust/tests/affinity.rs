//! Topology-aware pool integration suite (ISSUE 4).
//!
//! Three contracts under test:
//!
//! 1. **No head-of-line blocking** — a scope's helping submitter only
//!    ever executes its own batch's jobs, so a small concurrent scope
//!    cannot get stuck running another batch's long work (the old
//!    pool's help loop popped *any* queued job; the regression test
//!    below fails on it by ~30 s).
//! 2. **Placement and pinning are bitwise invisible** — plans run
//!    bitwise identically on the global pool, a pinned pool, an
//!    unpinned pool, and a synthetic heterogeneous (two-cluster)
//!    pool, with cost-weighted affinity placement on or off, across
//!    thread counts — against the legacy interpreter oracle.
//! 3. **The uniform fallback is safe** — unprobed topologies never pin
//!    and still execute everything (the constrained-host CI job runs
//!    this whole binary under `taskset -c 0,1`).
//!
//! This binary deliberately hosts every test that spawns private
//! [`ThreadPool`]s: the `pool_threads_spawned` counter is
//! process-global, and the lib/parity binaries assert it stays flat.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cappuccino::engine::{
    run_mapmajor_legacy, with_pool, ArithMode, CoreCluster, EngineParams, ExecConfig,
    ModeAssignment, PlanBuilder, ThreadPool, Topology,
};
use cappuccino::model::zoo;
use cappuccino::util::rng::Rng;

fn wait_until(flag: &AtomicBool, timeout: Duration) {
    let t0 = Instant::now();
    while !flag.load(Ordering::Acquire) && t0.elapsed() < timeout {
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Synthetic big.LITTLE shape: one 1024-capacity core, one 512-capacity
/// core. `probed` is false, so worker pinning no-ops (the cpu ids are
/// placeholders) while the per-cluster deques and weighted placement
/// are fully exercised.
fn two_cluster_pool() -> ThreadPool {
    let topo = Topology {
        clusters: vec![
            CoreCluster { cpus: vec![0], capacity: 1024 },
            CoreCluster { cpus: vec![1], capacity: 512 },
        ],
        probed: false,
    };
    ThreadPool::with_topology(&topo, true)
}

#[test]
fn small_scope_is_not_blocked_behind_a_concurrent_slow_batch() {
    // Pool of ONE worker. Scope A submits three jobs that block until
    // released: the worker takes one, A's own helper takes a second,
    // and the third sits queued. A concurrent small scope B must then
    // complete immediately — its helper runs B's job itself and must
    // NOT pop A's queued slow job (the old pool did exactly that, so
    // this test times out at ~30 s on it).
    let pool = Arc::new(ThreadPool::new(1));
    let release = Arc::new(AtomicBool::new(false));
    let started = Arc::new(AtomicUsize::new(0));
    let slow = {
        let (pool, release, started) =
            (Arc::clone(&pool), Arc::clone(&release), Arc::clone(&started));
        std::thread::spawn(move || {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                .map(|_| {
                    let (release, started) = (&release, &started);
                    Box::new(move || {
                        started.fetch_add(1, Ordering::AcqRel);
                        wait_until(release, Duration::from_secs(30));
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
        })
    };
    // Both execution contexts (worker + A's helper) are inside slow
    // jobs once two have started; the third is queued.
    let t0 = Instant::now();
    while started.load(Ordering::Acquire) < 2 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(started.load(Ordering::Acquire), 2, "slow scope never saturated the pool");

    let ran = AtomicBool::new(false);
    let t1 = Instant::now();
    pool.scope(vec![Box::new(|| {
        ran.store(true, Ordering::Release);
    }) as Box<dyn FnOnce() + Send + '_>]);
    let quick = t1.elapsed();
    release.store(true, Ordering::Release);
    slow.join().unwrap();
    assert!(ran.load(Ordering::Acquire), "quick job never ran");
    assert!(
        quick < Duration::from_secs(5),
        "head-of-line blocking: quick scope took {quick:?} behind a foreign slow batch"
    );
}

#[test]
fn placed_scope_runs_every_task_on_a_multi_cluster_pool() {
    let pool = two_cluster_pool();
    assert_eq!(pool.size(), 2);
    assert_eq!(pool.clusters().len(), 2);
    // Compute-bound weights follow capacity; memory-bound weights are
    // plain core counts.
    let wc = pool.cluster_weights(true);
    assert!(wc[0] > wc[1], "capacity weighting lost: {wc:?}");
    let wm = pool.cluster_weights(false);
    assert_eq!(wm[0], wm[1], "memory-bound weights should be core counts: {wm:?}");

    let hits = AtomicUsize::new(0);
    // Hints beyond the cluster count must fold into range, and every
    // task must run exactly once wherever it lands.
    let tasks: Vec<(usize, Box<dyn FnOnce() + Send + '_>)> = (0..16)
        .map(|i| {
            (
                i % 5,
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>,
            )
        })
        .collect();
    pool.scope_placed(tasks);
    assert_eq!(hits.load(Ordering::Relaxed), 16);
}

#[test]
fn plans_are_bitwise_identical_across_pools_pinning_and_affinity() {
    // The acceptance matrix: pinned / unpinned / heterogeneous pools x
    // affinity on/off x threads {1, 2, 4}, all bitwise against the
    // legacy interpreter — placement changes who computes, never what.
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 90, 4).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise);
    let mut rng = Rng::new(91);
    let inputs: Vec<Vec<f32>> =
        (0..3).map(|_| rng.normal_vec(net.input.elements())).collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();

    let topo = Topology::probe();
    let pinned = ThreadPool::with_topology(&topo, true);
    let unpinned = ThreadPool::with_topology(&topo, false);
    let hetero = two_cluster_pool();

    for threads in [1usize, 2, 4] {
        let cfg = ExecConfig { threads, ..Default::default() };
        let wants: Vec<Vec<f32>> = inputs
            .iter()
            .map(|x| run_mapmajor_legacy(&net, &params, x, &modes, cfg).unwrap())
            .collect();
        for affinity in [false, true] {
            let mut plan = PlanBuilder::new(&net, &params)
                .modes(&modes)
                .threads(threads)
                .batch(3)
                .affinity(affinity)
                .build()
                .unwrap();
            let on_global = plan.run_batch(&refs).unwrap();
            let on_pinned = with_pool(&pinned, || plan.run_batch(&refs).unwrap());
            let on_unpinned = with_pool(&unpinned, || plan.run_batch(&refs).unwrap());
            let on_hetero = with_pool(&hetero, || plan.run_batch(&refs).unwrap());
            for (i, want) in wants.iter().enumerate() {
                let label = format!("threads={threads} affinity={affinity} lane {i}");
                assert_eq!(&on_global[i], want, "global pool: {label}");
                assert_eq!(&on_pinned[i], want, "pinned pool: {label}");
                assert_eq!(&on_unpinned[i], want, "unpinned pool: {label}");
                assert_eq!(&on_hetero[i], want, "two-cluster pool: {label}");
            }
        }
    }
}

#[test]
fn placed_dispatch_keeps_generic_u_parity() {
    // u != 4 routes per-thread scratch rows through the placed
    // dispatch; the weighted chunk layout must pair them correctly.
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 92, 3).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Relaxed);
    let hetero = two_cluster_pool();
    let mut rng = Rng::new(93);
    let input = rng.normal_vec(net.input.elements());
    for threads in [2usize, 4] {
        let cfg = ExecConfig { threads, ..Default::default() };
        let want = run_mapmajor_legacy(&net, &params, &input, &modes, cfg).unwrap();
        let mut plan = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(threads)
            .affinity(true)
            .build()
            .unwrap();
        let got = with_pool(&hetero, || plan.run(&input).unwrap());
        assert_eq!(got, want, "u=3 threads={threads} placed dispatch diverged");
    }
}

#[test]
fn global_pool_is_topology_shaped() {
    let pool = cappuccino::engine::global_pool();
    assert!(pool.size() >= 1);
    assert!(!pool.clusters().is_empty());
    let total: usize = pool.clusters().iter().map(|c| c.workers).sum();
    assert_eq!(total, pool.size(), "every worker belongs to exactly one cluster");
    // Uniform-fallback hosts (and CAPPUCCINO_PIN=0) run unpinned; when
    // the probe grouped by capacity the weights must be finite and
    // positive either way.
    for w in pool.cluster_weights(true) {
        assert!(w.is_finite() && w > 0.0);
    }
}
