//! Dense f32 tensors for the native engine.
//!
//! Two layout conventions flow through the engine:
//!
//! * conventional `(C, H, W)` row-major — the baseline executor,
//! * map-major `(Cb, H, W, u)` — the optimised executor (section IV.B).
//!
//! `Tensor` is layout-agnostic storage (dims + row-major data); the
//! layout-aware wrappers below carry the semantic channel count, since a
//! map-major tensor's true `C` can be smaller than `Cb * u`.

use crate::engine::mode::{mode_cast, ArithMode};
use crate::util::ceil_div;

/// Pad map-major `(stacks, h, w, u)` data spatially by `p` into `dst`
/// (`stacks, h+2p, w+2p, u`), filling borders with `fill` — the arena
/// variant of [`MapTensor::pad_spatial`], overwriting `dst` completely.
/// The batched plan walk calls this once per live batch row, each row
/// into its own `scratch_row`-strided scratch lane.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pad_spatial_into(
    src: &[f32],
    stacks: usize,
    h: usize,
    w: usize,
    u: usize,
    p: usize,
    fill: f32,
    dst: &mut [f32],
) {
    pad_cast_into(src, stacks, h, w, u, p, fill, ArithMode::Precise, dst);
}

/// Fused spatial pad + arithmetic-mode cast into a caller-owned scratch
/// buffer: borders get `mode_cast(fill)`, the interior `mode_cast(src)`.
/// Identical to casting after padding (the legacy executor's order),
/// since `mode_cast` is elementwise.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pad_cast_into(
    src: &[f32],
    stacks: usize,
    h: usize,
    w: usize,
    u: usize,
    p: usize,
    fill: f32,
    mode: ArithMode,
    dst: &mut [f32],
) {
    let (hp, wp) = (h + 2 * p, w + 2 * p);
    debug_assert_eq!(src.len(), stacks * h * w * u, "pad_cast_into: src len");
    debug_assert_eq!(dst.len(), stacks * hp * wp * u, "pad_cast_into: dst len");
    if p == 0 {
        if mode == ArithMode::Precise {
            dst.copy_from_slice(src);
        } else {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = mode_cast(s, mode);
            }
        }
        return;
    }
    dst.fill(mode_cast(fill, mode));
    for st in 0..stacks {
        for hi in 0..h {
            let s0 = ((st * h + hi) * w) * u;
            let d0 = ((st * hp + hi + p) * wp + p) * u;
            let srow = &src[s0..s0 + w * u];
            let drow = &mut dst[d0..d0 + w * u];
            if mode == ArithMode::Precise {
                drow.copy_from_slice(srow);
            } else {
                for (d, &s) in drow.iter_mut().zip(srow) {
                    *d = mode_cast(s, mode);
                }
            }
        }
    }
}

/// Row-major dense f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "tensor dims {dims:?} vs data len {}",
            data.len()
        );
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Feature maps in map-major layout: `(Cb, H, W, u)` + true channel count.
#[derive(Debug, Clone, PartialEq)]
pub struct MapTensor {
    /// True (unpadded) channel count.
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Vector width; stacks = ceil(c/u).
    pub u: usize,
    /// `(Cb, H, W, u)` C-order data, channel-padded with zeros.
    pub data: Vec<f32>,
}

impl MapTensor {
    pub fn zeros(c: usize, h: usize, w: usize, u: usize) -> Self {
        let cb = ceil_div(c, u);
        MapTensor { c, h, w, u, data: vec![0.0; cb * h * w * u] }
    }

    /// Number of channel stacks `Cb`.
    pub fn stacks(&self) -> usize {
        ceil_div(self.c, self.u)
    }

    /// Construct from conventional `(C, H, W)` data.
    pub fn from_nchw(src: &[f32], c: usize, h: usize, w: usize, u: usize) -> Self {
        MapTensor { c, h, w, u, data: crate::layout::nchw_to_mapmajor(src, c, h, w, u) }
    }

    /// Convert back to conventional `(C, H, W)` (drops padding).
    pub fn to_nchw(&self) -> Vec<f32> {
        crate::layout::mapmajor_to_nchw(&self.data, self.c, self.h, self.w, self.u)
    }

    /// Linear offset of `(stack, h, w, lane)`.
    #[inline]
    pub fn offset(&self, stack: usize, h: usize, w: usize, lane: usize) -> usize {
        ((stack * self.h + h) * self.w + w) * self.u + lane
    }

    /// Value of true channel `ci` at `(h, w)`.
    pub fn at(&self, ci: usize, h: usize, w: usize) -> f32 {
        self.data[self.offset(ci / self.u, h, w, ci % self.u)]
    }

    /// Spatially zero-pad by `p` on each side (stays map-major).
    pub fn pad_spatial(&self, p: usize) -> MapTensor {
        if p == 0 {
            return self.clone();
        }
        let (hp, wp) = (self.h + 2 * p, self.w + 2 * p);
        let mut out = MapTensor::zeros(self.c, hp, wp, self.u);
        let stacks = self.stacks();
        for s in 0..stacks {
            for hi in 0..self.h {
                let src0 = self.offset(s, hi, 0, 0);
                let dst0 = ((s * hp + hi + p) * wp + p) * self.u;
                out.data[dst0..dst0 + self.w * self.u]
                    .copy_from_slice(&self.data[src0..src0 + self.w * self.u]);
            }
        }
        out
    }

    /// Channel-concatenate map-major tensors (fork merge). Requires every
    /// input's true channel count to be a multiple of `u` (the synthesizer
    /// checks this alignment precondition).
    pub fn concat_channels(parts: &[&MapTensor]) -> MapTensor {
        assert!(!parts.is_empty());
        let (h, w, u) = (parts[0].h, parts[0].w, parts[0].u);
        for p in parts {
            assert_eq!((p.h, p.w, p.u), (h, w, u), "concat: spatial/u mismatch");
            assert_eq!(p.c % u, 0, "concat: branch width {} not aligned to u={u}", p.c);
        }
        let c_total: usize = parts.iter().map(|p| p.c).sum();
        let mut out = MapTensor::zeros(c_total, h, w, u);
        let mut dst = 0;
        for p in parts {
            out.data[dst..dst + p.data.len()].copy_from_slice(&p.data);
            dst += p.data.len();
        }
        out
    }

    /// Flatten to the map-major linear order (the order eq. (3)-(5)
    /// indexes, and the order FC weights are reordered for).
    pub fn flatten(&self) -> Vec<f32> {
        self.data.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn from_nchw_at_roundtrip() {
        let mut rng = Rng::new(1);
        let (c, h, w, u) = (5, 3, 4, 4);
        let src = rng.normal_vec(c * h * w);
        let mm = MapTensor::from_nchw(&src, c, h, w, u);
        for ci in 0..c {
            for hi in 0..h {
                for wi in 0..w {
                    assert_eq!(mm.at(ci, hi, wi), src[(ci * h + hi) * w + wi]);
                }
            }
        }
        assert_eq!(mm.to_nchw(), src);
    }

    #[test]
    fn pad_spatial_preserves_interior() {
        let mut rng = Rng::new(2);
        let (c, h, w, u) = (4, 3, 3, 4);
        let src = rng.normal_vec(c * h * w);
        let mm = MapTensor::from_nchw(&src, c, h, w, u);
        let padded = mm.pad_spatial(2);
        assert_eq!((padded.h, padded.w), (7, 7));
        for ci in 0..c {
            assert_eq!(padded.at(ci, 0, 0), 0.0);
            assert_eq!(padded.at(ci, 2, 2), mm.at(ci, 0, 0));
            assert_eq!(padded.at(ci, 4, 4), mm.at(ci, 2, 2));
        }
    }

    #[test]
    fn concat_channels_stacks_aligned_parts() {
        let u = 4;
        let a = MapTensor::from_nchw(&vec![1.0; 4 * 2 * 2], 4, 2, 2, u);
        let b = MapTensor::from_nchw(&vec![2.0; 8 * 2 * 2], 8, 2, 2, u);
        let cat = MapTensor::concat_channels(&[&a, &b]);
        assert_eq!(cat.c, 12);
        assert_eq!(cat.at(0, 0, 0), 1.0);
        assert_eq!(cat.at(4, 1, 1), 2.0);
        assert_eq!(cat.at(11, 0, 1), 2.0);
    }

    #[test]
    #[should_panic]
    fn concat_rejects_unaligned() {
        let u = 4;
        let a = MapTensor::from_nchw(&vec![1.0; 3 * 2 * 2], 3, 2, 2, u); // c=3 unaligned
        let b = MapTensor::from_nchw(&vec![2.0; 4 * 2 * 2], 4, 2, 2, u);
        MapTensor::concat_channels(&[&a, &b]);
    }
}
