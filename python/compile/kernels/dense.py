"""Layer-1 Pallas kernel: fully-connected (dense) layer on the map-major
flattened activation vector.

AlexNet spends a large fraction of its parameters in FC layers; Cappuccino
reorders FC weights at compile time so that the incoming activation can be
consumed directly in map-major flatten order — the FC counterpart of the
zero-overhead OFM reordering (section IV.B.1). The row permutation lives
in :func:`fc_weights_for_mapmajor`.

The kernel tiles the output dimension across the grid; each program
computes ``TILE_O`` outputs as a (TILE_O, I) x (I,) contraction — the
lane-vectorised MAC of Fig. 6 with the whole input vector as the lane
axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .conv import _mode_cast

TILE_O = 128


def fc_weights_for_mapmajor(w: jnp.ndarray, c: int, h: int, wdim: int,
                            u: int) -> jnp.ndarray:
    """Reorder FC weight columns for a map-major flattened input.

    ``w`` is ``(O, I)`` with ``I = c*h*wdim`` laid out for a *row-major*
    (NCHW-flatten) input. The returned matrix is ``(O, Ib)`` with
    ``Ib = ceil(c/u)*u*h*wdim`` whose columns match ``(Cb, H, W, u)``
    C-order flattening — zero columns inserted for channel padding. This
    is compile-time parameter reordering: zero runtime cost.
    """
    o, i = w.shape
    if i != c * h * wdim:
        raise ValueError(f"FC input dim {i} != {c}*{h}*{wdim}")
    cb = -(-c // u)
    # (O, C, H, W) -> pad C -> (O, Cb, u, H, W) -> (O, Cb, H, W, u) -> flat
    w4 = w.reshape(o, c, h, wdim)
    w4 = jnp.pad(w4, ((0, 0), (0, cb * u - c), (0, 0), (0, 0)))
    w4 = w4.reshape(o, cb, u, h, wdim).transpose(0, 1, 3, 4, 2)
    return w4.reshape(o, cb * h * wdim * u)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, mode: str):
    """One grid step: ``TILE_O`` outputs for one batch element."""
    x = _mode_cast(x_ref[0], mode)            # (I,)
    w = _mode_cast(w_ref[...], mode)          # (TILE_O, I)
    o_ref[0] = jnp.einsum("oi,i->o", w, x,
                          preferred_element_type=jnp.float32) + b_ref[...]


def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, *,
          mode: str = "precise") -> jnp.ndarray:
    """Dense layer ``(B, I) x (O, I) -> (B, O)`` via Pallas.

    ``O`` is padded to a multiple of ``TILE_O`` at trace time; padding is
    sliced off before returning.
    """
    if x.ndim != 2:
        raise ValueError(f"x must be (B, I), got {x.shape}")
    bsz, i = x.shape
    o, i_w = w.shape
    if i_w != i:
        raise ValueError(f"weight input dim {i_w} != activation dim {i}")
    ob = -(-o // TILE_O)
    w_p = jnp.pad(w, ((0, ob * TILE_O - o), (0, 0)))
    b_p = jnp.pad(b, (0, ob * TILE_O - o))

    kern = functools.partial(_dense_kernel, mode=mode)
    out = pl.pallas_call(
        kern,
        grid=(bsz, ob),
        in_specs=[
            pl.BlockSpec((1, i), lambda bi, oi: (bi, 0)),
            pl.BlockSpec((TILE_O, i), lambda bi, oi: (oi, 0)),
            pl.BlockSpec((TILE_O,), lambda bi, oi: (oi,)),
        ],
        out_specs=pl.BlockSpec((1, TILE_O), lambda bi, oi: (bi, oi)),
        out_shape=jax.ShapeDtypeStruct((bsz, ob * TILE_O), jnp.float32),
        interpret=True,
    )(x, w_p, b_p)
    return out[:, :o]
