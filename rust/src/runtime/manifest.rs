//! AOT artifact manifest (`artifacts/manifest.json`) — the contract
//! between the Python compile path and the Rust runtime.
//!
//! The manifest records every lowered artifact (net, mode, batch, HLO
//! file, input/output shapes, parameter order + map-major shapes) plus
//! the expanded network specs, so the runtime can build PJRT argument
//! lists and the model IR without touching Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::model::Network;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Shape of one parameter pair in an artifact's argument list.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub w_dims: Vec<usize>,
    pub b_dims: Vec<usize>,
}

impl ParamSpec {
    pub fn w_len(&self) -> usize {
        self.w_dims.iter().product()
    }

    pub fn b_len(&self) -> usize {
        self.b_dims.iter().product()
    }
}

/// One lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub net: String,
    /// Arithmetic mode baked into the artifact ("precise"/"imprecise").
    pub mode: String,
    pub batch: usize,
    /// HLO text file, relative to the artifacts dir.
    pub hlo: String,
    /// `(B, Cb, H, W, u)` map-major input shape.
    pub input_shape: Vec<usize>,
    /// `(B, classes)`.
    pub output_shape: Vec<usize>,
    pub params: Vec<ParamSpec>,
}

impl ArtifactSpec {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Vector width used by every artifact.
    pub u: usize,
    pub tinynet_val_accuracy: f64,
    pub artifacts: Vec<ArtifactSpec>,
    /// Expanded network specs, rebuilt into the Rust IR.
    pub nets: BTreeMap<String, Network>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Io(std::io::Error::new(
                e.kind(),
                format!("{} (run `make artifacts` first)", path.display()),
            ))
        })?;
        let json = Json::parse(&text)?;
        let u = json.get("u")?.as_usize()?;
        let tinynet_val_accuracy = json
            .opt("tinynet_val_accuracy")
            .map(|v| v.as_f64())
            .transpose()?
            .unwrap_or(0.0);

        let mut artifacts = Vec::new();
        for a in json.get("artifacts")?.as_arr()? {
            let params = a
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        w_dims: p.get("w")?.usize_vec()?,
                        b_dims: p.get("b")?.usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: a.get("name")?.as_str()?.to_string(),
                net: a.get("net")?.as_str()?.to_string(),
                mode: a.get("mode")?.as_str()?.to_string(),
                batch: a.get("batch")?.as_usize()?,
                hlo: a.get("hlo")?.as_str()?.to_string(),
                input_shape: a.get("input_shape")?.usize_vec()?,
                output_shape: a.get("output_shape")?.usize_vec()?,
                params,
            });
        }

        let mut nets = BTreeMap::new();
        for (name, net_json) in json.get("nets")?.as_obj()? {
            nets.insert(name.clone(), Network::from_manifest(name, net_json)?);
        }

        Ok(Manifest { dir, u, tinynet_val_accuracy, artifacts, nets })
    }

    /// Find an artifact by (net, mode, batch).
    pub fn find(&self, net: &str, mode: &str, batch: usize) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.net == net && a.mode == mode && a.batch == batch)
            .ok_or_else(|| {
                Error::Invalid(format!("no artifact for net={net} mode={mode} batch={batch}"))
            })
    }

    /// All batch sizes available for (net, mode), ascending.
    pub fn batch_sizes(&self, net: &str, mode: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.net == net && a.mode == mode)
            .map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.hlo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        crate::artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn load_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        assert_eq!(m.u, 4);
        assert!(m.artifacts.len() >= 11);
        assert!(m.tinynet_val_accuracy > 0.9);
        // Every referenced HLO file exists.
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{}", a.name);
        }
    }

    #[test]
    fn find_and_batches() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        let a = m.find("tinynet", "precise", 8).unwrap();
        assert_eq!(a.input_shape, vec![8, 1, 16, 16, 4]);
        assert_eq!(a.output_shape, vec![8, 8]);
        assert_eq!(m.batch_sizes("tinynet", "precise"), vec![1, 4, 8]);
        assert!(m.find("tinynet", "precise", 3).is_err());
    }

    #[test]
    fn manifest_nets_match_zoo() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load(crate::artifacts_dir()).unwrap();
        // The manifest's expanded specs must rebuild into the same IR the
        // Rust zoo defines — single-source-of-truth cross-check.
        for (name, net) in &m.nets {
            let zoo_net = crate::model::zoo::by_name(name).expect(name);
            assert_eq!(
                net.param_layer_names(),
                zoo_net.param_layer_names(),
                "{name}: param layer order"
            );
            assert_eq!(net.input, zoo_net.input, "{name}");
            let a = crate::model::shapes::infer(net).unwrap();
            let b = crate::model::shapes::infer(&zoo_net).unwrap();
            assert_eq!(a.output, b.output, "{name}");
            assert!((a.total_flops() - b.total_flops()).abs() < 1.0, "{name}");
        }
    }

    #[test]
    fn param_spec_lens() {
        let p = ParamSpec {
            name: "c".into(),
            w_dims: vec![4, 4, 1, 3, 3, 4],
            b_dims: vec![4, 4],
        };
        assert_eq!(p.w_len(), 576);
        assert_eq!(p.b_len(), 16);
    }
}
