//! Backend registry for staged execution — resolving a
//! [`BackendTarget`] to the executor a pipeline stage runs on.
//!
//! A staged plan ([`crate::engine::hetero::StagedPlan`]) cuts the step
//! sequence at backend boundaries; each stage then needs something to
//! *run* its step range. That something is a [`StageExecutor`]:
//!
//! * [`StageExecutor::Native`] — the in-process CPU engine: the stage's
//!   range walks through [`crate::engine::ExecutionPlan`]'s normal
//!   step executor. The default, and what every layer runs on unless a
//!   schedule says otherwise.
//! * [`StageExecutor::Mock`] — the deterministic mock accelerator: the
//!   **same** native walk (bitwise-identical math, so partitioning and
//!   transfer correctness are testable against the single-backend
//!   oracles) plus a configurable per-layer latency ([`MockLatency`])
//!   slept after the walk — the knob that makes pipeline-overlap wins
//!   measurable without accelerator hardware.
//! * [`BackendTarget::Pjrt`] has **no** stage executor yet: the PJRT
//!   runtime ([`crate::runtime`]) executes whole lowered artifacts, not
//!   step ranges, so resolving it reports a typed
//!   [`Error::Xla`] pointing at the vendoring patch
//!   (see the [`crate::runtime`] module header). Schedules may still
//!   *name* it — verification and `cappuccino check` work — but
//!   execution requires `Native`/`Mock` stages.
//!
//! The [`BackendRegistry`] is the lookup table serve and the autotuner
//! share; [`BackendRegistry::from_env`] reads the mock latency model
//! from `CAPPUCCINO_MOCK_LATENCY` (e.g. `conv2:300,*:50`, microseconds)
//! so CI's `pipeline-smoke` job can shape a bottleneck without
//! recompiling.

use std::collections::BTreeMap;
use std::ops::Range;
use std::time::Duration;

use crate::engine::plan::ExecutionPlan;
use crate::engine::schedule::BackendTarget;
use crate::util::error::{Error, Result};

/// Deterministic per-layer latency model of the mock accelerator,
/// in microseconds. Parameterised layers a stage executes look up
/// their own entry, falling back to the `*` default (0 when unset);
/// structural steps (reorders, pools, transfers) add nothing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MockLatency {
    per_layer: BTreeMap<String, u64>,
    default_us: u64,
}

impl MockLatency {
    /// Parse a latency spec: comma-separated `layer:micros` entries,
    /// with `*` naming the default for unlisted layers. Example:
    /// `conv2:300,*:50` — conv2 costs 300 µs, every other
    /// parameterised layer 50 µs. Malformed entries are a typed
    /// [`Error::Config`]; the empty string is the all-zero model.
    pub fn parse(spec: &str) -> Result<MockLatency> {
        let mut lat = MockLatency::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (name, us) = entry.split_once(':').ok_or_else(|| {
                Error::Config(format!(
                    "mock latency entry {entry:?} is not `layer:micros` (spec {spec:?})"
                ))
            })?;
            let us: u64 = us.trim().parse().map_err(|_| {
                Error::Config(format!(
                    "mock latency entry {entry:?}: {us:?} is not a microsecond count"
                ))
            })?;
            match name.trim() {
                "*" => lat.default_us = us,
                layer => {
                    lat.per_layer.insert(layer.to_string(), us);
                }
            }
        }
        Ok(lat)
    }

    /// The modelled latency of one layer, in microseconds.
    pub fn latency_us(&self, layer: &str) -> u64 {
        self.per_layer.get(layer).copied().unwrap_or(self.default_us)
    }

    /// Does this model ever sleep at all?
    pub fn is_zero(&self) -> bool {
        self.default_us == 0 && self.per_layer.values().all(|&us| us == 0)
    }
}

/// The lookup table from [`BackendTarget`] to [`StageExecutor`] —
/// shared by the pipelined serve backend and the autotuner's
/// split search, so both run candidate stages on the same substrates.
#[derive(Debug, Clone, Default)]
pub struct BackendRegistry {
    mock: MockLatency,
}

impl BackendRegistry {
    /// A registry with an explicit mock latency model.
    pub fn new(mock: MockLatency) -> BackendRegistry {
        BackendRegistry { mock }
    }

    /// Read the mock latency model from `CAPPUCCINO_MOCK_LATENCY`
    /// (unset = the all-zero model). A malformed spec is a typed
    /// [`Error::Config`] — never silently zero.
    pub fn from_env() -> Result<BackendRegistry> {
        let mock = match std::env::var("CAPPUCCINO_MOCK_LATENCY") {
            Ok(spec) => MockLatency::parse(&spec)?,
            Err(_) => MockLatency::default(),
        };
        Ok(BackendRegistry { mock })
    }

    /// The mock latency model this registry resolves `Mock` stages
    /// with.
    pub fn mock_latency(&self) -> &MockLatency {
        &self.mock
    }

    /// Resolve a backend target to its stage executor. `Pjrt` reports
    /// [`Error::Xla`]: the PJRT runtime executes whole artifacts, not
    /// plan step ranges (see the module header for the vendoring
    /// patch).
    pub fn executor(&self, target: BackendTarget) -> Result<StageExecutor> {
        match target {
            BackendTarget::Native => Ok(StageExecutor::Native),
            BackendTarget::Mock => Ok(StageExecutor::Mock(self.mock.clone())),
            BackendTarget::Pjrt => Err(Error::Xla(
                "backend `pjrt` has no stage executor: the PJRT runtime runs whole \
                 lowered artifacts, not plan step ranges — vendor the `xla` crate \
                 (see rust/src/runtime/mod.rs) or place these layers on `native`/`mock`"
                    .into(),
            )),
        }
    }
}

/// What actually runs one stage's step range. Cheap to clone (the mock
/// model is a small map); each pipeline worker owns one.
#[derive(Debug, Clone)]
pub enum StageExecutor {
    /// The in-process CPU engine.
    Native,
    /// The native walk plus the modelled per-layer sleep.
    Mock(MockLatency),
}

impl StageExecutor {
    /// Execute `range` of `plan`'s steps over `live` batch rows
    /// ([`ExecutionPlan::exec_range`] — fault-injection and
    /// panic-containment semantics are the plan's own). The mock
    /// executor runs the identical walk, then sleeps the summed
    /// modelled latency of the parameterised layers in the range —
    /// after the math, so injected latency can never reorder or
    /// perturb it.
    pub(crate) fn run_stage(
        &self,
        plan: &mut ExecutionPlan,
        range: Range<usize>,
        images: &[&[f32]],
        live: usize,
    ) -> Result<()> {
        match self {
            StageExecutor::Native => plan.exec_range(images, live, range),
            StageExecutor::Mock(lat) => {
                plan.exec_range(images, live, range.clone())?;
                let mut us = 0u64;
                let mut seen: Option<&str> = None;
                for i in range {
                    let label = plan.labels[i].as_str();
                    // One charge per layer, not per step: a layer's
                    // reorder/pad steps share its label.
                    if plan.sched.layers.contains_key(label) && seen != Some(label) {
                        us += lat.latency_us(label);
                        seen = Some(label);
                    }
                }
                if us > 0 {
                    std::thread::sleep(Duration::from_micros(us));
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineParams, PlanBuilder};
    use crate::model::zoo;
    use crate::util::rng::Rng;

    #[test]
    fn latency_spec_parses_and_defaults() {
        let lat = MockLatency::parse("conv2:300, *:50").unwrap();
        assert_eq!(lat.latency_us("conv2"), 300);
        assert_eq!(lat.latency_us("conv1"), 50);
        assert!(!lat.is_zero());
        assert!(MockLatency::parse("").unwrap().is_zero());
        assert!(matches!(MockLatency::parse("conv2"), Err(Error::Config(_))));
        assert!(matches!(MockLatency::parse("conv2:fast"), Err(Error::Config(_))));
    }

    #[test]
    fn registry_resolves_targets() {
        let reg = BackendRegistry::new(MockLatency::parse("*:1").unwrap());
        assert!(matches!(reg.executor(BackendTarget::Native), Ok(StageExecutor::Native)));
        assert!(matches!(reg.executor(BackendTarget::Mock), Ok(StageExecutor::Mock(_))));
        assert!(matches!(reg.executor(BackendTarget::Pjrt), Err(Error::Xla(_))));
    }

    #[test]
    fn mock_executor_is_bitwise_native() {
        // The mock accelerator is the native walk plus a sleep: output
        // must be bitwise identical to the plain plan.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let mut native = PlanBuilder::new(&net, &params).build().unwrap();
        let mut mocked = PlanBuilder::new(&net, &params).build().unwrap();
        let img = Rng::new(7).normal_vec(native.input_len());
        let want = native.run(&img).unwrap();
        let ex = StageExecutor::Mock(MockLatency::parse("conv1:1").unwrap());
        mocked.validate_batch(&[&img[..]]).unwrap();
        ex.run_stage(&mut mocked, 0..mocked.step_count(), &[&img[..]], 1).unwrap();
        let mut got = vec![0.0f32; mocked.output_len()];
        mocked.extract_row_into(0, &mut got);
        assert_eq!(got, want);
    }
}
