//! Non-conv layer operations for the native engine, in both layouts.
//!
//! Map-major variants power the optimised executor; row-major variants
//! power the single-threaded baseline. Pooling and GAP are
//! layout-preserving in map-major (spatial-only windows); LRN crosses
//! stack boundaries and therefore indexes through the true channel axis.
//!
//! Every op has an `_into` core writing into a caller-owned buffer —
//! the compiled plan executor's arena path — plus the original
//! allocating wrapper for ad-hoc use. Dense weights follow the baked
//! contract of [`crate::engine::conv`]: the `mode` argument casts the
//! activations only; weights must already be in the mode's domain.

use crate::engine::mode::{mode_cast, ArithMode};
use crate::engine::simd::{self, F32Lanes, I8Dot};
use crate::engine::tensor::MapTensor;

/// Output spatial size. Shape inference validates `k <= size + 2p`
/// ahead of time; a direct call with a too-large window panics with a
/// clear message instead of underflowing.
#[inline]
fn out_size(size: usize, k: usize, s: usize, p: usize) -> usize {
    let padded = size + 2 * p;
    assert!(
        padded >= k,
        "pool window k={k} larger than padded input {padded} (run shapes::infer first)"
    );
    (padded - k) / s + 1
}

// ---------------------------------------------------------------------------
// Map-major ops
// ---------------------------------------------------------------------------

/// Max pooling, map-major, layout-preserving.
pub fn maxpool_mm(x: &MapTensor, k: usize, s: usize, p: usize) -> MapTensor {
    pool_mm(x, k, s, p, true)
}

/// Average pooling, map-major. Caffe-style count includes padding
/// (divisor is always k*k), matching the Python layers.
pub fn avgpool_mm(x: &MapTensor, k: usize, s: usize, p: usize) -> MapTensor {
    pool_mm(x, k, s, p, false)
}

fn pool_mm(x: &MapTensor, k: usize, s: usize, p: usize, is_max: bool) -> MapTensor {
    let padded = if is_max {
        x.pad_spatial_with(p, f32::NEG_INFINITY)
    } else {
        x.pad_spatial(p)
    };
    let (hp, wp, u) = (padded.h, padded.w, padded.u);
    let ho = out_size(x.h, k, s, p);
    let wo = out_size(x.w, k, s, p);
    let mut out = MapTensor::zeros(x.c, ho, wo, u);
    pool_mm_core(&padded.data, hp, wp, u, x.stacks(), &mut out.data, ho, wo, k, s, is_max);
    out
}

/// Pooling inner loops over pre-padded map-major data, writing into a
/// caller-owned buffer (`stacks * ho * wo * u` elements).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pool_mm_core(
    padded: &[f32],
    hp: usize,
    wp: usize,
    u: usize,
    stacks: usize,
    out: &mut [f32],
    ho: usize,
    wo: usize,
    k: usize,
    s: usize,
    is_max: bool,
) {
    debug_assert_eq!(out.len(), stacks * ho * wo * u, "pool_mm_core: out len");
    for cs in 0..stacks {
        for oh in 0..ho {
            for ow in 0..wo {
                let dst = ((cs * ho + oh) * wo + ow) * u;
                let acc = &mut out[dst..dst + u];
                if is_max {
                    acc.fill(f32::NEG_INFINITY);
                } else {
                    acc.fill(0.0);
                }
                for kh in 0..k {
                    let base = ((cs * hp + oh * s + kh) * wp + ow * s) * u;
                    for kw in 0..k {
                        let src = &padded[base + kw * u..base + (kw + 1) * u];
                        for l in 0..u {
                            if is_max {
                                if src[l] > acc[l] {
                                    acc[l] = src[l];
                                }
                            } else {
                                acc[l] += src[l];
                            }
                        }
                    }
                }
                if !is_max {
                    let inv = 1.0 / (k * k) as f32;
                    for a in acc.iter_mut() {
                        *a *= inv;
                    }
                }
            }
        }
    }
}

impl MapTensor {
    /// Spatial padding with an arbitrary fill value (max-pool needs -inf).
    pub fn pad_spatial_with(&self, p: usize, fill: f32) -> MapTensor {
        if p == 0 {
            return self.clone();
        }
        let (hp, wp) = (self.h + 2 * p, self.w + 2 * p);
        let mut out = MapTensor::zeros(self.c, hp, wp, self.u);
        crate::engine::tensor::pad_spatial_into(
            &self.data,
            self.stacks(),
            self.h,
            self.w,
            self.u,
            p,
            fill,
            &mut out.data,
        );
        out
    }
}

/// Local response normalisation across channels (AlexNet/GoogLeNet).
pub fn lrn_mm(x: &MapTensor, size: usize, alpha: f32, beta: f32) -> MapTensor {
    let (c, h, w, u) = (x.c, x.h, x.w, x.u);
    let mut out = MapTensor::zeros(c, h, w, u);
    lrn_mm_into(&x.data, c, h, w, u, size, alpha, beta, &mut out.data);
    out
}

/// LRN inner loops over raw map-major data. Channel-padding lanes are
/// never written (callers keep them zero — the arena invariant).
#[allow(clippy::too_many_arguments)]
pub(crate) fn lrn_mm_into(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    u: usize,
    size: usize,
    alpha: f32,
    beta: f32,
    out: &mut [f32],
) {
    let half = size / 2;
    let at = |ci: usize, hi: usize, wi: usize| x[(((ci / u) * h + hi) * w + wi) * u + ci % u];
    for hi in 0..h {
        for wi in 0..w {
            for ci in 0..c {
                let lo = ci.saturating_sub(half);
                let hi_c = (ci + half).min(c - 1);
                let mut ssum = 0.0f32;
                for cj in lo..=hi_c {
                    let v = at(cj, hi, wi);
                    ssum += v * v;
                }
                let v = at(ci, hi, wi);
                let denom = (1.0 + alpha / size as f32 * ssum).powf(beta);
                out[(((ci / u) * h + hi) * w + wi) * u + ci % u] = v / denom;
            }
        }
    }
}

/// Global average pooling: `(Cb, H, W, u)` → flat `(C,)` (true channels).
pub fn gap_mm(x: &MapTensor) -> Vec<f32> {
    let mut out = vec![0.0f32; x.c];
    gap_mm_into(&x.data, x.c, x.h, x.w, x.u, &mut out);
    out
}

/// GAP inner loop over raw map-major data (u = 1 covers row-major too).
pub(crate) fn gap_mm_into(x: &[f32], c: usize, h: usize, w: usize, u: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), c);
    let inv = 1.0 / (h * w) as f32;
    for (ci, o) in out.iter_mut().enumerate() {
        let mut sum = 0.0f32;
        for hi in 0..h {
            for wi in 0..w {
                sum += x[(((ci / u) * h + hi) * w + wi) * u + ci % u];
            }
        }
        *o = sum * inv;
    }
}

/// Dense layer `(O, I) x (I,) + (O,)`, vectorisable inner loop.
/// `w` must be baked into `mode`'s domain; `mode` casts `x` only.
pub fn dense(x: &[f32], w: &[f32], b: &[f32], o: usize, relu: bool, mode: ArithMode) -> Vec<f32> {
    let x_c;
    let x: &[f32] = if mode == ArithMode::Precise {
        x
    } else {
        x_c = x.iter().map(|&v| mode_cast(v, mode)).collect::<Vec<_>>();
        &x_c
    };
    let mut out = vec![0.0f32; o];
    dense_into(x, w, b, o, relu, &mut out);
    out
}

/// Dense inner loop over a pre-cast activation vector, writing into a
/// caller-owned buffer.
pub(crate) fn dense_into(x: &[f32], w: &[f32], b: &[f32], o: usize, relu: bool, out: &mut [f32]) {
    let i = x.len();
    assert_eq!(w.len(), o * i, "dense: weight len");
    assert_eq!(b.len(), o, "dense: bias len");
    debug_assert_eq!(out.len(), o);
    for oi in 0..o {
        let row = &w[oi * i..(oi + 1) * i];
        let mut acc = 0.0f32;
        for l in 0..i {
            acc += x[l] * row[l];
        }
        acc += b[oi];
        if relu && acc < 0.0 {
            acc = 0.0;
        }
        out[oi] = acc;
    }
}

/// Batched dense: `rows` pre-cast activation vectors (`x_stride` apart,
/// each `i` long) against one baked weight matrix, output rows written
/// contiguously (`o` apart). Rows are chunked over the persistent pool
/// in **one** parallel region — per-row results are computed by the
/// exact same [`dense_into`] loop, so batching is bitwise invisible.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_rows_into(
    xs: &[f32],
    x_stride: usize,
    i: usize,
    w: &[f32],
    b: &[f32],
    o: usize,
    relu: bool,
    out: &mut [f32],
    rows: usize,
    threads: usize,
) {
    debug_assert!(xs.len() >= (rows.saturating_sub(1)) * x_stride + i);
    debug_assert!(out.len() >= rows * o);
    if threads <= 1 || rows <= 1 {
        for r in 0..rows {
            let x = &xs[r * x_stride..][..i];
            dense_into(x, w, b, o, relu, &mut out[r * o..(r + 1) * o]);
        }
        return;
    }
    crate::engine::parallel::parallel_for_slices(
        rows,
        threads,
        o,
        &mut out[..rows * o],
        &|range: std::ops::Range<usize>, slice: &mut [f32]| {
            for (j, r) in range.enumerate() {
                let x = &xs[r * x_stride..][..i];
                dense_into(x, w, b, o, relu, &mut slice[j * o..(j + 1) * o]);
            }
        },
    );
}

/// Dense inner loop over **column-blocked packed panels**
/// ([`crate::layout::pack_dense_panels`]): one pass over the activation
/// vector feeds [`crate::layout::DENSE_BLOCK`] output neurons from
/// strictly sequential panel reads, instead of one full `x` pass per
/// neuron. Per-output accumulation order (columns ascending, bias
/// last) matches [`dense_into`] exactly — bitwise identical output.
/// `vec` selects the [`F32Lanes`] register kernel (`DENSE_BLOCK` *is*
/// the `f32x4` width), which performs the identical per-lane op
/// sequence — still bitwise identical on every backend.
pub(crate) fn dense_packed_into(
    x: &[f32],
    w_pack: &[f32],
    b: &[f32],
    o: usize,
    relu: bool,
    vec: bool,
    out: &mut [f32],
) {
    use crate::layout::DENSE_BLOCK as BL;
    let i = x.len();
    debug_assert_eq!(
        w_pack.len(),
        crate::util::ceil_div(o, BL) * i * BL,
        "dense_packed_into: weight len"
    );
    debug_assert_eq!(b.len(), o, "dense_packed_into: bias len");
    debug_assert_eq!(out.len(), o);
    if i == 0 {
        for (v, &bv) in out.iter_mut().zip(b) {
            *v = if relu && bv < 0.0 { 0.0 } else { bv };
        }
        return;
    }
    if vec {
        #[cfg(target_arch = "x86_64")]
        if simd::enabled() {
            dense_packed_lanes::<simd::SseF32x4>(x, w_pack, b, o, relu, out);
            return;
        }
        dense_packed_lanes::<simd::ScalarF32x4>(x, w_pack, b, o, relu, out);
        return;
    }
    for (blk, panel) in w_pack.chunks_exact(i * BL).enumerate() {
        let o0 = blk * BL;
        let live = BL.min(o - o0); // remainder block
        let mut acc = [0.0f32; BL];
        for (col, &xv) in x.iter().enumerate() {
            let wv = &panel[col * BL..(col + 1) * BL];
            for (a, &wl) in acc.iter_mut().zip(wv) {
                *a += xv * wl;
            }
        }
        for (ol, &a) in acc.iter().enumerate().take(live) {
            let mut v = a + b[o0 + ol];
            if relu && v < 0.0 {
                v = 0.0;
            }
            out[o0 + ol] = v;
        }
    }
}

/// [`dense_packed_into`]'s register kernel: one `f32x4` accumulator per
/// column block (`V::N == DENSE_BLOCK`), broadcast-multiply per column
/// — the same `(0 + x0*w0) + x1*w1 + ...` per-lane sequence as the
/// scalar loop, hence bitwise identical.
fn dense_packed_lanes<V: F32Lanes>(
    x: &[f32],
    w_pack: &[f32],
    b: &[f32],
    o: usize,
    relu: bool,
    out: &mut [f32],
) {
    use crate::layout::DENSE_BLOCK as BL;
    let i = x.len();
    debug_assert_eq!(V::N, BL);
    for (blk, panel) in w_pack.chunks_exact(i * BL).enumerate() {
        let o0 = blk * BL;
        let live = BL.min(o - o0);
        let mut acc_v = V::zero();
        for (col, &xv) in x.iter().enumerate() {
            acc_v = acc_v.add(V::splat(xv).mul(V::load(&panel[col * BL..])));
        }
        let mut acc = [0.0f32; BL];
        acc_v.store(&mut acc);
        for (ol, &a) in acc.iter().enumerate().take(live) {
            let mut v = a + b[o0 + ol];
            if relu && v < 0.0 {
                v = 0.0;
            }
            out[o0 + ol] = v;
        }
    }
}

/// Batched [`dense_packed_into`]: drop-in packed analogue of
/// [`dense_rows_into`] (same chunking, same bitwise-invisible batching).
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_rows_packed_into(
    xs: &[f32],
    x_stride: usize,
    i: usize,
    w_pack: &[f32],
    b: &[f32],
    o: usize,
    relu: bool,
    vec: bool,
    out: &mut [f32],
    rows: usize,
    threads: usize,
) {
    debug_assert!(xs.len() >= (rows.saturating_sub(1)) * x_stride + i);
    debug_assert!(out.len() >= rows * o);
    if threads <= 1 || rows <= 1 {
        for r in 0..rows {
            let x = &xs[r * x_stride..][..i];
            dense_packed_into(x, w_pack, b, o, relu, vec, &mut out[r * o..(r + 1) * o]);
        }
        return;
    }
    crate::engine::parallel::parallel_for_slices(
        rows,
        threads,
        o,
        &mut out[..rows * o],
        &|range: std::ops::Range<usize>, slice: &mut [f32]| {
            for (j, r) in range.enumerate() {
                let x = &xs[r * x_stride..][..i];
                dense_packed_into(x, w_pack, b, o, relu, vec, &mut slice[j * o..(j + 1) * o]);
            }
        },
    );
}

/// Quantized dense over the same column-blocked panel layout
/// ([`crate::layout::pack_dense_panels_i8`]): columns are consumed in
/// pairs — one [`I8Dot::from_i8`] load covers two columns' weight
/// blocks, [`I8Dot::splat_pair`] broadcasts both activations — with a
/// scalar-i32 tail for an odd final column. Output requantizes as
/// `acc * sc + bias` (then ReLU). Integer arithmetic is exact, so
/// backend choice never changes results.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_i8_packed_into(
    xq: &[i8],
    w_pack: &[i8],
    b: &[f32],
    o: usize,
    relu: bool,
    sc: f32,
    out: &mut [f32],
) {
    #[cfg(target_arch = "x86_64")]
    if simd::enabled() {
        dense_i8_packed_impl::<simd::SseI16x8>(xq, w_pack, b, o, relu, sc, out);
        return;
    }
    dense_i8_packed_impl::<simd::ScalarI16x8>(xq, w_pack, b, o, relu, sc, out);
}

fn dense_i8_packed_impl<D: I8Dot>(
    xq: &[i8],
    w_pack: &[i8],
    b: &[f32],
    o: usize,
    relu: bool,
    sc: f32,
    out: &mut [f32],
) {
    use crate::layout::DENSE_BLOCK as BL;
    let i = xq.len();
    debug_assert_eq!(
        w_pack.len(),
        crate::util::ceil_div(o, BL) * i * BL,
        "dense_i8_packed_into: weight len"
    );
    debug_assert_eq!(b.len(), o, "dense_i8_packed_into: bias len");
    debug_assert_eq!(out.len(), o);
    if i == 0 {
        for (v, &bv) in out.iter_mut().zip(b) {
            *v = if relu && bv < 0.0 { 0.0 } else { bv };
        }
        return;
    }
    for (blk, panel) in w_pack.chunks_exact(i * BL).enumerate() {
        let o0 = blk * BL;
        let live = BL.min(o - o0);
        let mut acc8 = D::acc_zero();
        let mut tail = [0i32; BL];
        let pairs = i / 2;
        for c in 0..pairs {
            let xp = D::splat_pair(xq[2 * c], xq[2 * c + 1]);
            let w = D::from_i8(&panel[2 * c * BL..2 * c * BL + 2 * BL]);
            acc8 = D::acc_add(acc8, w.mul(xp));
        }
        if i % 2 == 1 {
            let c = i - 1;
            let xv = xq[c] as i32;
            for (ol, t) in tail.iter_mut().enumerate() {
                *t += xv * panel[c * BL + ol] as i32;
            }
        }
        let v = D::acc_get(acc8);
        for ol in 0..live {
            let q = v[ol] + v[ol + BL] + tail[ol];
            let mut val = q as f32 * sc + b[o0 + ol];
            if relu && val < 0.0 {
                val = 0.0;
            }
            out[o0 + ol] = val;
        }
    }
}

/// Batched [`dense_i8_packed_into`]: the quantized analogue of
/// [`dense_rows_packed_into`]; each row carries its own activation
/// scale (`x_scales[r] * w_scale` is the row's requantize factor).
#[allow(clippy::too_many_arguments)]
pub(crate) fn dense_i8_rows_packed_into(
    xqs: &[i8],
    x_scales: &[f32],
    x_stride: usize,
    i: usize,
    w_pack: &[i8],
    w_scale: f32,
    b: &[f32],
    o: usize,
    relu: bool,
    out: &mut [f32],
    rows: usize,
    threads: usize,
) {
    debug_assert!(xqs.len() >= (rows.saturating_sub(1)) * x_stride + i);
    debug_assert!(x_scales.len() >= rows);
    debug_assert!(out.len() >= rows * o);
    if threads <= 1 || rows <= 1 {
        for r in 0..rows {
            let x = &xqs[r * x_stride..][..i];
            let sc = x_scales[r] * w_scale;
            dense_i8_packed_into(x, w_pack, b, o, relu, sc, &mut out[r * o..(r + 1) * o]);
        }
        return;
    }
    crate::engine::parallel::parallel_for_slices(
        rows,
        threads,
        o,
        &mut out[..rows * o],
        &|range: std::ops::Range<usize>, slice: &mut [f32]| {
            for (j, r) in range.enumerate() {
                let x = &xqs[r * x_stride..][..i];
                let sc = x_scales[r] * w_scale;
                dense_i8_packed_into(x, w_pack, b, o, relu, sc, &mut slice[j * o..(j + 1) * o]);
            }
        },
    );
}

/// In-place ReLU.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Numerically stable softmax.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    softmax_into(x, &mut out);
    out
}

/// Softmax into a caller-owned buffer.
pub(crate) fn softmax_into(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for (o, &v) in out.iter_mut().zip(x) {
        let e = (v - max).exp();
        *o = e;
        sum += e;
    }
    for o in out.iter_mut() {
        *o /= sum;
    }
}

// ---------------------------------------------------------------------------
// Row-major (baseline) ops
// ---------------------------------------------------------------------------

/// Max/avg pooling over `(C, H, W)` row-major.
#[allow(clippy::too_many_arguments)]
pub fn pool_nchw(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    s: usize,
    p: usize,
    is_max: bool,
) -> (Vec<f32>, usize, usize) {
    let ho = out_size(h, k, s, p);
    let wo = out_size(w, k, s, p);
    let mut out = vec![0.0f32; c * ho * wo];
    pool_nchw_into(x, c, h, w, k, s, p, is_max, ho, wo, &mut out);
    (out, ho, wo)
}

/// Row-major pooling into a caller-owned buffer (padding handled by
/// bounds checks — no scratch needed).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pool_nchw_into(
    x: &[f32],
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    s: usize,
    p: usize,
    is_max: bool,
    ho: usize,
    wo: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), c * ho * wo);
    for ci in 0..c {
        for oh in 0..ho {
            for ow in 0..wo {
                let mut acc = if is_max { f32::NEG_INFINITY } else { 0.0 };
                for kh in 0..k {
                    for kw in 0..k {
                        let ih = oh * s + kh;
                        let iw = ow * s + kw;
                        let v = if ih < p || ih >= h + p || iw < p || iw >= w + p {
                            if is_max {
                                f32::NEG_INFINITY
                            } else {
                                0.0
                            }
                        } else {
                            x[(ci * h + ih - p) * w + iw - p]
                        };
                        if is_max {
                            acc = acc.max(v);
                        } else {
                            acc += v;
                        }
                    }
                }
                out[(ci * ho + oh) * wo + ow] =
                    if is_max { acc } else { acc / (k * k) as f32 };
            }
        }
    }
}

/// LRN over `(C, H, W)` row-major.
pub fn lrn_nchw(x: &[f32], c: usize, h: usize, w: usize, size: usize, alpha: f32, beta: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    lrn_mm_into(x, c, h, w, 1, size, alpha, beta, &mut out);
    out
}

/// Global average pool over `(C, H, W)` row-major.
pub fn gap_nchw(x: &[f32], c: usize, h: usize, w: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; c];
    gap_mm_into(x, c, h, w, 1, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    #[test]
    fn maxpool_mm_matches_nchw() {
        let mut rng = Rng::new(1);
        for &(c, h, w, k, s, p) in &[(5, 8, 8, 2, 2, 0), (6, 7, 9, 3, 2, 1), (4, 5, 5, 3, 1, 1)] {
            let x = rng.normal_vec(c * h * w);
            let (want, ho, wo) = pool_nchw(&x, c, h, w, k, s, p, true);
            let got = maxpool_mm(&MapTensor::from_nchw(&x, c, h, w, 4), k, s, p);
            assert_eq!((got.h, got.w), (ho, wo));
            assert_close(&got.to_nchw(), &want, 1e-6, "maxpool");
        }
    }

    #[test]
    fn avgpool_mm_matches_nchw() {
        let mut rng = Rng::new(2);
        let (c, h, w, k, s, p) = (6, 8, 8, 3, 2, 1);
        let x = rng.normal_vec(c * h * w);
        let (want, ..) = pool_nchw(&x, c, h, w, k, s, p, false);
        let got = avgpool_mm(&MapTensor::from_nchw(&x, c, h, w, 4), k, s, p);
        assert_close(&got.to_nchw(), &want, 1e-6, "avgpool");
    }

    #[test]
    fn maxpool_padding_uses_neg_infinity() {
        // All-negative input: zero padding would corrupt the max.
        let x = vec![-5.0f32; 4 * 4 * 4];
        let got = maxpool_mm(&MapTensor::from_nchw(&x, 4, 4, 4, 4), 3, 2, 1);
        assert!(got.to_nchw().iter().all(|&v| v == -5.0));
    }

    #[test]
    fn lrn_mm_matches_nchw() {
        let mut rng = Rng::new(3);
        let (c, h, w) = (10, 4, 4);
        let x = rng.normal_vec(c * h * w);
        let want = lrn_nchw(&x, c, h, w, 5, 1e-4, 0.75);
        let got = lrn_mm(&MapTensor::from_nchw(&x, c, h, w, 4), 5, 1e-4, 0.75);
        assert_close(&got.to_nchw(), &want, 1e-6, "lrn");
    }

    #[test]
    fn gap_matches() {
        let mut rng = Rng::new(4);
        let (c, h, w) = (6, 3, 5);
        let x = rng.normal_vec(c * h * w);
        let want = gap_nchw(&x, c, h, w);
        let got = gap_mm(&MapTensor::from_nchw(&x, c, h, w, 4));
        assert_close(&got, &want, 1e-6, "gap");
    }

    #[test]
    fn dense_modes() {
        use crate::engine::conv::cast_weights;
        let mut rng = Rng::new(5);
        let (i, o) = (32, 8);
        let x = rng.normal_vec(i);
        let w = rng.normal_vec(o * i);
        let b = rng.normal_vec(o);
        let precise = dense(&x, &w, &b, o, false, ArithMode::Precise);
        let w_baked = cast_weights(&w, ArithMode::Imprecise);
        let imprecise = dense(&x, &w_baked, &b, o, false, ArithMode::Imprecise);
        let max_d = precise
            .iter()
            .zip(&imprecise)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d > 0.0 && max_d < 0.2, "max_d={max_d}");
        // ReLU variant clamps.
        let neg_b = vec![-100.0f32; o];
        let clamped = dense(&x, &w, &neg_b, o, true, ArithMode::Precise);
        assert!(clamped.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dense_packed_bitwise_matches_unpacked() {
        let mut rng = Rng::new(6);
        // Output counts straddling DENSE_BLOCK boundaries, incl. o < B.
        // Both the scalar and the register kernel (vec) must be bitwise
        // identical to the unpacked loop.
        for &(i, o) in &[(32usize, 8usize), (17, 5), (9, 1), (4, 3), (5, 4)] {
            let x = rng.normal_vec(i);
            let w = rng.normal_vec(o * i);
            let b = rng.normal_vec(o);
            for relu in [false, true] {
                let mut want = vec![0.0f32; o];
                dense_into(&x, &w, &b, o, relu, &mut want);
                let packed = crate::layout::pack_dense_panels(&w, o, i);
                for vec_k in [false, true] {
                    let mut got = vec![0.0f32; o];
                    dense_packed_into(&x, &packed, &b, o, relu, vec_k, &mut got);
                    assert_eq!(got, want, "i={i} o={o} relu={relu} vec={vec_k}");
                    // Batched packed rows with threads: still bitwise.
                    let rows = 3;
                    let xs: Vec<f32> = (0..rows).flat_map(|_| x.clone()).collect();
                    let mut rows_out = vec![0.0f32; rows * o];
                    dense_rows_packed_into(
                        &xs, i, i, &packed, &b, o, relu, vec_k, &mut rows_out, rows, 2,
                    );
                    for r in 0..rows {
                        assert_eq!(&rows_out[r * o..(r + 1) * o], want.as_slice(), "row {r}");
                    }
                }
            }
        }
    }

    #[test]
    fn dense_i8_backends_agree_and_track_f32() {
        use crate::engine::mode::quantize_symmetric;
        let mut rng = Rng::new(7);
        // Odd i exercises the scalar tail column; o straddles blocks.
        for &(i, o) in &[(32usize, 8usize), (17, 5), (9, 3), (1, 4)] {
            let x = rng.normal_vec(i);
            let w = rng.normal_vec(o * i);
            let b = rng.normal_vec(o);
            let (xq, xs) = quantize_symmetric(&x);
            let (wq, ws) = quantize_symmetric(&w);
            let packed = crate::layout::pack_dense_panels_i8(&wq, o, i);
            let sc = xs * ws;
            let mut got = vec![0.0f32; o];
            dense_i8_packed_into(&xq, &packed, &b, o, false, sc, &mut got);
            // Cross-backend: integer kernels are exact.
            let mut scalar = vec![0.0f32; o];
            dense_i8_packed_impl::<crate::engine::simd::ScalarI16x8>(
                &xq, &packed, &b, o, false, sc, &mut scalar,
            );
            #[cfg(target_arch = "x86_64")]
            {
                let mut sse = vec![0.0f32; o];
                dense_i8_packed_impl::<crate::engine::simd::SseI16x8>(
                    &xq, &packed, &b, o, false, sc, &mut sse,
                );
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&scalar), bits(&sse), "i={i} o={o}");
            }
            // Exactness vs a plain i32 reference dot product.
            for oi in 0..o {
                let mut acc = 0i64;
                for c in 0..i {
                    acc += xq[c] as i64 * wq[oi * i + c] as i64;
                }
                let want = acc as i32 as f32 * sc + b[oi];
                assert_eq!(got[oi].to_bits(), want.to_bits(), "i={i} o={o} oi={oi}");
            }
            // Tracks the f32 dense within quantization error.
            let f32_out = dense(&x, &w, &b, o, false, ArithMode::Precise);
            for (a, bb) in got.iter().zip(&f32_out) {
                assert!((a - bb).abs() < 0.3, "{a} vs {bb}");
            }
            // Batched rows path agrees with single-row calls.
            let rows = 3;
            let xqs: Vec<i8> = (0..rows).flat_map(|_| xq.clone()).collect();
            let scales = vec![xs; rows];
            let mut rows_out = vec![0.0f32; rows * o];
            dense_i8_rows_packed_into(
                &xqs, &scales, i, i, &packed, ws, &b, o, false, &mut rows_out, rows, 2,
            );
            for r in 0..rows {
                assert_eq!(&rows_out[r * o..(r + 1) * o], got.as_slice(), "row {r}");
            }
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0, 4.0]);
        let sum: f32 = p.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(p[3] > p[2] && p[2] > p[1]);
        // Stability: huge logits must not produce NaN.
        let p = softmax(&[1000.0, 999.0]);
        assert!(p.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn relu_inplace_works() {
        let mut v = vec![-1.0, 0.0, 2.0];
        relu_inplace(&mut v);
        assert_eq!(v, vec![0.0, 0.0, 2.0]);
    }
}
