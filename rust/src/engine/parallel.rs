//! Thread workload allocation (paper section IV.A) and the persistent
//! worker pool the compiled execution plans run on.
//!
//! The three sources of parallelism in a convolutional layer:
//!
//! * **OLP** (output-level) — each thread computes whole output pixels
//!   (the full 3-D convolution for its pixels). No reduction, maximal
//!   kernel reuse. Cappuccino's primary policy.
//! * **FLP** (filter-bank-level) — each thread convolves *one entire
//!   kernel* (one input plane against one 2-D kernel); a reduction sums
//!   partial planes over input channels.
//! * **KLP** (kernel-level) — threads split the multiplications *within*
//!   a kernel window (here: by input-channel slices); a reduction
//!   accumulates partial products.
//!
//! KLP/FLP exist to measure exactly what the paper argues against:
//! reduction/synchronisation overhead and poor data reuse. The ablation
//! bench regenerates that comparison.
//!
//! ## Execution substrate
//!
//! [`parallel_for`] / [`parallel_reduce`] run on a process-wide
//! [`ThreadPool`]: long-lived workers blocked on a work channel, so the
//! per-layer cost of going parallel is one enqueue + one wakeup instead
//! of an OS thread spawn. The original scoped-spawn implementations are
//! kept as [`parallel_for_spawn`] / [`parallel_reduce_spawn`] purely as
//! the ablation reference (what every conv layer used to pay).
//!
//! Batch-first plans stretch each region instead of adding regions: a
//! `run_batch` of `B` images submits **one** task batch per conv layer
//! spanning the whole `B x alpha` item space, so the enqueue + wakeup
//! cost above is paid once per layer per *batch*, not per image.

use std::collections::VecDeque;
use std::ops::Range;
use std::str::FromStr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Thread workload allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Parallelism {
    Olp,
    Flp,
    Klp,
}

impl Parallelism {
    pub const ALL: [Parallelism; 3] = [Parallelism::Olp, Parallelism::Flp, Parallelism::Klp];

    pub fn as_str(&self) -> &'static str {
        match self {
            Parallelism::Olp => "olp",
            Parallelism::Flp => "flp",
            Parallelism::Klp => "klp",
        }
    }
}

impl FromStr for Parallelism {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "olp" => Ok(Parallelism::Olp),
            "flp" => Ok(Parallelism::Flp),
            "klp" => Ok(Parallelism::Klp),
            other => Err(crate::Error::Invalid(format!("unknown parallelism {other:?}"))),
        }
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Split `n_items` into at most `n_chunks` contiguous ranges.
pub fn chunk_ranges(n_items: usize, n_chunks: usize) -> Vec<Range<usize>> {
    if n_items == 0 || n_chunks == 0 {
        return Vec::new();
    }
    let n_chunks = n_chunks.min(n_items);
    let base = n_items / n_chunks;
    let extra = n_items % n_chunks;
    let mut out = Vec::with_capacity(n_chunks);
    let mut start = 0;
    for i in 0..n_chunks {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

// ---------------------------------------------------------------------------
// Persistent thread pool
// ---------------------------------------------------------------------------

/// Total OS threads ever spawned by pools in this process — the plan
/// parity tests assert this stays flat across inferences (zero per-layer
/// spawns once the pool is warm).
static THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// OS threads spawned by [`ThreadPool`]s since process start.
pub fn pool_threads_spawned() -> usize {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// Completion latch for one [`ThreadPool::scope`] call.
struct Latch {
    state: Mutex<(usize, bool)>, // (tasks remaining, any panicked)
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, false)), cv: Condvar::new() }
    }

    fn done(&self, ok: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if !ok {
            st.1 = true;
        }
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        if st.1 {
            panic!("thread-pool task panicked");
        }
    }
}

/// Long-lived worker pool: workers block on a shared work queue; scoped
/// task batches borrow caller data (the submitting call blocks until
/// every task in the batch has completed, so the borrow is sound).
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            work_cv: Condvar::new(),
        });
        let workers = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("capp-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Run a batch of borrowed tasks to completion.
    ///
    /// Tasks may borrow caller data (`'a`): the call blocks until every
    /// task has finished, and the caller *helps* by draining the queue
    /// while it waits, so the batch makes progress even when all workers
    /// are busy (and nested `scope` calls cannot deadlock).
    pub fn scope<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if tasks.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(tasks.len()));
        {
            let mut st = self.shared.state.lock().unwrap();
            for task in tasks {
                // SAFETY: `latch.wait()` below blocks this call until
                // every task in the batch has run to completion, so the
                // `'a` borrows each task captures strictly outlive its
                // execution. The wrapper job cannot panic (the user task
                // runs under `catch_unwind`), so an unwinding worker or
                // helper never abandons a queued sibling mid-borrow.
                let task: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(task)
                };
                let latch = Arc::clone(&latch);
                st.queue.push_back(Box::new(move || {
                    let ok =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(task)).is_ok();
                    latch.done(ok);
                }));
            }
            self.shared.work_cv.notify_all();
        }
        // Help while waiting.
        loop {
            let job = self.shared.state.lock().unwrap().queue.pop_front();
            match job {
                Some(job) => job(),
                None => break,
            }
        }
        latch.wait();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: Arc<PoolShared>) {
    loop {
        let job = {
            let mut st = sh.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = sh.work_cv.wait(st).unwrap();
            }
        };
        match job {
            Some(j) => j(),
            None => return,
        }
    }
}

/// The process-wide pool every executor shares. Sized to the machine
/// once, on first use; callers limit their own parallelism via the
/// chunk count they submit, not by resizing the pool.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        ThreadPool::new(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    })
}

// ---------------------------------------------------------------------------
// Data-parallel helpers (pool-backed)
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, range)` over `n_items` split into at most
/// `n_threads` chunks on the persistent [`global_pool`]. With
/// `n_threads <= 1` (or a single chunk) runs inline with zero overhead.
pub fn parallel_for<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r);
        }
        return;
    }
    let f = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .enumerate()
        .map(|(i, r)| Box::new(move || f(i, r)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    global_pool().scope(tasks);
}

/// Split `items` into at most `n_threads` contiguous ranges, hand each
/// range its disjoint `range.len() * row_len` slice of `out`, and run
/// `f(range, slice)` on the persistent [`global_pool`] in **one**
/// parallel region (inline when a single chunk results). This is the
/// writer side of the batched conv/dense kernels: every work item owns
/// one contiguous `row_len` output row, so disjoint chunk slices need
/// zero synchronisation.
pub(crate) fn parallel_for_slices<F>(
    items: usize,
    n_threads: usize,
    row_len: usize,
    out: &mut [f32],
    f: &F,
) where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            let len = r.len() * row_len;
            f(r, &mut out[..len]);
        }
        return;
    }
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.len() * row_len);
        slices.push(head);
        rest = tail;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || f(range, slice)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    global_pool().scope(tasks);
}

/// Macro-item variant of [`parallel_for_slices`] for the tiled conv
/// core: items may own output slices of *varying* length, and every
/// chunk is paired with its own per-thread scratch row.
///
/// `offset_of(i)` maps item `i` to the element offset where its output
/// region starts (monotone non-decreasing, `offset_of(0) == 0`,
/// `offset_of(items)` = total region length). Chunks are contiguous
/// item ranges, so **chunk boundaries always fall on macro-item
/// boundaries** — a tile is never split across threads, and each chunk's
/// output slice is disjoint (zero write synchronisation, as in the
/// uniform-row case). `scratch` must hold at least one row per chunk
/// (chunk count <= `n_threads`); rows may be empty when the kernel
/// needs none (the `u = 4` register path).
pub(crate) fn parallel_for_macro_slices<O, F>(
    items: usize,
    n_threads: usize,
    out: &mut [f32],
    offset_of: &O,
    scratch: &mut [Vec<f32>],
    f: &F,
) where
    O: Fn(usize) -> usize,
    F: Fn(Range<usize>, &mut [f32], &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(items, n_threads.max(1));
    if ranges.is_empty() {
        return;
    }
    assert!(
        scratch.len() >= ranges.len(),
        "parallel_for_macro_slices: {} scratch rows for {} chunks",
        scratch.len(),
        ranges.len()
    );
    if ranges.len() == 1 {
        let r = ranges.into_iter().next().unwrap();
        let (lo, hi) = (offset_of(r.start), offset_of(r.end));
        f(r, &mut out[lo..hi], scratch[0].as_mut_slice());
        return;
    }
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut consumed = 0usize;
    for r in &ranges {
        let end = offset_of(r.end);
        let (head, tail) = rest.split_at_mut(end - consumed);
        slices.push(head);
        rest = tail;
        consumed = end;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .zip(slices)
        .zip(scratch.iter_mut())
        .map(|((range, slice), sc)| {
            let sc: &mut [f32] = sc.as_mut_slice();
            Box::new(move || f(range, slice, sc)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    global_pool().scope(tasks);
}

/// Like [`parallel_for`] but each chunk owns a scratch accumulation
/// buffer of `buf_len` zeros; after the parallel phase the buffers are
/// reduced (element-wise sum) into a single vector. This is the
/// reduction + inter-thread data-transfer overhead KLP/FLP pay.
pub fn parallel_reduce<F>(n_items: usize, n_threads: usize, buf_len: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let n_chunks = chunk_ranges(n_items, n_threads.max(1)).len().max(1);
    let mut bufs: Vec<Vec<f32>> = (0..n_chunks).map(|_| vec![0.0f32; buf_len]).collect();
    parallel_reduce_with(n_items, n_threads, buf_len, &mut bufs, &f);
    bufs.swap_remove(0)
}

/// Arena-friendly reduction: run the KLP/FLP accumulation over
/// preallocated per-thread buffers (each at least `buf_len` long) and
/// leave the reduced result in `bufs[0][..buf_len]`. The compiled plan
/// executor reuses one set of buffers across every layer and inference.
pub fn parallel_reduce_with<F>(
    n_items: usize,
    n_threads: usize,
    buf_len: usize,
    bufs: &mut [Vec<f32>],
    f: &F,
) where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    let n = ranges.len();
    assert!(
        bufs.len() >= n.max(1),
        "parallel_reduce_with: {} buffers for {} chunks",
        bufs.len(),
        n
    );
    for buf in bufs.iter_mut().take(n.max(1)) {
        assert!(buf.len() >= buf_len, "parallel_reduce_with: buffer too small");
        buf[..buf_len].fill(0.0);
    }
    if n <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r, &mut bufs[0][..buf_len]);
        }
        return;
    }
    {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
            .into_iter()
            .enumerate()
            .zip(bufs.iter_mut())
            .map(|((i, r), buf)| {
                let buf = &mut buf[..buf_len];
                Box::new(move || f(i, r, buf)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        global_pool().scope(tasks);
    }
    // Sequential reduction — deliberately the simple strategy a
    // RenderScript reduction kernel would lower to.
    let (first, rest) = bufs.split_at_mut(1);
    let out = &mut first[0][..buf_len];
    for buf in rest.iter().take(n - 1) {
        for (o, v) in out.iter_mut().zip(&buf[..buf_len]) {
            *o += *v;
        }
    }
}

// ---------------------------------------------------------------------------
// Scoped-spawn ablation reference (the pre-pool execution substrate)
// ---------------------------------------------------------------------------

/// Ablation reference: the original scoped-spawn `parallel_for` — one
/// fresh OS thread per chunk per call, exactly what every conv layer
/// paid before the persistent pool.
pub fn parallel_for_spawn<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r);
        }
        return;
    }
    std::thread::scope(|scope| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(i, r));
        }
    });
}

/// Ablation reference: the original scoped-spawn `parallel_reduce`.
pub fn parallel_reduce_spawn<F>(n_items: usize, n_threads: usize, buf_len: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        let mut buf = vec![0.0f32; buf_len];
        if let Some(r) = ranges.into_iter().next() {
            f(0, r, &mut buf);
        }
        return buf;
    }
    let n = ranges.len();
    let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; buf_len]).collect();
    std::thread::scope(|scope| {
        for ((i, r), buf) in ranges.into_iter().enumerate().zip(bufs.iter_mut()) {
            let f = &f;
            scope.spawn(move || f(i, r, buf));
        }
    });
    let mut out = bufs.swap_remove(0);
    for buf in &bufs {
        for (o, v) in out.iter_mut().zip(buf) {
            *o += *v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for &(n, c) in &[(10, 3), (3, 10), (0, 4), (7, 7), (100, 1)] {
            let ranges = chunk_ranges(n, c);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            let mut expect = 0;
            for r in &ranges {
                assert_eq!(r.start, expect);
                assert!(!r.is_empty());
                expect = r.end;
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        let ranges = chunk_ranges(10, 3);
        let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn parallel_for_visits_every_item() {
        let visited = AtomicUsize::new(0);
        parallel_for(1000, 4, |_, r| {
            visited.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(visited.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_for_single_thread_inline() {
        let visited = AtomicUsize::new(0);
        parallel_for(10, 1, |i, r| {
            assert_eq!(i, 0);
            visited.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(visited.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_reduce_sums_buffers() {
        // Each of 8 items adds 1.0 at its index; reduction must total 1
        // per slot regardless of thread count.
        for threads in [1, 2, 4, 8] {
            let out = parallel_reduce(8, threads, 8, |_, range, buf| {
                for i in range {
                    buf[i] += 1.0;
                }
            });
            assert_eq!(out, vec![1.0; 8], "threads={threads}");
        }
    }

    #[test]
    fn spawn_reference_matches_pool() {
        let pool_sum = AtomicUsize::new(0);
        let spawn_sum = AtomicUsize::new(0);
        parallel_for(100, 4, |_, r| {
            pool_sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
        });
        parallel_for_spawn(100, 4, |_, r| {
            spawn_sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
        });
        assert_eq!(pool_sum.load(Ordering::Relaxed), spawn_sum.load(Ordering::Relaxed));
        let a = parallel_reduce(16, 4, 16, |_, range, buf| {
            for i in range {
                buf[i] += i as f32;
            }
        });
        let b = parallel_reduce_spawn(16, 4, 16, |_, range, buf| {
            for i in range {
                buf[i] += i as f32;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn pool_reused_across_calls_and_private_scope() {
        // One test on purpose: THREADS_SPAWNED is process-global and
        // libtest runs tests concurrently, so the private-pool check
        // must not race the flat-counter assertion below.
        let pool = ThreadPool::new(2);
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(hits.load(Ordering::Relaxed), 16);
        drop(pool);

        // Warm the global pool, then check no further threads are
        // spawned no matter how many parallel sections run.
        parallel_for(64, 8, |_, _| {});
        let warm = pool_threads_spawned();
        for _ in 0..32 {
            parallel_for(64, 8, |_, _| {});
        }
        assert_eq!(pool_threads_spawned(), warm, "pool spawned threads per call");
    }

    #[test]
    fn macro_slices_cover_varying_items_on_boundaries() {
        // Five macro items with different output lengths; every thread
        // count must cover each item exactly once, never splitting one.
        let lens = [3usize, 1, 4, 2, 5];
        let mut offsets = vec![0usize];
        for &l in &lens {
            offsets.push(offsets.last().unwrap() + l);
        }
        let total = *offsets.last().unwrap();
        let mut want = Vec::new();
        for (i, &l) in lens.iter().enumerate() {
            for _ in 0..l {
                want.push(i as f32 + 1.0);
            }
        }
        for threads in [1usize, 2, 4, 8] {
            let mut out = vec![0.0f32; total];
            let mut scratch: Vec<Vec<f32>> = (0..threads).map(|_| vec![0.0f32; 1]).collect();
            parallel_for_macro_slices(
                lens.len(),
                threads,
                &mut out,
                &|i| offsets[i],
                &mut scratch,
                &|range: Range<usize>, slice: &mut [f32], sc: &mut [f32]| {
                    sc[0] += 1.0;
                    let mut off = 0;
                    for item in range {
                        for v in &mut slice[off..off + lens[item]] {
                            *v = item as f32 + 1.0;
                        }
                        off += lens[item];
                    }
                },
            );
            assert_eq!(out, want, "threads={threads}");
            let used: f32 = scratch.iter().map(|s| s[0]).sum();
            assert!(used >= 1.0, "threads={threads}: no chunk ran");
        }
    }

    #[test]
    fn reduce_with_reuses_buffers() {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![7.0f32; 8]).collect();
        for _ in 0..3 {
            parallel_reduce_with(8, 4, 8, &mut bufs, &|_, range, buf: &mut [f32]| {
                for i in range {
                    buf[i] += 1.0;
                }
            });
            assert_eq!(&bufs[0][..8], &[1.0f32; 8][..], "stale partials leaked");
        }
    }

    #[test]
    fn parallelism_parse() {
        assert_eq!("olp".parse::<Parallelism>().unwrap(), Parallelism::Olp);
        assert!("slp".parse::<Parallelism>().is_err());
    }
}
