//! Inexact-computing analysis (paper section IV.C).
//!
//! Given the primary parallel program, a trained model and the
//! validation dataset, decide *per layer* which arithmetic mode to use:
//! "the goal is to execute as many CNN layers as possible in inexact
//! modes, under user specified constraints in terms of acceptable
//! degradation in classification accuracy."
//!
//! The analyzer measures top-1 classification accuracy (not arithmetic
//! accuracy — the paper's distinction) on the validation split, then
//! greedily walks the layers in order, trying the cheapest acceptable
//! mode for each (quantized int8 first, then imprecise, then relaxed)
//! while keeping all previously accepted assignments in place. A layer
//! whose inexact modes breach the accuracy budget stays precise. A mode
//! the plan compiler rejects for a layer outright —
//! [`ArithMode::QuantI8`] on a width that cannot be lane-padded — is
//! skipped (it costs no evaluation), not fatal: this accuracy gate is
//! exactly the tolerance-based check the quantized path is gated by,
//! since int8 has no bitwise f32 oracle.

use crate::data::Dataset;
use crate::engine::{self, ArithMode, EngineParams, ExecConfig, ModeAssignment};
use crate::model::Network;
use crate::util::error::{Error, Result};

/// Analysis configuration.
#[derive(Debug, Clone)]
pub struct AnalysisConfig {
    /// Acceptable top-1 accuracy drop (absolute, e.g. 0.01 = 1 point).
    pub max_accuracy_drop: f64,
    /// Validation images to evaluate (taken from the dataset's
    /// validation split).
    pub max_images: usize,
    /// Engine threads per evaluation.
    pub threads: usize,
}

impl Default for AnalysisConfig {
    fn default() -> Self {
        AnalysisConfig { max_accuracy_drop: 0.01, max_images: 256, threads: 1 }
    }
}

/// Per-layer decision record.
#[derive(Debug, Clone)]
pub struct LayerDecision {
    pub layer: String,
    pub chosen: ArithMode,
    /// Accuracy with the cumulative assignment including this decision.
    pub accuracy: f64,
    /// Modes that were tried and rejected (mode, accuracy).
    pub rejected: Vec<(ArithMode, f64)>,
}

/// Full analysis result.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    pub baseline_accuracy: f64,
    pub final_accuracy: f64,
    pub decisions: Vec<LayerDecision>,
    pub assignment: ModeAssignment,
    /// Evaluations performed (engine runs over the val set).
    pub evaluations: usize,
}

impl AnalysisReport {
    pub fn inexact_layers(&self) -> usize {
        self.decisions
            .iter()
            .filter(|d| d.chosen != ArithMode::Precise)
            .count()
    }
}

/// Images per plan walk while streaming the validation split.
const EVAL_BATCH: usize = 8;

/// Top-1 accuracy of `net` under `modes` on (a prefix of) the
/// validation split.
pub fn evaluate_accuracy(
    net: &Network,
    params: &EngineParams,
    dataset: &Dataset,
    modes: &ModeAssignment,
    cfg: &AnalysisConfig,
) -> Result<f64> {
    let (images, labels) = dataset.validation();
    if images.is_empty() {
        return Ok(0.0);
    }
    let n = images.len().min(cfg.max_images).max(1);
    // Build one execution plan per candidate assignment and stream the
    // whole validation prefix through it in `EVAL_BATCH`-image walks:
    // weights are baked and buffers preallocated once per evaluation,
    // and per-invocation walk overhead is amortised across each batch
    // (per-row numerics are batch-size independent, so accuracy is
    // identical to the per-image flow).
    let mut plan = engine::PlanBuilder::new(net, params)
        .modes(modes)
        .config(ExecConfig { threads: cfg.threads, ..Default::default() })
        .batch(EVAL_BATCH.min(n))
        .build()?;
    let mut correct = 0usize;
    for (imgs, labs) in images[..n].chunks(EVAL_BATCH).zip(labels[..n].chunks(EVAL_BATCH)) {
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        for (logits, &label) in plan.run_batch(&refs)?.iter().zip(labs) {
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            if pred == label as usize {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / n as f64)
}

/// Run the layer-by-layer mode analysis.
pub fn analyze(
    net: &Network,
    params: &EngineParams,
    dataset: &Dataset,
    cfg: &AnalysisConfig,
) -> Result<AnalysisReport> {
    let mut evaluations = 0usize;
    let mut eval = |modes: &ModeAssignment| -> Result<f64> {
        let acc = evaluate_accuracy(net, params, dataset, modes, cfg)?;
        evaluations += 1;
        Ok(acc)
    };

    let mut assignment = ModeAssignment::uniform(ArithMode::Precise);
    let baseline = eval(&assignment)?;
    let budget = baseline - cfg.max_accuracy_drop;

    let mut decisions = Vec::new();
    let mut last_accuracy = baseline;
    for layer in net.param_layer_names() {
        let mut rejected = Vec::new();
        let mut chosen = ArithMode::Precise;
        // Cheapest (fastest) mode first: quantized int8, then
        // imprecise, then relaxed. A candidate the plan compiler
        // rejects (quant_i8 on a non-lane-paddable width) is skipped.
        for mode in [ArithMode::QuantI8, ArithMode::Imprecise, ArithMode::Relaxed] {
            let mut candidate = assignment.clone();
            candidate.per_layer.insert(layer.clone(), mode);
            let acc = match eval(&candidate) {
                Ok(acc) => acc,
                Err(Error::Config(_)) => continue,
                Err(e) => return Err(e),
            };
            if acc >= budget {
                assignment = candidate;
                chosen = mode;
                last_accuracy = acc;
                break;
            }
            rejected.push((mode, acc));
        }
        decisions.push(LayerDecision {
            layer,
            chosen,
            accuracy: last_accuracy,
            rejected,
        });
    }

    let final_accuracy = last_accuracy;
    Ok(AnalysisReport {
        baseline_accuracy: baseline,
        final_accuracy,
        decisions,
        assignment,
        evaluations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::modelfile::ModelFile;
    use crate::model::zoo;

    fn trained_setup() -> Option<(Network, EngineParams, Dataset)> {
        let dir = crate::artifacts_dir();
        if !dir.join("tinynet.capp").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let net = zoo::tinynet();
        let mf = ModelFile::read_from(dir.join("tinynet.capp")).unwrap();
        let params = EngineParams::compile(&net, &mf, 4).unwrap();
        let dataset = Dataset::read_from(dir.join("dataset.bin")).unwrap();
        Some((net, params, dataset))
    }

    #[test]
    fn trained_tinynet_accuracy_high() {
        let Some((net, params, dataset)) = trained_setup() else { return };
        let cfg = AnalysisConfig { max_images: 128, ..Default::default() };
        let acc = evaluate_accuracy(
            &net,
            &params,
            &dataset,
            &ModeAssignment::uniform(ArithMode::Precise),
            &cfg,
        )
        .unwrap();
        assert!(acc > 0.9, "precise accuracy {acc}");
    }

    #[test]
    fn analysis_accepts_all_layers_imprecise() {
        // The paper's headline result: "classification accuracy in
        // imprecise mode turns out to be identical to the exact mode.
        // Hence, Cappuccino recommends utilization of imprecise
        // computing in all layers."
        let Some((net, params, dataset)) = trained_setup() else { return };
        let cfg = AnalysisConfig {
            max_accuracy_drop: 0.02,
            max_images: 96,
            threads: 1,
        };
        let report = analyze(&net, &params, &dataset, &cfg).unwrap();
        assert_eq!(report.inexact_layers(), 5, "{:#?}", report.decisions);
        assert!(report.final_accuracy >= report.baseline_accuracy - 0.02);
        // Greedy tries quant_i8 -> imprecise -> relaxed per layer: one
        // baseline evaluation plus 1..=3 per layer, and on a trained
        // net the first or second rung is accepted.
        assert!(
            (6..=16).contains(&report.evaluations),
            "evaluations {}",
            report.evaluations
        );
    }

    #[test]
    fn quant_i8_clears_the_tolerance_gate_on_trained_tinynet() {
        // The quantized path has no bitwise f32 oracle; its gate is
        // top-1 agreement within tolerance on the validation split.
        let Some((net, params, dataset)) = trained_setup() else { return };
        let cfg = AnalysisConfig { max_images: 96, ..Default::default() };
        let precise = evaluate_accuracy(
            &net,
            &params,
            &dataset,
            &ModeAssignment::uniform(ArithMode::Precise),
            &cfg,
        )
        .unwrap();
        let quant = evaluate_accuracy(
            &net,
            &params,
            &dataset,
            &ModeAssignment::uniform(ArithMode::QuantI8),
            &cfg,
        )
        .unwrap();
        assert!(quant >= precise - 0.05, "quant_i8 {quant} vs precise {precise}");
    }

    #[test]
    fn zero_budget_keeps_layers_precise_for_random_net() {
        // An untrained net near the decision boundary everywhere: with a
        // strict budget, some layers can be rejected. We only assert the
        // analysis respects the budget (final >= baseline - drop).
        let Some((_, _, dataset)) = trained_setup() else { return };
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 123, 4).unwrap();
        let cfg = AnalysisConfig {
            max_accuracy_drop: 0.0,
            max_images: 48,
            threads: 1,
        };
        let report = analyze(&net, &params, &dataset, &cfg).unwrap();
        assert!(report.final_accuracy >= report.baseline_accuracy - 1e-9);
    }
}
