//! `.cappnet` — the network description file format (paper Fig. 3,
//! input #1).
//!
//! A line-oriented text format, one layer per line, `#` comments. The
//! composites `fire` and `inception` expand exactly as in the Python
//! spec, so a `.cappnet` file round-trips through the same IR the AOT
//! manifest describes.
//!
//! ```text
//! net tinynet
//! input 3 16 16
//! classes 8
//!
//! conv conv1 m=16 k=3 s=1 p=1
//! maxpool k=2 s=2
//! conv conv2 m=32 k=3 s=1 p=1
//! maxpool k=2 s=2
//! conv conv3 m=32 k=3 s=1 p=1
//! flatten
//! dense fc4 o=64 relu=1
//! dense fc5 o=8 relu=0
//! ```
//!
//! Composites:
//!
//! ```text
//! fire fire2 s1=16 e1=64 e3=64
//! inception inc3a b1=64 b3r=96 b3=128 b5r=16 b5=32 pp=32
//! lrn size=5 alpha=0.0001 beta=0.75
//! ```

use std::collections::HashMap;

use crate::model::{Layer, LayerOp, Network, TensorShape};
use crate::util::error::{Error, Result};

/// Parse a `.cappnet` document into a [`Network`].
pub fn parse_cappnet(text: &str) -> Result<Network> {
    let mut name = None;
    let mut input = None;
    let mut classes = None;
    let mut layers: Vec<Layer> = Vec::new();
    let mut auto_idx = 0usize;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let head = toks.next().unwrap();
        let err = |msg: String| Error::parse("cappnet", format!("line {}: {msg}", lineno + 1));

        match head {
            "net" => {
                name = Some(
                    toks.next()
                        .ok_or_else(|| err("net needs a name".into()))?
                        .to_string(),
                );
            }
            "input" => {
                let dims: Vec<usize> = toks
                    .map(|t| t.parse().map_err(|_| err(format!("bad input dim {t:?}"))))
                    .collect::<Result<_>>()?;
                if dims.len() != 3 {
                    return Err(err(format!("input needs 3 dims, got {}", dims.len())));
                }
                input = Some(TensorShape::maps(dims[0], dims[1], dims[2]));
            }
            "classes" => {
                let c = toks
                    .next()
                    .ok_or_else(|| err("classes needs a count".into()))?;
                classes = Some(c.parse().map_err(|_| err(format!("bad classes {c:?}")))?);
            }
            _ => {
                let parsed = parse_layer_line(head, toks, lineno + 1, &mut auto_idx)?;
                layers.extend(parsed);
            }
        }
    }

    let net = Network {
        name: name.ok_or_else(|| Error::parse("cappnet", "missing `net` line"))?,
        input: input.ok_or_else(|| Error::parse("cappnet", "missing `input` line"))?,
        classes: classes.ok_or_else(|| Error::parse("cappnet", "missing `classes` line"))?,
        layers,
    };
    // Validate by running shape inference once.
    let info = crate::model::shapes::infer(&net)?;
    if info.output != (TensorShape::Flat { len: net.classes }) {
        return Err(Error::parse(
            "cappnet",
            format!(
                "network output {:?} does not match classes {}",
                info.output, net.classes
            ),
        ));
    }
    Ok(net)
}

fn parse_layer_line<'a>(
    head: &str,
    toks: impl Iterator<Item = &'a str>,
    lineno: usize,
    auto_idx: &mut usize,
) -> Result<Vec<Layer>> {
    let err = |msg: String| Error::parse("cappnet", format!("line {lineno}: {msg}"));
    let mut name: Option<String> = None;
    let mut kv: HashMap<&str, &str> = HashMap::new();
    for tok in toks {
        match tok.split_once('=') {
            Some((k, v)) => {
                kv.insert(k, v);
            }
            None if name.is_none() => name = Some(tok.to_string()),
            None => return Err(err(format!("unexpected token {tok:?}"))),
        }
    }
    let get_usize = |kv: &HashMap<&str, &str>, k: &str, default: Option<usize>| -> Result<usize> {
        match kv.get(k) {
            Some(v) => v.parse().map_err(|_| err(format!("bad {k}={v}"))),
            None => default.ok_or_else(|| err(format!("missing {k}="))),
        }
    };
    let get_f32 = |kv: &HashMap<&str, &str>, k: &str, default: f32| -> Result<f32> {
        match kv.get(k) {
            Some(v) => v.parse().map_err(|_| err(format!("bad {k}={v}"))),
            None => Ok(default),
        }
    };
    *auto_idx += 1;
    let auto = |prefix: &str, idx: usize| format!("{prefix}{idx}");

    let layers = match head {
        "conv" => {
            let n = name.ok_or_else(|| err("conv needs a name".into()))?;
            vec![Layer::new(
                n,
                LayerOp::Conv {
                    m: get_usize(&kv, "m", None)?,
                    k: get_usize(&kv, "k", None)?,
                    s: get_usize(&kv, "s", Some(1))?,
                    p: get_usize(&kv, "p", Some(0))?,
                    relu: get_usize(&kv, "relu", Some(1))? != 0,
                },
            )]
        }
        "maxpool" | "avgpool" => {
            let k = get_usize(&kv, "k", None)?;
            let s = get_usize(&kv, "s", Some(1))?;
            let p = get_usize(&kv, "p", Some(0))?;
            let n = name.unwrap_or_else(|| auto(head, *auto_idx));
            let op = if head == "maxpool" {
                LayerOp::MaxPool { k, s, p }
            } else {
                LayerOp::AvgPool { k, s, p }
            };
            vec![Layer::new(n, op)]
        }
        "lrn" => vec![Layer::new(
            name.unwrap_or_else(|| auto("lrn", *auto_idx)),
            LayerOp::Lrn {
                size: get_usize(&kv, "size", Some(5))?,
                alpha: get_f32(&kv, "alpha", 1e-4)?,
                beta: get_f32(&kv, "beta", 0.75)?,
            },
        )],
        "fire" => {
            let n = name.ok_or_else(|| err("fire needs a name".into()))?;
            let s1 = get_usize(&kv, "s1", None)?;
            let e1 = get_usize(&kv, "e1", None)?;
            let e3 = get_usize(&kv, "e3", None)?;
            vec![
                Layer::new(
                    format!("{n}/s1"),
                    LayerOp::Conv { m: s1, k: 1, s: 1, p: 0, relu: true },
                ),
                Layer::new(
                    n.clone(),
                    LayerOp::Fork {
                        branches: vec![
                            vec![Layer::new(
                                format!("{n}/e1"),
                                LayerOp::Conv { m: e1, k: 1, s: 1, p: 0, relu: true },
                            )],
                            vec![Layer::new(
                                format!("{n}/e3"),
                                LayerOp::Conv { m: e3, k: 3, s: 1, p: 1, relu: true },
                            )],
                        ],
                    },
                ),
            ]
        }
        "inception" => {
            let n = name.ok_or_else(|| err("inception needs a name".into()))?;
            let g = |k: &str| get_usize(&kv, k, None);
            let (b1, b3r, b3, b5r, b5, pp) =
                (g("b1")?, g("b3r")?, g("b3")?, g("b5r")?, g("b5")?, g("pp")?);
            let c = |nm: String, m: usize, k: usize, p: usize| {
                Layer::new(nm, LayerOp::Conv { m, k, s: 1, p, relu: true })
            };
            vec![Layer::new(
                n.clone(),
                LayerOp::Fork {
                    branches: vec![
                        vec![c(format!("{n}/b1"), b1, 1, 0)],
                        vec![c(format!("{n}/b3r"), b3r, 1, 0), c(format!("{n}/b3"), b3, 3, 1)],
                        vec![c(format!("{n}/b5r"), b5r, 1, 0), c(format!("{n}/b5"), b5, 5, 2)],
                        vec![
                            Layer::new(format!("{n}/pool"), LayerOp::MaxPool { k: 3, s: 1, p: 1 }),
                            c(format!("{n}/pp"), pp, 1, 0),
                        ],
                    ],
                },
            )]
        }
        "flatten" => vec![Layer::new(
            name.unwrap_or_else(|| auto("flatten", *auto_idx)),
            LayerOp::Flatten,
        )],
        "gap" => vec![Layer::new(
            name.unwrap_or_else(|| auto("gap", *auto_idx)),
            LayerOp::Gap,
        )],
        "dense" => {
            let n = name.ok_or_else(|| err("dense needs a name".into()))?;
            vec![Layer::new(
                n,
                LayerOp::Dense {
                    o: get_usize(&kv, "o", None)?,
                    relu: get_usize(&kv, "relu", Some(0))? != 0,
                },
            )]
        }
        "softmax" => vec![Layer::new(
            name.unwrap_or_else(|| auto("softmax", *auto_idx)),
            LayerOp::Softmax,
        )],
        other => return Err(err(format!("unknown layer kind {other:?}"))),
    };
    Ok(layers)
}

/// Serialise a network back to `.cappnet` text (fire/inception stay
/// expanded as fork blocks are not representable — networks built from
/// the zoo re-serialise composites naturally since expansion is 1:1;
/// this writer emits primitive lines plus explicit fork syntax is not
/// needed because all supported forks match the fire/inception shapes).
pub fn write_cappnet(net: &Network) -> String {
    let mut out = String::new();
    out.push_str(&format!("net {}\n", net.name));
    if let TensorShape::Maps { c, h, w } = net.input {
        out.push_str(&format!("input {c} {h} {w}\n"));
    }
    out.push_str(&format!("classes {}\n\n", net.classes));
    write_layers(&net.layers, &mut out);
    out
}

fn write_layers(layers: &[Layer], out: &mut String) {
    let conv_m = |l: &Layer| match l.op {
        LayerOp::Conv { m, .. } => Some(m),
        _ => None,
    };
    let mut i = 0;
    while i < layers.len() {
        let layer = &layers[i];
        // fire: `conv X/s1` immediately followed by a 2-branch fork `X`.
        if let (LayerOp::Conv { m: s1, .. }, Some(next)) = (&layer.op, layers.get(i + 1)) {
            if let LayerOp::Fork { branches } = &next.op {
                if branches.len() == 2 && layer.name == format!("{}/s1", next.name) {
                    if let (Some(e1), Some(e3)) = (
                        branches[0].first().and_then(conv_m),
                        branches[1].first().and_then(conv_m),
                    ) {
                        out.push_str(&format!(
                            "fire {} s1={s1} e1={e1} e3={e3}\n",
                            next.name
                        ));
                        i += 2;
                        continue;
                    }
                }
            }
        }
        match &layer.op {
            LayerOp::Conv { m, k, s, p, relu } => {
                out.push_str(&format!(
                    "conv {} m={m} k={k} s={s} p={p} relu={}\n",
                    layer.name, *relu as u8
                ));
            }
            LayerOp::MaxPool { k, s, p } => {
                out.push_str(&format!("maxpool k={k} s={s} p={p}\n"));
            }
            LayerOp::AvgPool { k, s, p } => {
                out.push_str(&format!("avgpool k={k} s={s} p={p}\n"));
            }
            LayerOp::Lrn { size, alpha, beta } => {
                out.push_str(&format!("lrn size={size} alpha={alpha} beta={beta}\n"));
            }
            LayerOp::Fork { branches } if branches.len() == 4 => {
                let vals = (
                    branches[0].first().and_then(conv_m),
                    branches[1].first().and_then(conv_m),
                    branches[1].get(1).and_then(conv_m),
                    branches[2].first().and_then(conv_m),
                    branches[2].get(1).and_then(conv_m),
                    branches[3].get(1).and_then(conv_m),
                );
                if let (Some(b1), Some(b3r), Some(b3), Some(b5r), Some(b5), Some(pp)) = vals {
                    out.push_str(&format!(
                        "inception {} b1={b1} b3r={b3r} b3={b3} b5r={b5r} b5={b5} pp={pp}\n",
                        layer.name
                    ));
                } else {
                    out.push_str(&format!("# unrepresentable fork {}\n", layer.name));
                }
            }
            LayerOp::Fork { .. } => {
                out.push_str(&format!("# unrepresentable fork {}\n", layer.name));
            }
            LayerOp::Flatten => out.push_str("flatten\n"),
            LayerOp::Gap => out.push_str("gap\n"),
            LayerOp::Dense { o, relu } => {
                out.push_str(&format!("dense {} o={o} relu={}\n", layer.name, *relu as u8));
            }
            LayerOp::Softmax => out.push_str("softmax\n"),
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    const TINY: &str = "
# TinyNet description
net tinynet
input 3 16 16
classes 8

conv conv1 m=16 k=3 s=1 p=1
maxpool k=2 s=2
conv conv2 m=32 k=3 s=1 p=1
maxpool k=2 s=2
conv conv3 m=32 k=3 s=1 p=1
flatten
dense fc4 o=64 relu=1
dense fc5 o=8 relu=0
";

    #[test]
    fn parses_tinynet_equal_to_zoo() {
        let net = parse_cappnet(TINY).unwrap();
        let zoo_net = zoo::tinynet();
        assert_eq!(net.input, zoo_net.input);
        assert_eq!(net.classes, zoo_net.classes);
        assert_eq!(net.param_layer_names(), zoo_net.param_layer_names());
    }

    #[test]
    fn fire_expansion_matches_zoo() {
        let text = "
net mini
input 3 15 15
classes 8
conv conv1 m=8 k=3 s=2 p=0
fire fire2 s1=4 e1=4 e3=4
gap
";
        let net = parse_cappnet(text).unwrap();
        assert_eq!(
            net.param_layer_names(),
            vec!["conv1", "fire2/s1", "fire2/e1", "fire2/e3"]
        );
    }

    #[test]
    fn inception_expansion() {
        let text = "
net mini
input 8 12 12
classes 16
inception inc b1=4 b3r=4 b3=4 b5r=4 b5=4 pp=4
gap
";
        let net = parse_cappnet(text).unwrap();
        assert_eq!(net.param_layer_names().len(), 6);
        let info = crate::model::shapes::infer(&net).unwrap();
        assert_eq!(info.output, TensorShape::Flat { len: 16 });
    }

    #[test]
    fn missing_header_rejected() {
        assert!(parse_cappnet("conv c m=4 k=3").is_err());
        assert!(parse_cappnet("net x\ninput 3 8 8\n").is_err()); // no classes
    }

    #[test]
    fn wrong_class_count_rejected() {
        let text = "
net bad
input 3 16 16
classes 10
conv conv1 m=8 k=3 s=1 p=1
gap
";
        // gap yields 8 outputs, classes says 10.
        assert!(parse_cappnet(text).is_err());
    }

    #[test]
    fn unknown_layer_rejected() {
        let text = "net x\ninput 3 8 8\nclasses 3\nwaffle w1 k=3\n";
        let e = parse_cappnet(text).unwrap_err().to_string();
        assert!(e.contains("waffle"), "{e}");
    }

    #[test]
    fn bad_param_value_rejected() {
        let text = "net x\ninput 3 8 8\nclasses 3\nconv c m=abc k=3\n";
        assert!(parse_cappnet(text).is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let net = parse_cappnet(TINY).unwrap();
        assert_eq!(net.name, "tinynet");
    }

    #[test]
    fn writer_roundtrip_linear_net() {
        let net = zoo::tinynet();
        let text = write_cappnet(&net);
        let back = parse_cappnet(&text).unwrap();
        assert_eq!(back.param_layer_names(), net.param_layer_names());
        assert_eq!(back.input, net.input);
    }

    #[test]
    fn writer_roundtrip_squeezenet_and_googlenet() {
        for net in [zoo::squeezenet(), zoo::googlenet()] {
            let text = write_cappnet(&net);
            assert!(!text.contains("unrepresentable"), "{text}");
            let back = parse_cappnet(&text).unwrap();
            assert_eq!(back.param_layer_names(), net.param_layer_names(), "{}", net.name);
        }
    }
}
