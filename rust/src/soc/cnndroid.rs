//! CNNDroid comparator model (paper Table III, prior art [10]).
//!
//! CNNDroid (Latifi Oskouei et al., MM'16) accelerates convolutions on
//! the mobile GPU but keeps conventional row-major data and performs a
//! host↔GPU round-trip per accelerated layer; FC and the remaining
//! layers run on the CPU. The model below implements exactly that
//! execution strategy on the same device constants our Cappuccino model
//! uses, so Table III compares *approaches*, not fitted numbers:
//!
//! * per conv layer: GPU compute at an effective GPU rate, plus copy-in
//!   (input + weights) and copy-out over the host↔GPU path, plus a
//!   driver launch overhead;
//! * everything else: single-core CPU at parallel-efficiency rate.
//!
//! No imprecise mode, no map-major vectorisation — the two Cappuccino
//! advantages the paper credits for the 1.38x / 11.47x wins.

use crate::model::{shapes, Network};
use crate::soc::devices::DeviceModel;

/// GPU-path constants for the CNNDroid execution strategy.
#[derive(Debug, Clone)]
pub struct CnnDroidModel {
    /// Effective mobile-GPU conv throughput, GFLOP/s.
    pub gpu_gflops: f64,
    /// Host↔GPU copy bandwidth, GB/s (shared-memory SoCs still pay a
    /// mapping/copy cost through the driver).
    pub copy_bw_gbs: f64,
    /// Per-kernel driver launch overhead, ms.
    pub launch_ms: f64,
}

impl CnnDroidModel {
    /// CNNDroid on a given SoC: GPU rate scales with the device's
    /// parallel efficiency class.
    pub fn for_device(device: &DeviceModel) -> CnnDroidModel {
        CnnDroidModel {
            // Adreno-class sustained conv throughput: a small multiple of
            // the CPU-parallel rate on the same SoC generation.
            gpu_gflops: device.parallel_gflops() * 0.9,
            copy_bw_gbs: device.mem_bw_gbs * 0.25,
            launch_ms: 1.2,
        }
    }

    /// Simulated single-inference latency, ms.
    pub fn latency_ms(&self, net: &Network, device: &DeviceModel) -> f64 {
        let info = shapes::infer(net).expect("network must shape-check");
        let mut total = 0.0;
        for cost in &info.costs {
            if cost.kind == "conv" {
                let compute = cost.flops / (self.gpu_gflops * 1e9) * 1e3;
                let copies = (cost.param_bytes + cost.input_bytes + cost.output_bytes)
                    / (self.copy_bw_gbs * 1e9)
                    * 1e3;
                total += compute + copies + self.launch_ms;
            } else {
                // CPU path, multi-threaded but scalar.
                let rate = device.parallel_gflops() * 1e9;
                total += cost.flops / rate * 1e3
                    + (cost.input_bytes + cost.output_bytes) / (device.mem_bw_gbs * 1e9) * 1e3;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::soc::devices;
    use crate::soc::devices::ProcessingMode;
    use crate::soc::latency::simulate;

    #[test]
    fn table3_shape_holds() {
        // Paper Table III (AlexNet on Snapdragon 810): CNNDroid 709ms,
        // Cappuccino parallel 512.72ms (1.38x), imprecise 61.80ms
        // (11.47x). Assert the ordering and coarse factors.
        let device = devices::nexus6p();
        let net = zoo::alexnet();
        let droid = CnnDroidModel::for_device(&device).latency_ms(&net, &device);
        let par = simulate(&net, &device, ProcessingMode::Parallel).total_ms();
        let imp = simulate(&net, &device, ProcessingMode::Imprecise).total_ms();
        assert!(droid > par, "CNNDroid {droid:.0}ms must trail parallel {par:.0}ms");
        let s_par = droid / par;
        let s_imp = droid / imp;
        assert!((1.05..4.0).contains(&s_par), "parallel speedup {s_par:.2}");
        assert!((4.0..40.0).contains(&s_imp), "imprecise speedup {s_imp:.2}");
        assert!(s_imp > s_par);
    }

    #[test]
    fn cnndroid_magnitude_close_to_paper() {
        // Paper: 709 ms on SD810; accept a 2.5x band.
        let device = devices::nexus6p();
        let droid = CnnDroidModel::for_device(&device).latency_ms(&zoo::alexnet(), &device);
        assert!(
            (300.0..1800.0).contains(&droid),
            "CNNDroid AlexNet latency {droid:.0}ms"
        );
    }

    #[test]
    fn cnndroid_still_beats_java() {
        let device = devices::nexus6p();
        let net = zoo::alexnet();
        let droid = CnnDroidModel::for_device(&device).latency_ms(&net, &device);
        let base = simulate(&net, &device, ProcessingMode::JavaBaseline).total_ms();
        assert!(base / droid > 3.0, "GPU offload must beat interpreter");
    }
}
