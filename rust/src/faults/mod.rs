//! Deterministic fault injection (std-only) — the chaos layer the
//! fault-tolerance machinery is proved against.
//!
//! Production serving code cannot be trusted to survive faults that
//! never happen in tests, so this module threads seeded, addressable
//! **injection points** through the hot path: the plan step loop
//! (`site` = the step kind: `conv`, `dense`, `pool`, `transfer` —
//! the last hitting the cross-backend copies of staged plans), the
//! thread pool (`pool`), the serve backend boundary (`backend`), and
//! the frontend queue/worker boundaries (`enqueue`, `worker`). Each point
//! calls [`check`] with its site name; when injection is disabled —
//! the production default — that is one relaxed atomic load and
//! nothing else.
//!
//! ## Spec grammar
//!
//! A config is a comma-separated list of `kind:site:prob` triples with
//! an optional `seed=N` element:
//!
//! ```text
//! CAPPUCCINO_FAULTS="seed=42,panic:conv:0.01,err:backend:0.05"
//! ```
//!
//! * `kind` — `panic` (the injection point panics, exercising
//!   containment) or `err` (the injection point surfaces a typed
//!   error, exercising fault replies and supervision).
//! * `site` — an injection-point name, or `*` to match every site.
//! * `prob` — injection probability in `[0, 1]`.
//!
//! The config comes from the `CAPPUCCINO_FAULTS` environment variable
//! (read once, at first use) or programmatically via [`install`]
//! (`serve --faults`, chaos tests). [`install`] always wins over the
//! environment.
//!
//! ## Determinism
//!
//! Every spec owns a monotone counter; the n-th check against a spec
//! hashes `(seed, site, n)` through splitmix64 and injects when the
//! hash falls below `prob * 2^64`. Same seed + same sequence of checks
//! → the same faults, so single-worker chaos runs are reproducible
//! bit-for-bit and multi-worker runs have a seed-stable fault *rate*
//! (threads interleave counter increments, so only the aggregate is
//! pinned). No wall clock, no OS entropy.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once};

use crate::util::error::{Error, Result};

/// What an injection point should do when its spec fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic at the injection point (containment path).
    Panic,
    /// Surface a typed error from the injection point (fault-reply path).
    Err,
}

/// One parsed `kind:site:prob` injection rule.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Injection-point name this rule matches (`*` matches all).
    pub site: String,
    /// Injection probability in `[0, 1]`.
    pub prob: f64,
}

/// A full injection config: seed + rules. Parsed from the spec grammar
/// above; installed process-wide with [`install`].
#[derive(Debug, Clone)]
pub struct FaultConfig {
    pub seed: u64,
    pub specs: Vec<FaultSpec>,
}

impl FaultConfig {
    /// Parse `"seed=42,panic:conv:0.01,err:backend:0.05"`. Unknown
    /// kinds, probabilities outside `[0, 1]`, and malformed elements
    /// are rejected with [`Error::Config`] — a typo'd chaos spec must
    /// not silently run fault-free.
    pub fn parse(spec: &str) -> Result<FaultConfig> {
        let mut seed = 0u64;
        let mut specs = Vec::new();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(s) = part.strip_prefix("seed=").or_else(|| part.strip_prefix("seed:")) {
                seed = s.trim().parse::<u64>().map_err(|_| {
                    Error::Config(format!("faults: bad seed {s:?} in {part:?}"))
                })?;
                continue;
            }
            let mut it = part.splitn(3, ':');
            let (kind, site, prob) = match (it.next(), it.next(), it.next()) {
                (Some(k), Some(s), Some(p)) => (k, s, p),
                _ => {
                    return Err(Error::Config(format!(
                        "faults: expected kind:site:prob, got {part:?}"
                    )))
                }
            };
            let kind = match kind {
                "panic" => FaultKind::Panic,
                "err" => FaultKind::Err,
                other => {
                    return Err(Error::Config(format!(
                        "faults: unknown kind {other:?} (want panic|err) in {part:?}"
                    )))
                }
            };
            let prob = prob.parse::<f64>().map_err(|_| {
                Error::Config(format!("faults: bad probability {prob:?} in {part:?}"))
            })?;
            if !prob.is_finite() || !(0.0..=1.0).contains(&prob) {
                return Err(Error::Config(format!(
                    "faults: probability {prob} outside [0, 1] in {part:?}"
                )));
            }
            if site.is_empty() {
                return Err(Error::Config(format!("faults: empty site in {part:?}")));
            }
            specs.push(FaultSpec { kind, site: site.to_string(), prob });
        }
        Ok(FaultConfig { seed, specs })
    }
}

/// One installed rule + its deterministic draw counter.
struct ActiveSpec {
    kind: FaultKind,
    site: String,
    /// `prob` scaled to the u64 hash range (`prob * 2^64`, saturating).
    threshold: u64,
    site_hash: u64,
    count: AtomicU64,
}

struct Active {
    seed: u64,
    specs: Vec<ActiveSpec>,
}

impl Active {
    fn check(&self, site: &str) -> Option<FaultKind> {
        for spec in &self.specs {
            if spec.site != "*" && spec.site != site {
                continue;
            }
            if spec.threshold == 0 {
                continue;
            }
            let n = spec.count.fetch_add(1, Ordering::Relaxed);
            let draw = splitmix64(
                self.seed
                    .wrapping_mul(0x9e37_79b9_7f4a_7c15)
                    .wrapping_add(spec.site_hash)
                    .wrapping_add(n),
            );
            if draw < spec.threshold {
                return Some(spec.kind);
            }
        }
        None
    }
}

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// FNV-1a — stable site addressing independent of the std hasher.
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fast-path gate: disabled means [`check`] is one relaxed load.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ACTIVE: Mutex<Option<Arc<Active>>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

fn activate(cfg: Option<&FaultConfig>) {
    let active = cfg.filter(|c| !c.specs.is_empty()).map(|c| {
        Arc::new(Active {
            seed: c.seed,
            specs: c
                .specs
                .iter()
                .map(|s| ActiveSpec {
                    kind: s.kind,
                    site: s.site.clone(),
                    threshold: if s.prob >= 1.0 {
                        u64::MAX
                    } else {
                        (s.prob * (u64::MAX as f64)) as u64
                    },
                    site_hash: fnv1a(&s.site),
                    count: AtomicU64::new(0),
                })
                .collect(),
        })
    });
    let mut guard = ACTIVE.lock().unwrap_or_else(|p| p.into_inner());
    ENABLED.store(active.is_some(), Ordering::Relaxed);
    *guard = active;
}

fn ensure_env() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("CAPPUCCINO_FAULTS") {
            match FaultConfig::parse(&spec) {
                Ok(cfg) => activate(Some(&cfg)),
                Err(e) => eprintln!("CAPPUCCINO_FAULTS ignored: {e}"),
            }
        }
    });
}

/// Install (or with `None`, clear) the process-wide injection config.
/// Overrides any `CAPPUCCINO_FAULTS` environment config. Chaos tests
/// that install different configs must serialize themselves (the
/// config is process-global).
pub fn install(cfg: Option<FaultConfig>) {
    ENV_INIT.call_once(|| {});
    activate(cfg.as_ref());
}

/// Is any injection config active?
pub fn enabled() -> bool {
    ensure_env();
    ENABLED.load(Ordering::Relaxed)
}

/// Should the injection point named `site` fault on this call — and if
/// so, how? `None` on the (default) disabled path costs one relaxed
/// atomic load.
pub fn check(site: &str) -> Option<FaultKind> {
    ensure_env();
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    let active = ACTIVE.lock().unwrap_or_else(|p| p.into_inner()).clone()?;
    active.check(site)
}

/// Panic here when a `panic:` spec fires for `site`. The standard
/// injection call for sites whose containment path is under test.
pub fn maybe_panic(site: &str) {
    if check(site) == Some(FaultKind::Panic) {
        panic!("injected fault at {site}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let cfg = FaultConfig::parse("seed=42, panic:conv:0.01, err:backend:1").unwrap();
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.specs.len(), 2);
        assert_eq!(cfg.specs[0].kind, FaultKind::Panic);
        assert_eq!(cfg.specs[0].site, "conv");
        assert!((cfg.specs[0].prob - 0.01).abs() < 1e-12);
        assert_eq!(cfg.specs[1].kind, FaultKind::Err);
        assert!((cfg.specs[1].prob - 1.0).abs() < 1e-12);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(FaultConfig::parse("panic:conv").is_err());
        assert!(FaultConfig::parse("boom:conv:0.1").is_err());
        assert!(FaultConfig::parse("panic:conv:1.5").is_err());
        assert!(FaultConfig::parse("panic:conv:NaN").is_err());
        assert!(FaultConfig::parse("panic::0.1").is_err());
        assert!(FaultConfig::parse("seed=xyz,panic:conv:0.1").is_err());
        assert!(FaultConfig::parse("").unwrap().specs.is_empty());
    }

    #[test]
    fn deterministic_by_seed() {
        // Directly on `Active` (not the global install) so this test
        // cannot race other tests over process state.
        let mk = |seed| {
            let cfg = FaultConfig::parse("panic:conv:0.25").unwrap();
            Active {
                seed,
                specs: cfg
                    .specs
                    .iter()
                    .map(|s| ActiveSpec {
                        kind: s.kind,
                        site: s.site.clone(),
                        threshold: (s.prob * (u64::MAX as f64)) as u64,
                        site_hash: fnv1a(&s.site),
                        count: AtomicU64::new(0),
                    })
                    .collect(),
            }
        };
        let draws = |a: &Active| (0..256).map(|_| a.check("conv").is_some()).collect::<Vec<_>>();
        let (a, b, c) = (mk(7), mk(7), mk(8));
        let (da, db, dc) = (draws(&a), draws(&b), draws(&c));
        assert_eq!(da, db, "same seed must reproduce the same fault sequence");
        assert_ne!(da, dc, "different seeds should differ");
        let hits = da.iter().filter(|&&h| h).count();
        assert!((20..=110).contains(&hits), "p=0.25 over 256 draws hit {hits} times");
        // Sites that no spec names never fault.
        assert!(a.check("dense").is_none());
    }

    #[test]
    fn wildcard_matches_every_site() {
        let cfg = FaultConfig::parse("err:*:1").unwrap();
        let a = Active {
            seed: 1,
            specs: cfg
                .specs
                .iter()
                .map(|s| ActiveSpec {
                    kind: s.kind,
                    site: s.site.clone(),
                    threshold: u64::MAX,
                    site_hash: fnv1a(&s.site),
                    count: AtomicU64::new(0),
                })
                .collect(),
        };
        assert_eq!(a.check("conv"), Some(FaultKind::Err));
        assert_eq!(a.check("anything"), Some(FaultKind::Err));
    }
}
