//! Schedule IR — the engine's one per-layer tuning surface.
//!
//! Cappuccino's output is not a model, it is *software*: a per-layer
//! choice of parallelization, layout, and arithmetic for one concrete
//! SoC. Until this module those choices were scattered across
//! [`crate::engine::PlanBuilder`] setters (`.policy/.packing/.tiling/`
//! `.modes/.config/.affinity`) and mostly plan-global. A [`Schedule`]
//! is the canonical, serializable form of the whole tuning surface:
//!
//! * [`LayerSchedule`] — per parameterised layer: thread-workload
//!   allocation ([`Parallelism`]: OLP lowers map-major vectorised,
//!   FLP/KLP lower row-major with reduction buffers), weight
//!   [`LayerSchedule::packing`], an optional row-tile
//!   [`LayerSchedule::tiling`] override (None = the L1/L2 cost model
//!   [`ConvTiling::choose`]), the arithmetic [`LayerSchedule::mode`],
//!   and [`LayerSchedule::placement`] (cost-weighted cluster placement
//!   of that layer's macro items).
//! * [`PoolSettings`] — plan-global execution state: pool-chunk
//!   `threads` per parallel region, the `affinity` default, and an
//!   optional serve-worker [`CoreSet`].
//!
//! Every [`crate::engine::PlanBuilder`] fluent setter now lowers into a
//! uniform `Schedule` ([`Schedule::from_uniform`]), so there is exactly
//! **one** path into plan compilation, and
//! [`crate::engine::PlanBuilder::schedule`] accepts a heterogeneous one
//! directly. Schedules serialize ([`Schedule::to_json`] /
//! [`Schedule::from_json`]) so a tuning run on the target device
//! (`cappuccino tune`, [`crate::autotune`]) becomes a durable
//! `schedule.json` artifact that `cappuccino serve --schedule` loads —
//! the synthesized software travels from tune to serve as a file, like
//! the paper's emitted programs.
//!
//! ## Migration: `vector_width` and quantized mode (PR 6)
//!
//! Two knobs were added to the per-layer surface: the kernel-selection
//! width [`LayerSchedule::vector_width`] (0 = auto, 1 = force the
//! scalar row kernels, 4/8 = require that lane width) and the
//! [`ArithMode::QuantI8`] arithmetic mode (serialized as
//! `"mode": "quant_i8"`). Both are **optional in the JSON artifact**:
//! pre-PR-6 `schedule.json` files carry neither field and parse as
//! `vector_width = 0` with their recorded f32 mode, so existing tuned
//! artifacts (including CI's `tune-smoke` upload) keep loading
//! unchanged. [`Schedule::to_json`] always emits `vector_width`.
//!
//! ## Migration: per-layer backends (PR 10)
//!
//! Placement now extends past "which core cluster" to **which
//! backend**: [`LayerSchedule::backend`] names the execution substrate
//! ([`BackendTarget::Native`], [`BackendTarget::Pjrt`],
//! [`BackendTarget::Mock`]) each layer runs on. A schedule whose layers
//! span more than one backend ([`Schedule::is_staged`]) compiles into a
//! staged pipeline ([`crate::engine::hetero`]): the flat step sequence
//! is cut at backend boundaries and explicit `Transfer` steps hand
//! buffers across each cut. The field serializes as `"backend"` and is
//! optional in the artifact — pre-PR-10 files parse as all-`Native`
//! and compile to exactly the non-staged plan.
//!
//! ## Strict parsing
//!
//! Historically [`Schedule::from_json`] silently ignored unknown keys,
//! so a typo'd field (say `"backned"` for `"backend"`) parsed cleanly
//! and quietly did nothing. Unknown keys at the top level, in `pool`,
//! in `tiling`, and per layer entry are now *warned about* on the
//! lenient path (`from_json`, stderr) and **rejected** with
//! [`Error::Config`] on the strict path ([`Schedule::from_json_strict`]
//! / [`Schedule::load_strict`], used by `cappuccino check --strict`).

use std::collections::BTreeMap;

use crate::engine::conv::ConvTiling;
use crate::engine::mode::ArithMode;
use crate::engine::network::ModeAssignment;
use crate::engine::parallel::Parallelism;
use crate::engine::topology::CoreSet;
use crate::model::Network;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Bounds a parsed artifact must respect — [`Schedule::from_json`]'s
/// guard against corrupt or hand-edited files. `as_usize` accepts any
/// non-negative integral double, so without these a 2^50 in the JSON
/// reaches plan compilation as a real allocation size. All three sit
/// far above anything the tuner can emit.
const MAX_U: usize = 64;
const MAX_POOL_THREADS: usize = 1024;
const MAX_TILE: usize = 1 << 20;

/// Execution substrate a layer is placed on — the backend dimension of
/// per-layer placement. A schedule mixing targets compiles into a
/// staged pipeline ([`crate::engine::hetero`]); a uniform schedule
/// compiles to exactly the single-backend plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum BackendTarget {
    /// The in-process native CPU engine (the default).
    Native,
    /// The PJRT/XLA runtime ([`crate::runtime`]); a typed
    /// [`Error::Xla`](crate::util::error::Error::Xla) unless the `pjrt`
    /// feature is enabled with the vendored `xla` crate patched in.
    Pjrt,
    /// Deterministic in-process mock accelerator: bitwise-identical
    /// math via the native plan executor plus configurable per-layer
    /// latency ([`crate::runtime::backends::MockLatency`]) — the
    /// hardware-free test substrate for partitioning and pipelining.
    Mock,
}

impl BackendTarget {
    /// Stable wire name — the `"backend"` value in `schedule.json`.
    pub fn as_str(self) -> &'static str {
        match self {
            BackendTarget::Native => "native",
            BackendTarget::Pjrt => "pjrt",
            BackendTarget::Mock => "mock",
        }
    }
}

impl std::fmt::Display for BackendTarget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for BackendTarget {
    type Err = Error;
    fn from_str(s: &str) -> Result<BackendTarget> {
        match s {
            "native" => Ok(BackendTarget::Native),
            "pjrt" => Ok(BackendTarget::Pjrt),
            "mock" => Ok(BackendTarget::Mock),
            other => Err(Error::parse(
                "backend",
                format!("unknown backend {other:?} (want native|pjrt|mock)"),
            )),
        }
    }
}

/// The tuning surface of one parameterised (conv/dense) layer.
///
/// Dense layers honour `packing` and `mode`; `parallelism`, `tiling`
/// and `placement` apply to conv layers (dense rows always chunk over
/// the pool). A conv layer scheduled [`Parallelism::Flp`] /
/// [`Parallelism::Klp`] lowers row-major — the plan inserts an exact
/// layout-reorder step at every boundary between map-major and
/// row-major layers, so heterogeneous schedules stay bitwise faithful
/// to the per-layer kernels.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerSchedule {
    /// Thread-workload allocation (section IV.A).
    pub parallelism: Parallelism,
    /// Arithmetic mode (section IV.C).
    pub mode: ArithMode,
    /// Tap-major / column-blocked weight panels (bitwise invisible).
    pub packing: bool,
    /// Row-tile macro-kernel override; `None` = the L1/L2 cost model.
    pub tiling: Option<ConvTiling>,
    /// Cost-weighted cluster placement of this layer's macro items
    /// (packed OLP conv only; bitwise invisible).
    pub placement: bool,
    /// SIMD kernel selection for the packed row kernels: `0` = auto
    /// (the widest backend available for the layer's `u`), `1` = force
    /// the scalar row kernels even in vectorised modes, `4`/`8` =
    /// require that lane width (a no-op unless the layer's `u` matches).
    /// [`ArithMode::Precise`] layers always run scalar regardless. The
    /// f32 kernels are bitwise identical at every setting, so this knob
    /// is pure speed — which is why the autotuner searches it.
    pub vector_width: usize,
    /// Execution substrate this layer is placed on. Mixing targets
    /// makes the schedule *staged* ([`Schedule::is_staged`]): the plan
    /// partitioner cuts the step sequence at backend boundaries and the
    /// staged pipeline runs each cut on its backend's worker
    /// ([`crate::engine::hetero`]). Bitwise invisible for `Native` and
    /// `Mock` (the mock runs the native kernels plus injected latency).
    pub backend: BackendTarget,
}

impl Default for LayerSchedule {
    fn default() -> Self {
        LayerSchedule {
            parallelism: Parallelism::Olp,
            mode: ArithMode::Precise,
            packing: true,
            tiling: None,
            placement: false,
            vector_width: 0,
            backend: BackendTarget::Native,
        }
    }
}

/// Plan-global execution settings.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolSettings {
    /// Pool **chunks** per parallel region (not a pool size — see
    /// [`crate::engine::ExecConfig`]). Must be >= 1.
    pub threads: usize,
    /// Default for cost-weighted cluster placement (the per-layer
    /// [`LayerSchedule::placement`] flag is what lowering consumes).
    pub affinity: bool,
    /// Serve-worker core set carried with the artifact
    /// ([`crate::serve::BatchPolicy::cores`]); plan compilation itself
    /// does not pin.
    pub cores: Option<CoreSet>,
}

impl Default for PoolSettings {
    fn default() -> Self {
        PoolSettings { threads: 1, affinity: false, cores: None }
    }
}

/// A complete per-layer schedule for one network — the canonical
/// configuration every plan is compiled from, and the artifact
/// `cappuccino tune` emits.
#[derive(Debug, Clone, PartialEq)]
pub struct Schedule {
    /// Network the schedule was built for (validated at apply time).
    pub net: String,
    /// Map-major vector width the schedule assumes (must match
    /// [`crate::engine::EngineParams::u`]).
    pub u: usize,
    pub pool: PoolSettings,
    /// One entry per parameterised layer, keyed by layer name.
    pub layers: BTreeMap<String, LayerSchedule>,
}

impl Schedule {
    /// The all-defaults schedule: every layer OLP / precise / packed /
    /// cost-model tiling, one pool chunk. The starting point the
    /// autotuner searches from.
    pub fn default_for(net: &Network, u: usize) -> Schedule {
        let layers = net
            .param_layer_names()
            .into_iter()
            .map(|n| (n, LayerSchedule::default()))
            .collect();
        Schedule { net: net.name.clone(), u, pool: PoolSettings::default(), layers }
    }

    /// Lower the fluent-setter surface into a uniform schedule — the
    /// designated (and only) translation from
    /// [`crate::engine::PlanBuilder`]'s global knobs to the per-layer
    /// IR. Rejects degenerate pools (`threads = 0`) and mode
    /// assignments naming layers the network does not have with
    /// [`Error::Config`].
    pub fn from_uniform(
        net: &Network,
        u: usize,
        modes: &ModeAssignment,
        policy: Parallelism,
        packing: bool,
        tiling: Option<ConvTiling>,
        pool: PoolSettings,
    ) -> Result<Schedule> {
        if u == 0 {
            return Err(Error::Config("u = 0: the vector width must be at least 1".into()));
        }
        if pool.threads == 0 {
            return Err(Error::Config(
                "threads = 0: a plan needs at least one pool chunk per region".into(),
            ));
        }
        let names = net.param_layer_names();
        for key in modes.per_layer.keys() {
            if !names.iter().any(|n| n == key) {
                return Err(Error::Config(format!(
                    "mode assignment names layer {key:?}, which net {:?} does not have \
                     ({} parameterised layers)",
                    net.name,
                    names.len()
                )));
            }
        }
        let layers = names
            .into_iter()
            .map(|n| {
                let ls = LayerSchedule {
                    parallelism: policy,
                    mode: modes.mode_of(&n),
                    packing,
                    tiling,
                    placement: pool.affinity,
                    vector_width: 0,
                    backend: BackendTarget::Native,
                };
                (n, ls)
            })
            .collect();
        Ok(Schedule { net: net.name.clone(), u, pool, layers })
    }

    /// The schedule's modes as a [`ModeAssignment`] view.
    pub fn mode_assignment(&self) -> ModeAssignment {
        let mut ma = ModeAssignment::uniform(ArithMode::Precise);
        for (name, ls) in &self.layers {
            ma.per_layer.insert(name.clone(), ls.mode);
        }
        ma
    }

    /// Do all layers lower row-major (FLP/KLP)? Such plans run `u = 1`
    /// end to end, exactly like the pre-schedule `.policy()` families.
    pub(crate) fn all_rowmajor(&self) -> bool {
        !self.layers.is_empty()
            && self.layers.values().all(|l| l.parallelism != Parallelism::Olp)
    }

    /// Does this schedule place layers on more than one backend? Staged
    /// schedules compile into a partitioned pipeline
    /// ([`crate::engine::hetero::StagedPlan`]); uniform ones compile to
    /// exactly the single-backend plan.
    pub fn is_staged(&self) -> bool {
        let mut targets = self.layers.values().map(|l| l.backend);
        match targets.next() {
            Some(first) => targets.any(|b| b != first),
            None => false,
        }
    }

    /// The backend a layer is placed on (`Native` for layers the
    /// schedule does not name — structural steps inherit their
    /// surrounding stage).
    pub fn backend_of(&self, layer: &str) -> BackendTarget {
        self.layers.get(layer).map(|l| l.backend).unwrap_or(BackendTarget::Native)
    }

    /// Validate the schedule against the network and parameter width it
    /// is about to compile with. Every violation is [`Error::Config`].
    pub fn validate_for(&self, net: &Network, params_u: usize) -> Result<()> {
        if self.net != net.name {
            return Err(Error::Config(format!(
                "schedule was built for net {:?}, applied to {:?}",
                self.net, net.name
            )));
        }
        if self.u == 0 {
            return Err(Error::Config("schedule u = 0: vector width must be >= 1".into()));
        }
        if self.u != params_u {
            return Err(Error::Config(format!("schedule u={} vs params u={params_u}", self.u)));
        }
        if self.pool.threads == 0 {
            return Err(Error::Config(
                "schedule pool.threads = 0: a plan needs at least one pool chunk".into(),
            ));
        }
        let names = net.param_layer_names();
        if self.layers.len() != names.len() {
            return Err(Error::Config(format!(
                "schedule has {} layer entries vs net {:?}'s {} parameterised layers",
                self.layers.len(),
                net.name,
                names.len()
            )));
        }
        for n in &names {
            if !self.layers.contains_key(n) {
                return Err(Error::Config(format!("schedule is missing an entry for layer {n:?}")));
            }
        }
        for (n, ls) in &self.layers {
            if !matches!(ls.vector_width, 0 | 1 | 4 | 8) {
                return Err(Error::Config(format!(
                    "layer {n:?}: vector_width must be 0 (auto), 1 (scalar), 4, or 8 — got {}",
                    ls.vector_width
                )));
            }
        }
        Ok(())
    }

    // -- JSON artifact ------------------------------------------------------

    /// Serialise to the `schedule.json` artifact format (stable key
    /// order; layers as an array sorted by name).
    pub fn to_json(&self) -> Json {
        let cores = match self.pool.cores {
            Some(cs) => Json::usizes(&cs.cpus()),
            None => Json::Null,
        };
        let layers = self
            .layers
            .iter()
            .map(|(name, ls)| {
                let tiling = match ls.tiling {
                    Some(t) => Json::obj(vec![
                        ("tm", Json::num(t.tm as f64)),
                        ("th", Json::num(t.th as f64)),
                    ]),
                    None => Json::Null,
                };
                Json::obj(vec![
                    ("layer", Json::str(name.clone())),
                    ("parallelism", Json::str(ls.parallelism.as_str())),
                    ("mode", Json::str(ls.mode.as_str())),
                    ("packing", Json::Bool(ls.packing)),
                    ("tiling", tiling),
                    ("placement", Json::Bool(ls.placement)),
                    ("vector_width", Json::num(ls.vector_width as f64)),
                    ("backend", Json::str(ls.backend.as_str())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("net", Json::str(self.net.clone())),
            ("u", Json::num(self.u as f64)),
            (
                "pool",
                Json::obj(vec![
                    ("threads", Json::num(self.pool.threads as f64)),
                    ("affinity", Json::Bool(self.pool.affinity)),
                    ("cores", cores),
                ]),
            ),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Parse a `schedule.json` document (lenient: unknown keys warn on
    /// stderr). Beyond shape errors, every numeric field is
    /// bounds-checked here: `as_usize` accepts any non-negative
    /// integral double, so a corrupt or hand-edited artifact could
    /// otherwise smuggle a 2^50 thread count or tile size straight into
    /// plan compilation and die as an allocation abort instead of a
    /// typed [`Error::Config`].
    pub fn from_json(json: &Json) -> Result<Schedule> {
        Schedule::from_json_with(json, false)
    }

    /// Strict-parse a `schedule.json` document: any unknown key — at
    /// the top level, in `pool`, in `tiling`, or in a layer entry — is
    /// rejected with [`Error::Config`] instead of warned about, so a
    /// typo'd field (`"backned"` for `"backend"`) can never silently
    /// no-op.
    pub fn from_json_strict(json: &Json) -> Result<Schedule> {
        Schedule::from_json_with(json, true)
    }

    /// Unknown-key sweep shared by the lenient and strict parse paths.
    /// Lenient = warn once per key on stderr (existing artifacts keep
    /// loading); strict = typed rejection.
    fn check_keys(json: &Json, known: &[&str], ctx: &str, strict: bool) -> Result<()> {
        for key in json.as_obj()?.keys() {
            if !known.contains(&key.as_str()) {
                let hint = format!(
                    "schedule artifact: unknown key {key:?} in {ctx} (known keys: {})",
                    known.join(", ")
                );
                if strict {
                    return Err(Error::Config(format!("{hint} — strict parse rejects it")));
                }
                eprintln!("WARNING: {hint} — ignored (use strict parsing to reject)");
            }
        }
        Ok(())
    }

    fn from_json_with(json: &Json, strict: bool) -> Result<Schedule> {
        Schedule::check_keys(json, &["net", "u", "pool", "layers"], "the top level", strict)?;
        let pool_json = json.get("pool")?;
        Schedule::check_keys(pool_json, &["threads", "affinity", "cores"], "pool", strict)?;
        let cores = match pool_json.get("cores")? {
            Json::Null => None,
            v => {
                let cpus = v.usize_vec()?;
                // CoreSet::of silently drops ids >= 64; for an artifact
                // that silence would turn "pin to cpu 91" into "run
                // unpinned", so reject instead.
                if let Some(bad) = cpus.iter().find(|&&c| c >= 64) {
                    return Err(Error::Config(format!(
                        "schedule artifact: core id {bad} out of range (core sets cover \
                         cpus 0-63)"
                    )));
                }
                Some(CoreSet::of(&cpus))
            }
        };
        let threads = pool_json.get("threads")?.as_usize()?;
        if threads > MAX_POOL_THREADS {
            return Err(Error::Config(format!(
                "schedule artifact: pool.threads={threads} is absurd (limit {MAX_POOL_THREADS})"
            )));
        }
        let pool = PoolSettings {
            threads,
            affinity: pool_json.get("affinity")?.as_bool()?,
            cores,
        };
        let mut layers = BTreeMap::new();
        for l in json.get("layers")?.as_arr()? {
            Schedule::check_keys(
                l,
                &[
                    "layer",
                    "parallelism",
                    "mode",
                    "packing",
                    "tiling",
                    "placement",
                    "vector_width",
                    "backend",
                ],
                "a layer entry",
                strict,
            )?;
            let name = l.get("layer")?.as_str()?.to_string();
            let tiling = match l.get("tiling")? {
                Json::Null => None,
                t => {
                    Schedule::check_keys(t, &["tm", "th"], "tiling", strict)?;
                    let (tm, th) = (t.get("tm")?.as_usize()?, t.get("th")?.as_usize()?);
                    if tm == 0 || th == 0 || tm > MAX_TILE || th > MAX_TILE {
                        return Err(Error::Config(format!(
                            "schedule artifact: layer {name:?} tiling {tm}x{th} out of range \
                             (1..={MAX_TILE})"
                        )));
                    }
                    Some(ConvTiling { tm, th })
                }
            };
            // `vector_width` arrived in PR 6; treat it as optional so
            // pre-PR-6 artifacts keep loading (default 0 = auto). The
            // mode string likewise simply never says "quant_i8" in old
            // files.
            let vector_width = match l.opt("vector_width") {
                Some(v) => v.as_usize()?,
                None => 0,
            };
            if !matches!(vector_width, 0 | 1 | 4 | 8) {
                return Err(Error::Config(format!(
                    "schedule artifact: vector_width must be 0, 1, 4, or 8 — got {vector_width}"
                )));
            }
            // `backend` arrived in PR 10; optional so pre-PR-10
            // artifacts keep loading as all-Native (non-staged).
            let backend = match l.opt("backend") {
                Some(v) => v.as_str()?.parse()?,
                None => BackendTarget::Native,
            };
            let ls = LayerSchedule {
                parallelism: l.get("parallelism")?.as_str()?.parse()?,
                mode: l.get("mode")?.as_str()?.parse()?,
                packing: l.get("packing")?.as_bool()?,
                tiling,
                placement: l.get("placement")?.as_bool()?,
                vector_width,
                backend,
            };
            if layers.insert(name.clone(), ls).is_some() {
                return Err(Error::Config(format!("schedule lists layer {name:?} twice")));
            }
        }
        let u = json.get("u")?.as_usize()?;
        // A zero width or chunk count can never describe a runnable
        // plan; reject the artifact at parse time rather than letting
        // it panic inside parameter layout later. The upper bound on u
        // guards the same way against allocation-sized widths.
        if u == 0 || pool.threads == 0 {
            return Err(Error::Config(format!(
                "schedule artifact has u={u}, pool.threads={}: both must be >= 1",
                pool.threads
            )));
        }
        if u > MAX_U {
            return Err(Error::Config(format!(
                "schedule artifact: u={u} is absurd (limit {MAX_U})"
            )));
        }
        Ok(Schedule {
            net: json.get("net")?.as_str()?.to_string(),
            u,
            pool,
            layers,
        })
    }

    /// Write the artifact to disk atomically (tmp + rename): a tuning
    /// run killed mid-write must never leave a truncated artifact where
    /// the next serve run expects a schedule.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::util::write_atomic(path, self.to_json().to_string())?;
        Ok(())
    }

    /// Load a `schedule.json` artifact from disk.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Schedule> {
        let text = std::fs::read_to_string(path)?;
        Schedule::from_json(&Json::parse(&text)?)
    }

    /// Load an artifact with strict parsing ([`Schedule::from_json_strict`]):
    /// unknown keys are a typed [`Error::Config`]. Used by
    /// `cappuccino check --strict`.
    pub fn load_strict(path: impl AsRef<std::path::Path>) -> Result<Schedule> {
        let text = std::fs::read_to_string(path)?;
        Schedule::from_json_strict(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn sample() -> Schedule {
        let net = zoo::tinynet();
        let mut s = Schedule::default_for(&net, 4);
        s.pool = PoolSettings { threads: 4, affinity: true, cores: Some(CoreSet::of(&[0, 2])) };
        let c2 = s.layers.get_mut("conv2").unwrap();
        c2.parallelism = Parallelism::Flp;
        c2.mode = ArithMode::Imprecise;
        c2.packing = false;
        c2.tiling = Some(ConvTiling { tm: 2, th: 3 });
        c2.placement = true;
        s
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let mut s = sample();
        // Exercise the PR-6 knobs: a forced-scalar layer and a
        // quantized layer must survive the round trip.
        let c1 = s.layers.get_mut("conv1").unwrap();
        c1.vector_width = 1;
        let c2 = s.layers.get_mut("conv2").unwrap();
        c2.mode = ArithMode::QuantI8;
        c2.vector_width = 8;
        let text = s.to_json().to_string();
        let back = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(text.contains("quant_i8") && text.contains("vector_width"));
    }

    #[test]
    fn pre_pr6_artifact_without_new_fields_loads_with_defaults() {
        // A fixture in the exact shape `to_json` emitted before the
        // `vector_width`/quant knobs existed: no vector_width key
        // anywhere, f32 modes only. It must parse with
        // `vector_width = 0` and re-serialize losslessly.
        let old = r#"{"net":"tinynet","u":4,
            "pool":{"threads":2,"affinity":false,"cores":null},
            "layers":[
              {"layer":"conv1","parallelism":"olp","mode":"precise",
               "packing":true,"tiling":null,"placement":false},
              {"layer":"conv2","parallelism":"flp","mode":"imprecise",
               "packing":false,"tiling":{"tm":2,"th":3},"placement":false},
              {"layer":"conv3","parallelism":"olp","mode":"imprecise",
               "packing":true,"tiling":null,"placement":true},
              {"layer":"fc4","parallelism":"olp","mode":"relaxed",
               "packing":true,"tiling":null,"placement":false},
              {"layer":"fc5","parallelism":"olp","mode":"precise",
               "packing":true,"tiling":null,"placement":false}
            ]}"#;
        let s = Schedule::from_json(&Json::parse(old).unwrap()).unwrap();
        assert!(s.layers.values().all(|l| l.vector_width == 0));
        assert_eq!(s.layers["conv2"].mode, ArithMode::Imprecise);
        // Pre-PR-10 artifacts carry no `backend` key: all-Native,
        // non-staged.
        assert!(s.layers.values().all(|l| l.backend == BackendTarget::Native));
        assert!(!s.is_staged());
        assert!(s.validate_for(&zoo::tinynet(), 4).is_ok());
        // And the upgraded artifact round-trips through the new format.
        let back = Schedule::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
        // Strict parsing accepts it too — old artifacts have no unknown
        // keys, only missing optional ones.
        assert!(Schedule::from_json_strict(&Json::parse(old).unwrap()).is_ok());
    }

    #[test]
    fn backend_field_round_trips_and_staging_detected() {
        let mut s = sample();
        assert!(!s.is_staged(), "uniform-backend sample must not be staged");
        s.layers.get_mut("conv2").unwrap().backend = BackendTarget::Mock;
        assert!(s.is_staged());
        assert_eq!(s.backend_of("conv2"), BackendTarget::Mock);
        assert_eq!(s.backend_of("conv1"), BackendTarget::Native);
        assert_eq!(s.backend_of("not_a_layer"), BackendTarget::Native);
        let text = s.to_json().to_string();
        assert!(text.contains(r#""backend":"mock""#));
        let back = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(back.is_staged());
        // Unknown backend names are a typed rejection, not a default.
        let corrupt = text.replacen(r#""backend":"mock""#, r#""backend":"npu""#, 1);
        assert!(Schedule::from_json(&Json::parse(&corrupt).unwrap()).is_err());
    }

    #[test]
    fn strict_parse_rejects_misspelled_keys_lenient_warns() {
        // The regression the strict flag exists for: a typo'd
        // `"backend"` key must not silently no-op. Lenient parse loads
        // the artifact (with the backend defaulted), strict rejects.
        let ok = sample().to_json().to_string();
        let typo = ok.replacen(r#""backend":"native""#, r#""backned":"mock""#, 1);
        let parsed = Json::parse(&typo).unwrap();
        let lenient = Schedule::from_json(&parsed).unwrap();
        assert_eq!(lenient.layers["conv1"].backend, BackendTarget::Native);
        assert!(matches!(Schedule::from_json_strict(&parsed), Err(Error::Config(_))));
        // Unknown keys at the other nesting levels are caught too.
        for (from, to) in [
            (r#""net":"tinynet""#, r#""net":"tinynet","flavor":"dark""#),
            (r#""affinity":true"#, r#""affinity":true,"afinity":true"#),
            (r#""tiling":{"th":3,"tm":2}"#, r#""tiling":{"th":3,"tm":2,"tk":9}"#),
        ] {
            assert!(ok.contains(from), "fixture drifted: {from:?} not in artifact");
            let corrupt = ok.replacen(from, to, 1);
            let parsed = Json::parse(&corrupt).unwrap();
            assert!(
                Schedule::from_json(&parsed).is_ok(),
                "lenient parse must keep loading {to:?}"
            );
            assert!(
                matches!(Schedule::from_json_strict(&parsed), Err(Error::Config(_))),
                "strict parse must reject {to:?}"
            );
        }
        // The clean artifact passes strict parsing.
        assert!(Schedule::from_json_strict(&Json::parse(&ok).unwrap()).is_ok());
    }

    #[test]
    fn bad_vector_width_rejected() {
        let mut s = sample();
        s.layers.get_mut("conv1").unwrap().vector_width = 3;
        assert!(matches!(s.validate_for(&zoo::tinynet(), 4), Err(Error::Config(_))));
        let text = s.to_json().to_string();
        assert!(matches!(
            Schedule::from_json(&Json::parse(&text).unwrap()),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn validate_catches_mismatches() {
        let net = zoo::tinynet();
        let s = sample();
        assert!(s.validate_for(&net, 4).is_ok());
        assert!(matches!(s.validate_for(&net, 8), Err(Error::Config(_))));
        let mut wrong_net = s.clone();
        wrong_net.net = "alexnet".into();
        assert!(matches!(wrong_net.validate_for(&net, 4), Err(Error::Config(_))));
        let mut missing = s.clone();
        missing.layers.remove("conv1");
        assert!(matches!(missing.validate_for(&net, 4), Err(Error::Config(_))));
        let mut renamed = s.clone();
        let ls = renamed.layers.remove("conv1").unwrap();
        renamed.layers.insert("conv_zzz".into(), ls);
        assert!(matches!(renamed.validate_for(&net, 4), Err(Error::Config(_))));
        let mut zero = s;
        zero.pool.threads = 0;
        assert!(matches!(zero.validate_for(&net, 4), Err(Error::Config(_))));
    }

    #[test]
    fn from_uniform_rejects_unknown_mode_layers_and_zero_threads() {
        let net = zoo::tinynet();
        let bad_modes =
            ModeAssignment::uniform(ArithMode::Precise).with("nope", ArithMode::Imprecise);
        let r = Schedule::from_uniform(
            &net,
            4,
            &bad_modes,
            Parallelism::Olp,
            true,
            None,
            PoolSettings::default(),
        );
        assert!(matches!(r, Err(Error::Config(_))));
        let r = Schedule::from_uniform(
            &net,
            4,
            &ModeAssignment::uniform(ArithMode::Precise),
            Parallelism::Olp,
            true,
            None,
            PoolSettings { threads: 0, ..Default::default() },
        );
        assert!(matches!(r, Err(Error::Config(_))));
    }

    #[test]
    fn zero_width_artifacts_rejected() {
        // A hand-edited artifact with u = 0 (or threads = 0) must be a
        // typed parse-time rejection, not a divide-by-zero later.
        let mut zero_u = sample();
        zero_u.u = 0;
        let text = zero_u.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(matches!(Schedule::from_json(&parsed), Err(Error::Config(_))));
        assert!(matches!(zero_u.validate_for(&zoo::tinynet(), 0), Err(Error::Config(_))));
    }

    #[test]
    fn absurd_numeric_fields_rejected() {
        // Corrupted-artifact fixtures: `as_usize` happily returns huge
        // integral doubles, so each bound must be enforced explicitly.
        let ok = sample().to_json().to_string();
        let cases = [
            // u far beyond any vector width.
            (r#""u":4"#, r#""u":1125899906842624"#),
            // Allocation-sized pool chunk count.
            (r#""threads":4"#, r#""threads":1125899906842624"#),
            // Tile dims: zero and huge are both unrunnable (serialized
            // key order is alphabetical: th before tm).
            (r#""tiling":{"th":3,"tm":2}"#, r#""tiling":{"th":3,"tm":0}"#),
            (r#""tiling":{"th":3,"tm":2}"#, r#""tiling":{"th":4194304,"tm":2}"#),
            // Core ids outside the 64-bit mask must not silently unpin.
            (r#""cores":[0,2]"#, r#""cores":[0,91]"#),
        ];
        for (from, to) in cases {
            assert!(ok.contains(from), "fixture drifted: {from:?} not in artifact");
            let corrupt = ok.replacen(from, to, 1);
            let parsed = Json::parse(&corrupt).unwrap();
            assert!(
                matches!(Schedule::from_json(&parsed), Err(Error::Config(_))),
                "corruption {to:?} must be a typed rejection"
            );
        }
        // The uncorrupted fixture still parses.
        assert!(Schedule::from_json(&Json::parse(&ok).unwrap()).is_ok());
    }

    #[test]
    fn save_is_atomic_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("capp-sched-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("schedule.json");
        let s = sample();
        s.save(&path).unwrap();
        assert_eq!(Schedule::load(&path).unwrap(), s);
        assert!(!dir.join("schedule.json.tmp").exists(), "tmp sibling left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_layer_entries_rejected() {
        let s = sample();
        let mut text = s.to_json().to_string();
        // Duplicate the first layer entry in the array.
        let start = text.find("{\"layer\"").unwrap();
        let end = text[start..].find('}').unwrap() + start + 1;
        let entry = text[start..end].to_string();
        text.insert_str(start, &format!("{entry},"));
        let parsed = Json::parse(&text).unwrap();
        assert!(matches!(Schedule::from_json(&parsed), Err(Error::Config(_))));
    }

    #[test]
    fn mode_assignment_view_matches_layers() {
        let s = sample();
        let ma = s.mode_assignment();
        assert_eq!(ma.mode_of("conv2"), ArithMode::Imprecise);
        assert_eq!(ma.mode_of("conv1"), ArithMode::Precise);
    }
}
