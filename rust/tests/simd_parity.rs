//! SIMD kernel-selection parity: the f32 vector row kernels must be
//! **bitwise identical** to their scalar fallback — across vector
//! widths `u` in {1, 2, 3, 4, 8} (3 exercises the generic scalar path,
//! 4/8 the SSE/AVX lanes) and thread counts {1, 2, 4}.
//!
//! CI runs this suite twice: once with `CAPPUCCINO_SIMD=0` (the
//! [`cappuccino::engine::simd`] runtime gate forces the scalar lane
//! backends) and once with `-Ctarget-cpu=native` (real intrinsics
//! where the host has them). The assertions compare three in-process
//! kernel selections — SIMD-selected packed, forced-scalar packed
//! (`vector_width = 1`), and the unpacked row-walk oracle — so a pass
//! under both CI configs proves intrinsics == fallback == oracle
//! bitwise.
//!
//! The quantized int8 path has no bitwise f32 oracle; here it gets the
//! determinism half of its contract (batch == singles, thread count
//! invisible — integer accumulation is exact) plus a scale-aware
//! tolerance against the precise plan. The accuracy half lives in
//! `inexact::evaluate_accuracy` (see `src/inexact`).

use cappuccino::engine::{ArithMode, EngineParams, ModeAssignment, PlanBuilder, Schedule};
use cappuccino::model::zoo;
use cappuccino::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];
const WIDTHS: [usize; 5] = [1, 2, 3, 4, 8];

#[test]
fn vector_kernels_bitwise_match_scalar_fallback_across_widths_and_threads() {
    let net = zoo::tinynet();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise);
    for &u in &WIDTHS {
        let params = EngineParams::random(&net, 100 + u as u64, u).unwrap();
        let x = Rng::new(7 + u as u64).normal_vec(net.input.elements());
        let mut oracle: Option<Vec<f32>> = None;
        for &threads in &THREADS {
            // Packed + SIMD-selected (Imprecise unlocks the vector rows).
            let mut vec_plan = PlanBuilder::new(&net, &params)
                .modes(&modes)
                .threads(threads)
                .build()
                .unwrap();
            let got = vec_plan.run(&x).unwrap();
            // Forced scalar rows via the per-layer schedule knob.
            let mut s = vec_plan.schedule().clone();
            for ls in s.layers.values_mut() {
                ls.vector_width = 1;
            }
            let mut scalar_plan =
                PlanBuilder::new(&net, &params).schedule(s).build().unwrap();
            assert_eq!(
                scalar_plan.run(&x).unwrap(),
                got,
                "u={u} threads={threads}: vector_width=1 diverged"
            );
            // Unpacked row walk: the pre-packing scalar oracle.
            let mut unpacked = PlanBuilder::new(&net, &params)
                .modes(&modes)
                .threads(threads)
                .packing(false)
                .build()
                .unwrap();
            assert_eq!(
                unpacked.run(&x).unwrap(),
                got,
                "u={u} threads={threads}: unpacked oracle diverged"
            );
            // Thread count must be bitwise invisible too.
            match &oracle {
                None => oracle = Some(got),
                Some(want) => {
                    assert_eq!(&got, want, "u={u} threads={threads} vs threads=1")
                }
            }
        }
    }
}

#[test]
fn precise_mode_ignores_vector_width() {
    // Precise always runs scalar — vector_width is consulted only by
    // vectorised modes, so every setting is bitwise identical.
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 31, 4).unwrap();
    let x = Rng::new(32).normal_vec(net.input.elements());
    let mut base = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
    let want = base.run(&x).unwrap();
    for vw in [1usize, 4, 8] {
        let mut s = base.schedule().clone();
        for ls in s.layers.values_mut() {
            ls.vector_width = vw;
        }
        let mut plan = PlanBuilder::new(&net, &params).schedule(s).build().unwrap();
        assert_eq!(plan.run(&x).unwrap(), want, "vector_width={vw} under precise");
    }
}

#[test]
fn quant_i8_is_deterministic_and_tracks_f32_across_widths_and_threads() {
    let net = zoo::tinynet();
    for &u in &[1usize, 2, 4, 8] {
        let params = EngineParams::random(&net, 200 + u as u64, u).unwrap();
        let inputs: Vec<Vec<f32>> = (0..3)
            .map(|i| Rng::new(40 + i + u as u64).normal_vec(net.input.elements()))
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let mut precise = PlanBuilder::new(&net, &params).build().unwrap();
        let mut quant_sched = Schedule::default_for(&net, u);
        for ls in quant_sched.layers.values_mut() {
            ls.mode = ArithMode::QuantI8;
        }
        let mut thread_oracle: Option<Vec<Vec<f32>>> = None;
        for &threads in &THREADS {
            let mut s = quant_sched.clone();
            s.pool.threads = threads;
            let mut plan =
                PlanBuilder::new(&net, &params).schedule(s).batch(3).build().unwrap();
            let rows = plan.run_batch(&refs).unwrap();
            for (i, row) in rows.iter().enumerate() {
                // Per-image quantization: batches == singles, bitwise.
                assert_eq!(
                    row,
                    &plan.run(&inputs[i]).unwrap(),
                    "u={u} threads={threads} row {i}: batch != single"
                );
                // Scale-aware tolerance against the f32 plan (int8 is
                // approximate by design, never bitwise).
                let want = precise.run(&inputs[i]).unwrap();
                let scale = want.iter().fold(1.0f32, |m, &v| m.max(v.abs()));
                for (x, y) in want.iter().zip(row) {
                    assert!(
                        y.is_finite() && (x - y).abs() < 0.15 * scale,
                        "u={u} threads={threads}: {x} vs {y} (scale {scale})"
                    );
                }
            }
            // Integer accumulation is exact, so the thread count (and
            // macro-item chunking) is bitwise invisible.
            match &thread_oracle {
                None => thread_oracle = Some(rows),
                Some(want) => {
                    assert_eq!(&rows, want, "u={u} threads={threads} vs threads=1")
                }
            }
        }
    }
}
