//! Thread workload allocation (paper section IV.A) and the persistent,
//! **topology-aware** worker pool the compiled execution plans run on.
//!
//! The three sources of parallelism in a convolutional layer:
//!
//! * **OLP** (output-level) — each thread computes whole output pixels
//!   (the full 3-D convolution for its pixels). No reduction, maximal
//!   kernel reuse. Cappuccino's primary policy.
//! * **FLP** (filter-bank-level) — each thread convolves *one entire
//!   kernel* (one input plane against one 2-D kernel); a reduction sums
//!   partial planes over input channels.
//! * **KLP** (kernel-level) — threads split the multiplications *within*
//!   a kernel window (here: by input-channel slices); a reduction
//!   accumulates partial products.
//!
//! KLP/FLP exist to measure exactly what the paper argues against:
//! reduction/synchronisation overhead and poor data reuse. The ablation
//! bench regenerates that comparison.
//!
//! ## Execution substrate
//!
//! [`parallel_for`] / [`parallel_reduce`] run on a process-wide
//! [`ThreadPool`]: long-lived workers blocked on work deques, so the
//! per-layer cost of going parallel is one enqueue + one wakeup instead
//! of an OS thread spawn. The original scoped-spawn implementations are
//! kept as [`parallel_for_spawn`] / [`parallel_reduce_spawn`] purely as
//! the ablation reference (what every conv layer used to pay).
//!
//! ## Cluster model (big.LITTLE / multi-socket)
//!
//! The pool is shaped by a [`Topology`] probe
//! ([`crate::engine::topology`]): cores group into **clusters** (by
//! sysfs `cpu_capacity`, falling back to package ids, falling back to
//! one uniform cluster), each cluster owns its **own work deque**, and
//! each worker is pinned to a core of its cluster
//! (`sched_setaffinity`; a silent no-op off Linux, on failure, or when
//! the probe fell back to uniform — pinning is a placement hint, never
//! a correctness dependency). Workers drain their own cluster's deque
//! first and **steal from other clusters only when idle**, so work
//! placed on a cluster stays on the cores whose caches hold its data
//! unless those cores cannot keep up.
//!
//! ## Batch-tagged scopes (no head-of-line blocking)
//!
//! Every [`ThreadPool::scope`] call tags its jobs with a unique batch
//! id. Workers run anything; but the *submitting* thread, which helps
//! while it waits, only ever executes **its own batch's** jobs and
//! stops as soon as its completion latch clears. (The previous pool let
//! the helper pop *any* queued job, so a small scope could get stuck
//! executing an unrelated batch's long-running work — unbounded latency
//! for small layers. The `affinity` integration test pins this down.)
//!
//! ## Cost-weighted placement
//!
//! [`chunk_ranges_weighted`] splits an item space into per-cluster
//! spans proportional to throughput weights
//! ([`ThreadPool::cluster_weights`]: capacity-weighted core counts for
//! compute-bound work, plain core counts for memory-bound work), and
//! [`ThreadPool::scope_placed`] routes each task to its cluster's
//! deque. The packed conv macro-kernel feeds this with its per-layer
//! [`crate::engine::conv::ConvTiling`] working-set cost (see
//! [`crate::engine::PlanBuilder::affinity`]). Placement moves work
//! between cores — it never changes what is computed, so every parity
//! suite stays bitwise green with affinity on or off.
//!
//! Batch-first plans stretch each region instead of adding regions: a
//! `run_batch` of `B` images submits **one** task batch per conv layer
//! spanning the whole `B x alpha` item space, so the enqueue + wakeup
//! cost above is paid once per layer per *batch*, not per image.
//!
//! ## Panic containment
//!
//! Every queued task runs under `catch_unwind`; a panicking task marks
//! its scope's latch instead of unwinding through a worker (workers
//! never die) and [`ThreadPool::scope`] / [`ThreadPool::scope_placed`]
//! **return** the panic status instead of re-panicking in the
//! submitting thread. All queue/latch locks ignore poisoning (no
//! guarded state is ever mid-update at a panic boundary — the
//! catch_unwind wrapper is panic-free), so the pool stays fully usable
//! after a contained fault. The `parallel_*` helpers record a contained
//! panic in a submitting-thread-local flag
//! ([`take_scope_panic`](self::take_scope_panic)) that the plan
//! executor converts into a typed
//! [`Error::TaskPanicked`](crate::Error::TaskPanicked) per step; the
//! non-fault path is untouched, so every bitwise parity oracle is
//! unaffected.
//!
//! ## Pool size vs `ExecConfig::threads`
//!
//! [`global_pool`] is sized **once**, at first use, to the probed
//! topology (one worker per allowed core; `CAPPUCCINO_PIN=0` disables
//! pinning). Plans do not resize it: a plan compiled with
//! `ExecConfig { threads: n, .. }` limits itself by submitting at most
//! `n` chunks per parallel region. Tests may run a region on a private
//! pool via [`with_pool`] (the pinned-vs-unpinned ablation and parity
//! tests do).

use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::engine::topology::{self, Topology};

/// Thread workload allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Parallelism {
    Olp,
    Flp,
    Klp,
}

impl Parallelism {
    pub const ALL: [Parallelism; 3] = [Parallelism::Olp, Parallelism::Flp, Parallelism::Klp];

    pub fn as_str(&self) -> &'static str {
        match self {
            Parallelism::Olp => "olp",
            Parallelism::Flp => "flp",
            Parallelism::Klp => "klp",
        }
    }
}

impl FromStr for Parallelism {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "olp" => Ok(Parallelism::Olp),
            "flp" => Ok(Parallelism::Flp),
            "klp" => Ok(Parallelism::Klp),
            other => Err(crate::Error::Invalid(format!("unknown parallelism {other:?}"))),
        }
    }
}

impl std::fmt::Display for Parallelism {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Split `n_items` into at most `n_chunks` contiguous ranges.
pub fn chunk_ranges(n_items: usize, n_chunks: usize) -> Vec<Range<usize>> {
    if n_items == 0 || n_chunks == 0 {
        return Vec::new();
    }
    let n_chunks = n_chunks.min(n_items);
    let base = n_items / n_chunks;
    let extra = n_items % n_chunks;
    let mut out = Vec::with_capacity(n_chunks);
    let mut start = 0;
    for i in 0..n_chunks {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split `n_items` into exactly `weights.len()` contiguous spans whose
/// lengths apportion the items by weight (largest-remainder rounding;
/// ties go to the lower index). Non-finite and non-positive weights
/// count as zero; all-zero weights degrade to an equal split. Spans may
/// be empty — unlike [`chunk_ranges`], the output always has one span
/// per weight, in order, covering `0..n_items` exactly.
///
/// This is the cost-weighted placement primitive: weights are
/// per-cluster throughput estimates and the spans are the macro items
/// each cluster is asked to compute.
pub fn chunk_ranges_weighted(n_items: usize, weights: &[f64]) -> Vec<Range<usize>> {
    let k = weights.len();
    if k == 0 {
        return Vec::new();
    }
    let sane: Vec<f64> = weights
        .iter()
        .map(|w| if w.is_finite() && *w > 0.0 { *w } else { 0.0 })
        .collect();
    let total: f64 = sane.iter().sum();
    if total <= 0.0 {
        return chunk_ranges_weighted(n_items, &vec![1.0; k]);
    }
    let mut counts = vec![0usize; k];
    let mut fracs: Vec<(f64, usize)> = Vec::with_capacity(k);
    let mut assigned = 0usize;
    for (i, w) in sane.iter().enumerate() {
        let ideal = n_items as f64 * w / total;
        let floor = ideal.floor() as usize;
        counts[i] = floor;
        assigned += floor;
        fracs.push((ideal - floor as f64, i));
    }
    fracs.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });
    let mut rem = n_items.saturating_sub(assigned);
    let mut idx = 0usize;
    while rem > 0 {
        let (_, i) = fracs[idx % k];
        if sane[i] > 0.0 {
            counts[i] += 1;
            rem -= 1;
        }
        idx += 1;
    }
    let mut out = Vec::with_capacity(k);
    let mut start = 0usize;
    for c in counts {
        out.push(start..start + c);
        start += c;
    }
    debug_assert_eq!(start, n_items, "chunk_ranges_weighted: items not covered");
    out
}

// ---------------------------------------------------------------------------
// Persistent topology-aware thread pool
// ---------------------------------------------------------------------------

/// Total OS threads ever spawned by pools in this process — the plan
/// parity tests assert this stays flat across inferences (zero per-layer
/// spawns once the pool is warm).
static THREADS_SPAWNED: AtomicUsize = AtomicUsize::new(0);

/// OS threads spawned by [`ThreadPool`]s since process start.
pub fn pool_threads_spawned() -> usize {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// Monotone scope-batch ids: the tag that scopes the help loop to its
/// own work (process-wide so ids stay unique across pools).
static NEXT_BATCH: AtomicU64 = AtomicU64::new(1);

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a mutex ignoring poisoning. Pool tasks run under
/// `catch_unwind` and the wrapper itself is panic-free, so guarded
/// queue/latch state is never left mid-update; honoring poison here
/// would turn one contained fault elsewhere in the process into a
/// permanent pool outage.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

thread_local! {
    /// Set on the submitting thread when a pool scope it ran contained
    /// a task panic; drained per plan step via [`take_scope_panic`].
    static SCOPE_PANICKED: Cell<bool> = const { Cell::new(false) };
}

/// Drain this thread's contained-panic flag: `true` iff some pool
/// scope submitted from this thread since the previous call contained
/// a task panic. The plan executor calls this after every step to
/// surface contained panics as typed errors.
pub(crate) fn take_scope_panic() -> bool {
    SCOPE_PANICKED.with(|c| c.replace(false))
}

/// One queued job, tagged with the scope batch it belongs to.
struct Tagged {
    batch: u64,
    job: Job,
}

/// One cluster's work deque + wakeup signal.
struct ClusterQueue {
    queue: Mutex<VecDeque<Tagged>>,
    cv: Condvar,
}

struct PoolShared {
    clusters: Vec<ClusterQueue>,
    shutdown: AtomicBool,
}

/// Public description of one pool cluster (for placement decisions and
/// diagnostics).
#[derive(Debug, Clone)]
pub struct ClusterInfo {
    /// CPU ids the cluster's workers are pinned to (empty = unpinned).
    pub cpus: Vec<usize>,
    /// Relative per-core compute capacity (sysfs `cpu_capacity` scale).
    pub capacity: u32,
    /// Worker threads serving this cluster's deque.
    pub workers: usize,
}

/// Completion latch for one [`ThreadPool::scope`] call.
struct Latch {
    state: Mutex<(usize, bool)>, // (tasks remaining, any panicked)
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new((n, false)), cv: Condvar::new() }
    }

    fn done(&self, ok: bool) {
        let mut st = lock_ignore_poison(&self.state);
        st.0 -= 1;
        if !ok {
            st.1 = true;
        }
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        lock_ignore_poison(&self.state).0 == 0
    }

    /// Block until every task in the scope has completed. Returns
    /// whether any task panicked — the panic itself was already
    /// contained at the task boundary, never re-raised here.
    fn wait(&self) -> bool {
        let mut st = lock_ignore_poison(&self.state);
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        st.1
    }
}

/// Long-lived worker pool with one work deque per core cluster: workers
/// drain their own cluster first and steal across clusters only when
/// idle; scoped task batches borrow caller data (the submitting call
/// blocks until every task in the batch has completed, so the borrow is
/// sound) and are batch-tagged so the helping submitter never executes
/// another scope's work.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    clusters: Vec<ClusterInfo>,
}

impl ThreadPool {
    /// Spawn a pool with `size` unpinned workers in a single uniform
    /// cluster (min 1) — the shape private test pools use.
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        Self::build(vec![ClusterInfo {
            cpus: Vec::new(),
            capacity: topology::DEFAULT_CAPACITY,
            workers: size,
        }])
    }

    /// Spawn a pool shaped like `topo`: one worker per core, grouped
    /// into per-cluster deques. With `pin` (and a probed topology) each
    /// worker is pinned to its own core via `sched_setaffinity`;
    /// unprobed topologies and non-Linux hosts never pin (the uniform
    /// fallback contract the constrained-host CI job checks).
    pub fn with_topology(topo: &Topology, pin: bool) -> ThreadPool {
        let pin = pin && topo.probed;
        let mut infos: Vec<ClusterInfo> = topo
            .clusters
            .iter()
            .filter(|c| !c.cpus.is_empty())
            .map(|c| ClusterInfo {
                cpus: if pin { c.cpus.clone() } else { Vec::new() },
                capacity: c.capacity,
                workers: c.cpus.len(),
            })
            .collect();
        if infos.is_empty() {
            infos.push(ClusterInfo {
                cpus: Vec::new(),
                capacity: topology::DEFAULT_CAPACITY,
                workers: 1,
            });
        }
        Self::build(infos)
    }

    fn build(infos: Vec<ClusterInfo>) -> ThreadPool {
        let shared = Arc::new(PoolShared {
            clusters: infos
                .iter()
                .map(|_| ClusterQueue { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() })
                .collect(),
            shutdown: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        for (ci, info) in infos.iter().enumerate() {
            for wi in 0..info.workers {
                let sh = Arc::clone(&shared);
                let cpu = info.cpus.get(wi % info.cpus.len().max(1)).copied();
                THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("capp-pool-{ci}-{wi}"))
                        .spawn(move || {
                            if let Some(cpu) = cpu {
                                let _ = topology::pin_current_thread(&[cpu]);
                            }
                            worker_loop(sh, ci)
                        })
                        .expect("spawn pool worker"),
                );
            }
        }
        ThreadPool { shared, workers, clusters: infos }
    }

    /// Worker count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Per-cluster shape of the pool.
    pub fn clusters(&self) -> &[ClusterInfo] {
        &self.clusters
    }

    /// Per-cluster throughput weights for cost-weighted placement.
    /// Compute-bound work scales with each cluster's capacity-weighted
    /// core count (a LITTLE cluster retires fewer MACs per cycle);
    /// memory-bound work — a working set that overflows the modelled L2
    /// — scales with plain core counts (all clusters share the memory
    /// system).
    pub fn cluster_weights(&self, compute_bound: bool) -> Vec<f64> {
        self.clusters
            .iter()
            .map(|c| {
                if compute_bound {
                    c.workers as f64 * c.capacity as f64
                        / topology::DEFAULT_CAPACITY as f64
                } else {
                    c.workers as f64
                }
            })
            .collect()
    }

    /// Run a batch of borrowed tasks to completion, spreading contiguous
    /// task blocks over clusters in proportion to their worker counts.
    ///
    /// Tasks may borrow caller data (`'a`): the call blocks until every
    /// task has finished, and the caller *helps* by draining **its own
    /// batch's** queued jobs while it waits, so the batch makes progress
    /// even when all workers are busy (and nested `scope` calls cannot
    /// deadlock). The batch tag keeps the helper off other scopes' jobs
    /// — a concurrent scope's long-running tasks can no longer inflate
    /// this call's latency (head-of-line blocking).
    ///
    /// Returns `true` iff every task completed without panicking. A
    /// panicking task is **contained** at the task boundary: the scope
    /// still runs to completion (every sibling executes), the pool and
    /// its locks stay fully usable, and the failure is reported through
    /// the return value and the submitting thread's
    /// [`take_scope_panic`] flag instead of a re-panic.
    pub fn scope<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>) -> bool {
        let n = tasks.len();
        if n == 0 {
            return true;
        }
        let weights: Vec<f64> = self.clusters.iter().map(|c| c.workers as f64).collect();
        let spans = chunk_ranges_weighted(n, &weights);
        let mut hints = vec![0usize; n];
        for (c, span) in spans.iter().enumerate() {
            for h in &mut hints[span.clone()] {
                *h = c;
            }
        }
        self.scope_placed(hints.into_iter().zip(tasks).collect())
    }

    /// [`ThreadPool::scope`] with an explicit target cluster per task
    /// (indices clamped into range by modulo): the cost-weighted
    /// placement entry point. Placement only chooses which cluster's
    /// deque — and therefore which cores' caches — a task lands on;
    /// idle workers may still steal it, and execution order within the
    /// batch is unspecified either way. Same panic-containment contract
    /// (and return value) as [`ThreadPool::scope`].
    pub fn scope_placed<'a>(&self, tasks: Vec<(usize, Box<dyn FnOnce() + Send + 'a>)>) -> bool {
        if tasks.is_empty() {
            return true;
        }
        let batch = NEXT_BATCH.fetch_add(1, Ordering::Relaxed);
        let latch = Arc::new(Latch::new(tasks.len()));
        let n_clusters = self.shared.clusters.len();
        let mut touched = vec![false; n_clusters];
        for (hint, task) in tasks {
            let cluster = if hint < n_clusters { hint } else { hint % n_clusters };
            // SAFETY: `latch.wait()` below blocks this call until every
            // task in the batch has run to completion — workers drain
            // every queue and the helper drains this batch's leftovers,
            // so no tagged job can outlive the scope — hence the `'a`
            // borrows each task captures strictly outlive its
            // execution. The wrapper job cannot panic (the user task —
            // and the fault-injection probe — run under
            // `catch_unwind`), so an unwinding worker or helper never
            // abandons a queued sibling mid-borrow.
            let task: Job =
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(task) };
            let latch_c = Arc::clone(&latch);
            let job: Job = Box::new(move || {
                let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    crate::faults::maybe_panic("pool");
                    task();
                }))
                .is_ok();
                latch_c.done(ok);
            });
            lock_ignore_poison(&self.shared.clusters[cluster].queue)
                .push_back(Tagged { batch, job });
            touched[cluster] = true;
        }
        // Wake the clusters that received work; nudge one worker on each
        // other cluster so an idle stealer gets a chance.
        for (c, cl) in self.shared.clusters.iter().enumerate() {
            if touched[c] {
                cl.cv.notify_all();
            } else {
                cl.cv.notify_one();
            }
        }
        // Help while waiting — own batch only, stopping once the latch
        // clears or no own-batch jobs remain queued.
        loop {
            if latch.is_done() {
                break;
            }
            let mut found: Option<Tagged> = None;
            for cl in &self.shared.clusters {
                let mut q = lock_ignore_poison(&cl.queue);
                if let Some(pos) = q.iter().position(|t| t.batch == batch) {
                    found = q.remove(pos);
                    break;
                }
            }
            match found {
                Some(t) => (t.job)(),
                None => break,
            }
        }
        let panicked = latch.wait();
        if panicked {
            SCOPE_PANICKED.with(|c| c.set(true));
        }
        !panicked
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for cl in &self.shared.clusters {
            // Acquire each queue lock so no worker is between its empty
            // check and its wait when the wakeup lands.
            let _guard = lock_ignore_poison(&cl.queue);
            cl.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(sh: Arc<PoolShared>, me: usize) {
    loop {
        match next_job(&sh, me) {
            Some(t) => (t.job)(),
            None => return,
        }
    }
}

/// Next job for a worker of cluster `me`: own deque first, then — only
/// when idle — steal from the other clusters, then block on the own
/// cluster's condvar until new work or shutdown.
fn next_job(sh: &PoolShared, me: usize) -> Option<Tagged> {
    let n = sh.clusters.len();
    loop {
        if let Some(t) = lock_ignore_poison(&sh.clusters[me].queue).pop_front() {
            return Some(t);
        }
        for k in 1..n {
            let c = (me + k) % n;
            if let Some(t) = lock_ignore_poison(&sh.clusters[c].queue).pop_front() {
                return Some(t);
            }
        }
        let cl = &sh.clusters[me];
        let q = lock_ignore_poison(&cl.queue);
        if !q.is_empty() {
            continue;
        }
        if sh.shutdown.load(Ordering::Acquire) {
            return None;
        }
        // Woken by own-cluster work, a steal nudge, or shutdown; every
        // path rescans from the top.
        let _q = cl.cv.wait(q).unwrap_or_else(|p| p.into_inner());
    }
}

/// The process-wide pool every executor shares. Shaped **once**, on
/// first use, by [`Topology::probe`] — one worker per allowed core,
/// grouped into per-cluster deques and pinned to their cores
/// (`CAPPUCCINO_PIN=0`/`false`/`off` disables pinning; the uniform
/// fallback never pins). Callers limit their own parallelism via the
/// chunk count they submit ([`crate::engine::network::ExecConfig`]'s
/// `threads`), not by resizing the pool.
pub fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let pin = !matches!(
            std::env::var("CAPPUCCINO_PIN").as_deref(),
            Ok("0") | Ok("false") | Ok("off")
        );
        ThreadPool::with_topology(&Topology::probe(), pin)
    })
}

// ---------------------------------------------------------------------------
// Current-pool override (tests + ablations)
// ---------------------------------------------------------------------------

thread_local! {
    static POOL_OVERRIDE: Cell<*const ThreadPool> = Cell::new(std::ptr::null());
}

/// Run `f` with every `parallel_*` helper on this thread dispatching to
/// `pool` instead of the process-wide [`global_pool`]. Scoped to the
/// call (restored on unwind) and to the current thread. This is how the
/// parity tests prove pinned and unpinned pools — and synthetic
/// multi-cluster topologies — execute plans bitwise identically, and
/// how the layout ablation isolates the pinning contribution without
/// re-spawning the global pool.
pub fn with_pool<R>(pool: &ThreadPool, f: impl FnOnce() -> R) -> R {
    struct Restore(*const ThreadPool);
    impl Drop for Restore {
        fn drop(&mut self) {
            POOL_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = POOL_OVERRIDE.with(|c| c.replace(pool as *const ThreadPool));
    let _restore = Restore(prev);
    f()
}

/// Dispatch target for the helpers below: the thread's override if one
/// is active, else the global pool.
fn with_current_pool<R>(f: impl FnOnce(&ThreadPool) -> R) -> R {
    let ptr = POOL_OVERRIDE.with(|c| c.get());
    if ptr.is_null() {
        f(global_pool())
    } else {
        // SAFETY: the pointer is set only by `with_pool`, whose borrow
        // of the pool outlives its dynamic extent on this thread, and
        // which restores the previous value before returning.
        f(unsafe { &*ptr })
    }
}

// ---------------------------------------------------------------------------
// Data-parallel helpers (pool-backed)
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, range)` over `n_items` split into at most
/// `n_threads` chunks on the persistent pool ([`global_pool`] unless a
/// [`with_pool`] override is active). With `n_threads <= 1` (or a
/// single chunk) runs inline with zero overhead.
pub fn parallel_for<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r);
        }
        return;
    }
    let f = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .enumerate()
        .map(|(i, r)| Box::new(move || f(i, r)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    with_current_pool(|pool| pool.scope(tasks));
}

/// Split `items` into at most `n_threads` contiguous ranges, hand each
/// range its disjoint `range.len() * row_len` slice of `out`, and run
/// `f(range, slice)` on the persistent pool in **one** parallel region
/// (inline when a single chunk results). This is the writer side of the
/// batched conv/dense kernels: every work item owns one contiguous
/// `row_len` output row, so disjoint chunk slices need zero
/// synchronisation.
pub(crate) fn parallel_for_slices<F>(
    items: usize,
    n_threads: usize,
    row_len: usize,
    out: &mut [f32],
    f: &F,
) where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            let len = r.len() * row_len;
            f(r, &mut out[..len]);
        }
        return;
    }
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.len() * row_len);
        slices.push(head);
        rest = tail;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .zip(slices)
        .map(|(range, slice)| {
            Box::new(move || f(range, slice)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    with_current_pool(|pool| pool.scope(tasks));
}

/// Macro-item variant of [`parallel_for_slices`] for the tiled conv
/// core: items may own output slices of *varying* length, and every
/// chunk is paired with its own per-thread scratch row.
///
/// `offset_of(i)` maps item `i` to the element offset where its output
/// region starts (monotone non-decreasing, `offset_of(0) == 0`,
/// `offset_of(items)` = total region length). Chunks are contiguous
/// item ranges, so **chunk boundaries always fall on macro-item
/// boundaries** — a tile is never split across threads, and each chunk's
/// output slice is disjoint (zero write synchronisation, as in the
/// uniform-row case). `scratch` must hold at least one row per chunk
/// (chunk count <= `n_threads`); rows may be empty when the kernel
/// needs none (the `u = 4` register path).
pub(crate) fn parallel_for_macro_slices<O, F>(
    items: usize,
    n_threads: usize,
    out: &mut [f32],
    offset_of: &O,
    scratch: &mut [Vec<f32>],
    f: &F,
) where
    O: Fn(usize) -> usize,
    F: Fn(Range<usize>, &mut [f32], &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(items, n_threads.max(1));
    if ranges.is_empty() {
        return;
    }
    assert!(
        scratch.len() >= ranges.len(),
        "parallel_for_macro_slices: {} scratch rows for {} chunks",
        scratch.len(),
        ranges.len()
    );
    if ranges.len() == 1 {
        let r = ranges.into_iter().next().unwrap();
        let (lo, hi) = (offset_of(r.start), offset_of(r.end));
        f(r, &mut out[lo..hi], scratch[0].as_mut_slice());
        return;
    }
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(ranges.len());
    let mut rest = out;
    let mut consumed = 0usize;
    for r in &ranges {
        let end = offset_of(r.end);
        let (head, tail) = rest.split_at_mut(end - consumed);
        slices.push(head);
        rest = tail;
        consumed = end;
    }
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
        .into_iter()
        .zip(slices)
        .zip(scratch.iter_mut())
        .map(|((range, slice), sc)| {
            let sc: &mut [f32] = sc.as_mut_slice();
            Box::new(move || f(range, slice, sc)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    with_current_pool(|pool| pool.scope(tasks));
}

/// Give every cluster with a non-empty span one chunk slot, then
/// apportion the remaining `slots` by weight. `None` when the pool has
/// more working clusters than slots (the caller falls back to plain
/// chunking).
fn distribute_slots(
    slots: usize,
    weights: &[f64],
    spans: &[Range<usize>],
) -> Option<Vec<usize>> {
    let live: Vec<usize> = (0..spans.len()).filter(|&i| !spans[i].is_empty()).collect();
    if live.is_empty() || live.len() > slots {
        return None;
    }
    let mut out = vec![0usize; spans.len()];
    for &i in &live {
        out[i] = 1;
    }
    let extra = slots - live.len();
    if extra > 0 {
        let w: Vec<f64> = (0..spans.len())
            .map(|i| if spans[i].is_empty() { 0.0 } else { weights[i].max(0.0) })
            .collect();
        for (i, r) in chunk_ranges_weighted(extra, &w).into_iter().enumerate() {
            out[i] += r.len();
        }
    }
    for (i, s) in spans.iter().enumerate() {
        out[i] = out[i].min(s.len());
    }
    Some(out)
}

/// Cost-weighted placed variant of [`parallel_for_macro_slices`]: the
/// macro-item space is first split into per-cluster spans by the
/// current pool's throughput weights
/// ([`ThreadPool::cluster_weights`]`(compute_bound)`), each span is
/// chunked for its cluster's share of the `n_threads` budget, and every
/// chunk is submitted to its cluster's deque
/// ([`ThreadPool::scope_placed`]). Single-cluster pools — and degenerate
/// shapes (more clusters than thread slots, fewer chunks than 2) — fall
/// back to the plain helper. Chunk boundaries still always fall on
/// macro-item boundaries and every item is computed exactly once by one
/// thread, so output is **bitwise identical** to the unplaced dispatch.
pub(crate) fn parallel_for_macro_slices_placed<O, F>(
    items: usize,
    n_threads: usize,
    compute_bound: bool,
    out: &mut [f32],
    offset_of: &O,
    scratch: &mut [Vec<f32>],
    f: &F,
) where
    O: Fn(usize) -> usize,
    F: Fn(Range<usize>, &mut [f32], &mut [f32]) + Sync,
{
    with_current_pool(|pool| {
        let n_threads = n_threads.max(1);
        if pool.clusters().len() <= 1 || n_threads <= 1 || items <= 1 {
            return parallel_for_macro_slices(items, n_threads, out, offset_of, scratch, f);
        }
        let weights = pool.cluster_weights(compute_bound);
        let spans = chunk_ranges_weighted(items, &weights);
        let Some(slots) = distribute_slots(n_threads, &weights, &spans) else {
            return parallel_for_macro_slices(items, n_threads, out, offset_of, scratch, f);
        };
        let mut chunks: Vec<(usize, Range<usize>)> = Vec::new();
        for (c, span) in spans.iter().enumerate() {
            if span.is_empty() || slots[c] == 0 {
                continue;
            }
            for r in chunk_ranges(span.len(), slots[c]) {
                chunks.push((c, span.start + r.start..span.start + r.end));
            }
        }
        if chunks.len() <= 1 || chunks.len() > scratch.len() {
            return parallel_for_macro_slices(items, n_threads, out, offset_of, scratch, f);
        }
        // Spans are ascending and contiguous from 0, so the chunk list
        // walks the output region front to back — same disjoint
        // slicing as the plain helper.
        let mut slices: Vec<&mut [f32]> = Vec::with_capacity(chunks.len());
        let mut rest = out;
        let mut consumed = 0usize;
        for (_, r) in &chunks {
            let end = offset_of(r.end);
            let (head, tail) = rest.split_at_mut(end - consumed);
            slices.push(head);
            rest = tail;
            consumed = end;
        }
        let tasks: Vec<(usize, Box<dyn FnOnce() + Send + '_>)> = chunks
            .into_iter()
            .zip(slices)
            .zip(scratch.iter_mut())
            .map(|(((cluster, range), slice), sc)| {
                let sc: &mut [f32] = sc.as_mut_slice();
                (
                    cluster,
                    Box::new(move || f(range, slice, sc)) as Box<dyn FnOnce() + Send + '_>,
                )
            })
            .collect();
        pool.scope_placed(tasks);
    })
}

/// Like [`parallel_for`] but each chunk owns a scratch accumulation
/// buffer of `buf_len` zeros; after the parallel phase the buffers are
/// reduced (element-wise sum) into a single vector. This is the
/// reduction + inter-thread data-transfer overhead KLP/FLP pay.
///
/// Reductions are **never** cost-weight placed: the sequential sum
/// below depends on the chunk boundaries, so placement here would
/// change numerics — exactly what the affinity design forbids.
pub fn parallel_reduce<F>(n_items: usize, n_threads: usize, buf_len: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let n_chunks = chunk_ranges(n_items, n_threads.max(1)).len().max(1);
    let mut bufs: Vec<Vec<f32>> = (0..n_chunks).map(|_| vec![0.0f32; buf_len]).collect();
    parallel_reduce_with(n_items, n_threads, buf_len, &mut bufs, &f);
    bufs.swap_remove(0)
}

/// Arena-friendly reduction: run the KLP/FLP accumulation over
/// preallocated per-thread buffers (each at least `buf_len` long) and
/// leave the reduced result in `bufs[0][..buf_len]`. The compiled plan
/// executor reuses one set of buffers across every layer and inference.
pub fn parallel_reduce_with<F>(
    n_items: usize,
    n_threads: usize,
    buf_len: usize,
    bufs: &mut [Vec<f32>],
    f: &F,
) where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    let n = ranges.len();
    assert!(
        bufs.len() >= n.max(1),
        "parallel_reduce_with: {} buffers for {} chunks",
        bufs.len(),
        n
    );
    for buf in bufs.iter_mut().take(n.max(1)) {
        assert!(buf.len() >= buf_len, "parallel_reduce_with: buffer too small");
        buf[..buf_len].fill(0.0);
    }
    if n <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r, &mut bufs[0][..buf_len]);
        }
        return;
    }
    {
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = ranges
            .into_iter()
            .enumerate()
            .zip(bufs.iter_mut())
            .map(|((i, r), buf)| {
                let buf = &mut buf[..buf_len];
                Box::new(move || f(i, r, buf)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        with_current_pool(|pool| pool.scope(tasks));
    }
    // Sequential reduction — deliberately the simple strategy a
    // RenderScript reduction kernel would lower to.
    let (first, rest) = bufs.split_at_mut(1);
    let out = &mut first[0][..buf_len];
    for buf in rest.iter().take(n - 1) {
        for (o, v) in out.iter_mut().zip(&buf[..buf_len]) {
            *o += *v;
        }
    }
}

// ---------------------------------------------------------------------------
// Scoped-spawn ablation reference (the pre-pool execution substrate)
// ---------------------------------------------------------------------------

/// Ablation reference: the original scoped-spawn `parallel_for` — one
/// fresh OS thread per chunk per call, exactly what every conv layer
/// paid before the persistent pool.
pub fn parallel_for_spawn<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        if let Some(r) = ranges.into_iter().next() {
            f(0, r);
        }
        return;
    }
    std::thread::scope(|scope| {
        for (i, r) in ranges.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || f(i, r));
        }
    });
}

/// Ablation reference: the original scoped-spawn `parallel_reduce`.
pub fn parallel_reduce_spawn<F>(n_items: usize, n_threads: usize, buf_len: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, Range<usize>, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_items, n_threads.max(1));
    if ranges.len() <= 1 {
        let mut buf = vec![0.0f32; buf_len];
        if let Some(r) = ranges.into_iter().next() {
            f(0, r, &mut buf);
        }
        return buf;
    }
    let n = ranges.len();
    let mut bufs: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0f32; buf_len]).collect();
    std::thread::scope(|scope| {
        for ((i, r), buf) in ranges.into_iter().enumerate().zip(bufs.iter_mut()) {
            let f = &f;
            scope.spawn(move || f(i, r, buf));
        }
    });
    let mut out = bufs.swap_remove(0);
    for buf in &bufs {
        for (o, v) in out.iter_mut().zip(buf) {
            *o += *v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_ranges_cover_exactly() {
        for &(n, c) in &[(10, 3), (3, 10), (0, 4), (7, 7), (100, 1)] {
            let ranges = chunk_ranges(n, c);
            let total: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(total, n);
            let mut expect = 0;
            for r in &ranges {
                assert_eq!(r.start, expect);
                assert!(!r.is_empty());
                expect = r.end;
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        let ranges = chunk_ranges(10, 3);
        let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
    }

    #[test]
    fn weighted_chunks_cover_and_apportion() {
        // Exact coverage, one span per weight, ascending.
        for &(n, ref w) in &[
            (12usize, vec![3.0, 1.0]),
            (10, vec![1.0, 1.0, 1.0]),
            (1, vec![0.5, 0.5]),
            (0, vec![1.0, 2.0]),
            (7, vec![0.0, 1.0]),
            (9, vec![f64::NAN, 1.0, -3.0]),
        ] {
            let spans = chunk_ranges_weighted(n, w);
            assert_eq!(spans.len(), w.len());
            let mut expect = 0usize;
            for s in &spans {
                assert_eq!(s.start, expect);
                expect = s.end;
            }
            assert_eq!(expect, n, "weights {w:?}");
        }
        // 3:1 weights on 12 items: exactly 9 + 3.
        let spans = chunk_ranges_weighted(12, &[3.0, 1.0]);
        assert_eq!((spans[0].len(), spans[1].len()), (9, 3));
        // Zero-weight clusters get nothing.
        let spans = chunk_ranges_weighted(7, &[0.0, 1.0]);
        assert_eq!((spans[0].len(), spans[1].len()), (0, 7));
        // All-garbage weights degrade to an equal split.
        let spans = chunk_ranges_weighted(8, &[f64::NAN, -1.0]);
        assert_eq!((spans[0].len(), spans[1].len()), (4, 4));
        assert!(chunk_ranges_weighted(5, &[]).is_empty());
    }

    #[test]
    fn parallel_for_visits_every_item() {
        let visited = AtomicUsize::new(0);
        parallel_for(1000, 4, |_, r| {
            visited.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(visited.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_for_single_thread_inline() {
        let visited = AtomicUsize::new(0);
        parallel_for(10, 1, |i, r| {
            assert_eq!(i, 0);
            visited.fetch_add(r.len(), Ordering::Relaxed);
        });
        assert_eq!(visited.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn parallel_reduce_sums_buffers() {
        // Each of 8 items adds 1.0 at its index; reduction must total 1
        // per slot regardless of thread count.
        for threads in [1, 2, 4, 8] {
            let out = parallel_reduce(8, threads, 8, |_, range, buf| {
                for i in range {
                    buf[i] += 1.0;
                }
            });
            assert_eq!(out, vec![1.0; 8], "threads={threads}");
        }
    }

    #[test]
    fn spawn_reference_matches_pool() {
        let pool_sum = AtomicUsize::new(0);
        let spawn_sum = AtomicUsize::new(0);
        parallel_for(100, 4, |_, r| {
            pool_sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
        });
        parallel_for_spawn(100, 4, |_, r| {
            spawn_sum.fetch_add(r.sum::<usize>(), Ordering::Relaxed);
        });
        assert_eq!(pool_sum.load(Ordering::Relaxed), spawn_sum.load(Ordering::Relaxed));
        let a = parallel_reduce(16, 4, 16, |_, range, buf| {
            for i in range {
                buf[i] += i as f32;
            }
        });
        let b = parallel_reduce_spawn(16, 4, 16, |_, range, buf| {
            for i in range {
                buf[i] += i as f32;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn pool_reused_across_calls_and_private_scope() {
        // One test on purpose: THREADS_SPAWNED is process-global and
        // libtest runs tests concurrently, so the private-pool check
        // must not race the flat-counter assertion below. (Pool tests
        // that spawn more private pools live in the separate `affinity`
        // test binary for the same reason.)
        let pool = ThreadPool::new(2);
        assert_eq!(pool.size(), 2);
        assert_eq!(pool.clusters().len(), 1, "ThreadPool::new is single-cluster");
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert!(pool.scope(tasks), "fault-free scope reports ok");
        assert_eq!(hits.load(Ordering::Relaxed), 16);

        // Contained panic: the scope reports it (no re-panic), every
        // sibling task still runs, the submitting thread's flag is set
        // exactly once, and the same pool keeps executing work.
        let ran = AtomicUsize::new(0);
        let mut faulty: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("injected test panic"))];
        for _ in 0..7 {
            faulty.push(Box::new(|| {
                ran.fetch_add(1, Ordering::Relaxed);
            }));
        }
        assert!(!pool.scope(faulty), "panicking scope must report the fault");
        assert!(take_scope_panic(), "submitting thread records the contained panic");
        assert!(!take_scope_panic(), "the flag drains on read");
        assert_eq!(ran.load(Ordering::Relaxed), 7, "siblings ran despite the panic");
        let after = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
            .map(|_| {
                Box::new(|| {
                    after.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert!(pool.scope(tasks), "pool fully usable after a contained panic");
        assert_eq!(after.load(Ordering::Relaxed), 16);
        drop(pool);

        // Warm the global pool, then check no further threads are
        // spawned no matter how many parallel sections run.
        parallel_for(64, 8, |_, _| {});
        let warm = pool_threads_spawned();
        for _ in 0..32 {
            parallel_for(64, 8, |_, _| {});
        }
        assert_eq!(pool_threads_spawned(), warm, "pool spawned threads per call");
    }

    #[test]
    fn macro_slices_cover_varying_items_on_boundaries() {
        // Five macro items with different output lengths; every thread
        // count must cover each item exactly once, never splitting one.
        let lens = [3usize, 1, 4, 2, 5];
        let mut offsets = vec![0usize];
        for &l in &lens {
            offsets.push(offsets.last().unwrap() + l);
        }
        let total = *offsets.last().unwrap();
        let mut want = Vec::new();
        for (i, &l) in lens.iter().enumerate() {
            for _ in 0..l {
                want.push(i as f32 + 1.0);
            }
        }
        for threads in [1usize, 2, 4, 8] {
            let mut out = vec![0.0f32; total];
            let mut scratch: Vec<Vec<f32>> = (0..threads).map(|_| vec![0.0f32; 1]).collect();
            parallel_for_macro_slices(
                lens.len(),
                threads,
                &mut out,
                &|i| offsets[i],
                &mut scratch,
                &|range: Range<usize>, slice: &mut [f32], sc: &mut [f32]| {
                    sc[0] += 1.0;
                    let mut off = 0;
                    for item in range {
                        for v in &mut slice[off..off + lens[item]] {
                            *v = item as f32 + 1.0;
                        }
                        off += lens[item];
                    }
                },
            );
            assert_eq!(out, want, "threads={threads}");
            let used: f32 = scratch.iter().map(|s| s[0]).sum();
            assert!(used >= 1.0, "threads={threads}: no chunk ran");
        }
    }

    #[test]
    fn reduce_with_reuses_buffers() {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![7.0f32; 8]).collect();
        for _ in 0..3 {
            parallel_reduce_with(8, 4, 8, &mut bufs, &|_, range, buf: &mut [f32]| {
                for i in range {
                    buf[i] += 1.0;
                }
            });
            assert_eq!(&bufs[0][..8], &[1.0f32; 8][..], "stale partials leaked");
        }
    }

    #[test]
    fn parallelism_parse() {
        assert_eq!("olp".parse::<Parallelism>().unwrap(), Parallelism::Olp);
        assert!("slp".parse::<Parallelism>().is_err());
    }
}
