//! Measurement substrates: latency histograms, throughput meters, and
//! the paper's trimmed-mean protocol.
//!
//! Section V.A: "all experiments have been repeated 100 times, the
//! minimum and maximum observations are omitted, and the average of the
//! remaining 98 observations are reported" — [`trimmed_mean`] implements
//! exactly that protocol and every bench reports through it.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// The paper's measurement protocol: drop min and max, average the rest.
pub fn trimmed_mean(samples: &[f64]) -> f64 {
    match samples.len() {
        0 => 0.0,
        1 => samples[0],
        2 => (samples[0] + samples[1]) / 2.0,
        n => {
            let sum: f64 = samples.iter().sum();
            let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (sum - min - max) / (n - 2) as f64
        }
    }
}

/// Log-bucketed latency histogram (1µs … ~17min, 5% resolution).
#[derive(Debug)]
pub struct LatencyHistogram {
    /// Bucket i covers [GROWTH^i, GROWTH^(i+1)) microseconds.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const GROWTH: f64 = 1.05;
const N_BUCKETS: usize = 420; // 1.05^420 ≈ 8e8 µs ≈ 13 min

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        let idx = us.ln() / GROWTH.ln();
        (idx as usize).min(N_BUCKETS - 1)
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_secs_f64() * 1e6;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Quantile via bucket interpolation (upper bucket edge).
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                let upper_us = GROWTH.powi(i as i32 + 1);
                return Duration::from_secs_f64(upper_us / 1e6);
            }
        }
        self.max()
    }

    /// p50/p95/p99 summary line.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.count(),
            crate::util::fmt_duration(self.mean()),
            crate::util::fmt_duration(self.quantile(0.50)),
            crate::util::fmt_duration(self.quantile(0.95)),
            crate::util::fmt_duration(self.quantile(0.99)),
            crate::util::fmt_duration(self.max()),
        )
    }
}

/// Throughput meter: items completed since construction.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    items: AtomicU64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: Instant::now(), items: AtomicU64::new(0) }
    }

    pub fn add(&self, n: u64) {
        self.items.fetch_add(n, Ordering::Relaxed);
    }

    pub fn items(&self) -> u64 {
        self.items.load(Ordering::Relaxed)
    }

    pub fn per_second(&self) -> f64 {
        let elapsed = self.start.elapsed().as_secs_f64();
        if elapsed <= 0.0 {
            0.0
        } else {
            self.items() as f64 / elapsed
        }
    }
}

/// Heap-allocation accounting for the steady-state inference path.
///
/// The compiled plan executor routes every buffer it allocates through
/// one of these: resident arena bytes are recorded once at
/// plan-compile time, request-path bytes on every inference. The
/// `engine_hotpath` bench reports both so the arena win is a measured
/// number, not an anecdote (zero-ish bytes/inference for a compiled
/// plan vs. the full activation footprint for the legacy executor).
#[derive(Debug, Default)]
pub struct AllocCounter {
    bytes: AtomicU64,
    allocs: AtomicU64,
}

impl AllocCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one allocation of `bytes` bytes.
    pub fn record(&self, bytes: usize) {
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.allocs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.bytes.store(0, Ordering::Relaxed);
        self.allocs.store(0, Ordering::Relaxed);
    }

    /// Mean bytes per inference over `runs` inferences.
    pub fn per_inference(&self, runs: u64) -> f64 {
        if runs == 0 {
            0.0
        } else {
            self.bytes() as f64 / runs as f64
        }
    }
}

impl Clone for AllocCounter {
    fn clone(&self) -> Self {
        AllocCounter {
            bytes: AtomicU64::new(self.bytes()),
            allocs: AtomicU64::new(self.allocs()),
        }
    }
}

/// Serving-side counters (requests, batches, rejections by reason,
/// deadline outcomes).
///
/// `rejected` is always the **total** across the per-reason counters —
/// the front-end bumps the total and exactly one reason on every
/// refusal, so `rejected == rejected_queue_full + rejected_deadline +
/// rejected_unknown_model + rejected_other` holds at any quiescent
/// point.
#[derive(Debug, Default)]
pub struct ServeCounters {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Total refusals (sum of the per-reason counters below).
    pub rejected: AtomicU64,
    /// Bounded-queue backpressure refusals.
    pub rejected_queue_full: AtomicU64,
    /// Admission-control load sheds: predicted queue drain time
    /// exceeded the request's deadline.
    pub rejected_deadline: AtomicU64,
    /// Requests naming a model that is not resident.
    pub rejected_unknown_model: AtomicU64,
    /// Everything else (unknown SLO class, worker gone).
    pub rejected_other: AtomicU64,
    /// Admitted requests whose reply beat their deadline.
    pub deadline_met: AtomicU64,
    /// Admitted requests replied to *after* their deadline (still
    /// replied — admitted work is never silently dropped).
    pub deadline_missed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
}

impl ServeCounters {
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_items.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Per-tenant fault-tolerance counters, fed by the serve supervisor.
///
/// These are the observable surface of the failure model: every
/// contained backend fault, every backend rebuild, every quarantined
/// request, and the total time a tenant spent degraded to its fallback
/// schedule. A chaos run is judged by these numbers (faults > 0,
/// respawns > 0, drops = 0), so they are counted at the supervision
/// points themselves, not reconstructed from logs.
#[derive(Debug, Default)]
pub struct FaultStats {
    /// Backend faults (contained panics or typed errors) the supervisor
    /// absorbed without losing a request.
    pub faults_contained: AtomicU64,
    /// Times the worker rebuilt its backend after a fault.
    pub worker_respawns: AtomicU64,
    /// Requests answered with `Rejected::Fault` after exhausting their
    /// retry budget (poison-pill isolation).
    pub requests_quarantined: AtomicU64,
    /// Total milliseconds spent serving from the fallback schedule.
    pub degraded_ms: AtomicU64,
}

impl FaultStats {
    /// Did any fault-path counter move?
    pub fn any(&self) -> bool {
        self.faults_contained.load(Ordering::Relaxed) != 0
            || self.worker_respawns.load(Ordering::Relaxed) != 0
            || self.requests_quarantined.load(Ordering::Relaxed) != 0
            || self.degraded_ms.load(Ordering::Relaxed) != 0
    }

    /// `contained=N respawns=N quarantined=N degraded_ms=N`.
    pub fn summary_fragment(&self) -> String {
        format!(
            "contained={} respawns={} quarantined={} degraded_ms={}",
            self.faults_contained.load(Ordering::Relaxed),
            self.worker_respawns.load(Ordering::Relaxed),
            self.requests_quarantined.load(Ordering::Relaxed),
            self.degraded_ms.load(Ordering::Relaxed),
        )
    }
}

/// Per-tenant [`FaultStats`] registry. Tenants register once at worker
/// start; the stats handle is an `Arc` so the supervisor counts without
/// holding the registry lock.
#[derive(Debug, Default)]
pub struct FaultRegistry {
    tenants: Mutex<Vec<(String, Arc<FaultStats>)>>,
}

impl FaultRegistry {
    /// Stats handle for `name`, created on first use.
    pub fn register(&self, name: &str) -> Arc<FaultStats> {
        let mut g = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        if let Some((_, s)) = g.iter().find(|(n, _)| n == name) {
            return Arc::clone(s);
        }
        let s = Arc::new(FaultStats::default());
        g.push((name.to_string(), Arc::clone(&s)));
        s
    }

    /// Stats for `name`, if that tenant ever registered.
    pub fn stats(&self, name: &str) -> Option<Arc<FaultStats>> {
        let g = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        g.iter().find(|(n, _)| n == name).map(|(_, s)| Arc::clone(s))
    }

    /// `tenant[contained=.. respawns=.. ...]` fragments for tenants
    /// whose counters moved; empty on the fault-free path.
    pub fn summary(&self) -> String {
        let g = self.tenants.lock().unwrap_or_else(|p| p.into_inner());
        g.iter()
            .filter(|(_, s)| s.any())
            .map(|(n, s)| format!("{n}[{}]", s.summary_fragment()))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Per-SLO-class latency histograms.
///
/// Classes are registered **once** at server start, so the record path
/// is lock-free (a linear scan over a handful of names, then an atomic
/// histogram update). Requests without a class — and requests naming a
/// class that was never registered, which the front-end rejects before
/// they reach here anyway — land in the implicit `"default"` slot.
#[derive(Debug)]
pub struct LatencyByClass {
    classes: Vec<(String, LatencyHistogram)>,
}

impl Default for LatencyByClass {
    fn default() -> Self {
        LatencyByClass::with_classes(&[])
    }
}

impl LatencyByClass {
    /// `"default"` plus the given class names (duplicates folded).
    pub fn with_classes(names: &[String]) -> Self {
        let mut classes = vec![("default".to_string(), LatencyHistogram::new())];
        for n in names {
            if !classes.iter().any(|(c, _)| c == n) {
                classes.push((n.clone(), LatencyHistogram::new()));
            }
        }
        LatencyByClass { classes }
    }

    /// Record a completion latency under `class` (`None` → "default").
    pub fn record(&self, class: Option<&str>, d: Duration) {
        let name = class.unwrap_or("default");
        let slot = self
            .classes
            .iter()
            .find(|(c, _)| c == name)
            .unwrap_or(&self.classes[0]);
        slot.1.record(d);
    }

    pub fn histogram(&self, class: &str) -> Option<&LatencyHistogram> {
        self.classes.iter().find(|(c, _)| c == class).map(|(_, h)| h)
    }

    /// Registered class names, "default" first.
    pub fn class_names(&self) -> Vec<&str> {
        self.classes.iter().map(|(c, _)| c.as_str()).collect()
    }

    /// `class[p50/p99]` fragments for every class that saw traffic.
    pub fn summary(&self) -> String {
        self.classes
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(c, h)| {
                format!(
                    "{c}[n={} p50={} p99={}]",
                    h.count(),
                    crate::util::fmt_duration(h.quantile(0.50)),
                    crate::util::fmt_duration(h.quantile(0.99)),
                )
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_extremes() {
        // Paper protocol: omit min and max.
        let samples = [10.0, 1.0, 10.0, 10.0, 100.0];
        assert!((trimmed_mean(&samples) - 10.0).abs() < 1e-9);
        assert_eq!(trimmed_mean(&[]), 0.0);
        assert_eq!(trimmed_mean(&[5.0]), 5.0);
        assert_eq!(trimmed_mean(&[4.0, 6.0]), 5.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        // p50 ≈ 5ms within bucket resolution.
        let p50_us = p50.as_secs_f64() * 1e6;
        assert!((4000.0..7000.0).contains(&p50_us), "p50 {p50_us}µs");
    }

    #[test]
    fn histogram_mean_max() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
        assert_eq!(h.max(), Duration::from_micros(300));
    }

    #[test]
    fn throughput_counts() {
        let t = Throughput::new();
        t.add(5);
        t.add(7);
        assert_eq!(t.items(), 12);
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.per_second() > 0.0);
    }

    #[test]
    fn alloc_counter_accounting() {
        let c = AllocCounter::new();
        c.record(1024);
        c.record(512);
        assert_eq!(c.bytes(), 1536);
        assert_eq!(c.allocs(), 2);
        assert_eq!(c.per_inference(2), 768.0);
        assert_eq!(c.per_inference(0), 0.0);
        let d = c.clone();
        c.reset();
        assert_eq!(c.bytes(), 0);
        assert_eq!(d.bytes(), 1536, "clone must snapshot, not share");
    }

    #[test]
    fn latency_by_class_routes_and_defaults() {
        let by = LatencyByClass::with_classes(&["gold".into(), "bulk".into(), "gold".into()]);
        assert_eq!(by.class_names(), vec!["default", "gold", "bulk"]);
        by.record(Some("gold"), Duration::from_micros(100));
        by.record(Some("gold"), Duration::from_micros(200));
        by.record(None, Duration::from_micros(300));
        by.record(Some("nope"), Duration::from_micros(400)); // unknown -> default
        assert_eq!(by.histogram("gold").unwrap().count(), 2);
        assert_eq!(by.histogram("default").unwrap().count(), 2);
        assert_eq!(by.histogram("bulk").unwrap().count(), 0);
        assert!(by.histogram("nope").is_none());
        let s = by.summary();
        assert!(s.contains("gold[") && s.contains("default["));
        assert!(!s.contains("bulk["), "empty classes stay out of the summary: {s}");
    }

    #[test]
    fn fault_registry_registers_once_and_summarizes_movers_only() {
        let reg = FaultRegistry::default();
        let a = reg.register("a");
        let a2 = reg.register("a");
        let _b = reg.register("b");
        assert!(Arc::ptr_eq(&a, &a2), "re-registering must return the same handle");
        assert!(reg.summary().is_empty(), "fault-free tenants stay out of the summary");
        a.faults_contained.fetch_add(2, Ordering::Relaxed);
        a.worker_respawns.fetch_add(1, Ordering::Relaxed);
        let s = reg.summary();
        assert!(s.contains("a[contained=2 respawns=1 quarantined=0 degraded_ms=0]"), "{s}");
        assert!(!s.contains("b["), "{s}");
        assert!(reg.stats("a").unwrap().any());
        assert!(reg.stats("missing").is_none());
    }

    #[test]
    fn serve_counters_batch_mean() {
        let c = ServeCounters::default();
        c.batches.store(4, Ordering::Relaxed);
        c.batched_items.store(10, Ordering::Relaxed);
        assert_eq!(c.mean_batch_size(), 2.5);
    }
}
