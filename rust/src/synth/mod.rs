//! Program synthesis — Cappuccino's top-level flow (paper Fig. 3).
//!
//! 1. [`PrimarySynthesizer`] builds the *primary parallel program*: OLP
//!    thread allocation (section IV.A), map-major layout with vector
//!    width `u` (section IV.B), every layer precise. It validates the
//!    alignment precondition (every conv width divisible by `u`, so
//!    fork concats align with stacks) and records per-layer thread
//!    counts (`alpha = M x Wout x Hout`, Fig. 4).
//! 2. The inexact analysis ([`crate::inexact`]) runs the primary program
//!    against the validation set to pick per-layer arithmetic modes.
//! 3. [`finalize`] stamps the chosen modes into the final
//!    [`SynthesisPlan`] — the "synthesized software". Plans serialise to
//!    JSON and bind to either execution substrate: the native engine
//!    ([`execute_plan`]) or the SoC simulator ([`predict_latency_ms`]).

use std::collections::BTreeMap;

use crate::engine::{
    self, ArithMode, EngineParams, ExecutionPlan, LayerSchedule, ModeAssignment, Parallelism,
    PoolSettings, Schedule,
};
use crate::model::{shapes, Network};
use crate::soc::{DeviceModel, ProcessingMode};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Per-parameterised-layer plan entry.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub layer: String,
    /// Thread workload allocation (always OLP from the primary
    /// synthesizer; KLP/FLP appear only in ablation plans).
    pub parallelism: Parallelism,
    /// Arithmetic mode chosen by the inexact analysis.
    pub mode: ArithMode,
    /// OLP thread-pool size for this layer.
    pub threads: usize,
    /// `alpha = M x Wout x Hout` — the paper's per-layer logical thread
    /// count (one thread per output pixel, Fig. 4).
    pub alpha: usize,
}

/// A synthesized program: the complete executable description.
#[derive(Debug, Clone, PartialEq)]
pub struct SynthesisPlan {
    pub net: String,
    pub u: usize,
    pub threads: usize,
    pub layers: Vec<LayerPlan>,
}

impl SynthesisPlan {
    /// Mode assignment view for the engine.
    pub fn mode_assignment(&self) -> ModeAssignment {
        let mut ma = ModeAssignment::uniform(ArithMode::Precise);
        for lp in &self.layers {
            ma.per_layer.insert(lp.layer.clone(), lp.mode);
        }
        ma
    }

    /// How many layers run inexact (the analysis' objective).
    pub fn inexact_layers(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.mode != ArithMode::Precise)
            .count()
    }

    // -- Schedule bridge ----------------------------------------------------

    /// Lower the synthesized program into the engine's [`Schedule`] IR
    /// — the single surface plan compilation accepts. Per-layer
    /// parallelism and modes carry over; packing/tiling/placement take
    /// their defaults (packed, cost-model tiles, no placement), which
    /// the autotuner ([`crate::autotune`]) then refines in place.
    pub fn to_schedule(&self) -> Schedule {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let ls = LayerSchedule {
                    parallelism: l.parallelism,
                    mode: l.mode,
                    ..Default::default()
                };
                (l.layer.clone(), ls)
            })
            .collect();
        Schedule {
            net: self.net.clone(),
            u: self.u,
            pool: PoolSettings { threads: self.threads, ..Default::default() },
            layers,
        }
    }

    /// Rebuild a synthesis-plan view from a schedule (the reverse
    /// bridge: `alpha` comes from shape inference, per-layer threads
    /// from the schedule's pool). Validates the schedule against `net`.
    pub fn from_schedule(schedule: &Schedule, net: &Network) -> Result<SynthesisPlan> {
        schedule.validate_for(net, schedule.u)?;
        let info = shapes::infer(net)?;
        let layers = info
            .param_layers
            .iter()
            .map(|pl| {
                let ls = schedule.layers.get(&pl.name).copied().unwrap_or_default();
                LayerPlan {
                    layer: pl.name.clone(),
                    parallelism: ls.parallelism,
                    mode: ls.mode,
                    threads: schedule.pool.threads,
                    alpha: pl.output.elements(),
                }
            })
            .collect();
        Ok(SynthesisPlan {
            net: net.name.clone(),
            u: schedule.u,
            threads: schedule.pool.threads,
            layers,
        })
    }

    // -- JSON round-trip ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("net", Json::str(self.net.clone())),
            ("u", Json::num(self.u as f64)),
            ("threads", Json::num(self.threads as f64)),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("layer", Json::str(l.layer.clone())),
                                ("parallelism", Json::str(l.parallelism.as_str())),
                                ("mode", Json::str(l.mode.as_str())),
                                ("threads", Json::num(l.threads as f64)),
                                ("alpha", Json::num(l.alpha as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(json: &Json) -> Result<SynthesisPlan> {
        let layers = json
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LayerPlan {
                    layer: l.get("layer")?.as_str()?.to_string(),
                    parallelism: l.get("parallelism")?.as_str()?.parse()?,
                    mode: l.get("mode")?.as_str()?.parse()?,
                    threads: l.get("threads")?.as_usize()?,
                    alpha: l.get("alpha")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(SynthesisPlan {
            net: json.get("net")?.as_str()?.to_string(),
            u: json.get("u")?.as_usize()?,
            threads: json.get("threads")?.as_usize()?,
            layers,
        })
    }
}

/// Primary Program Synthesizer (Fig. 3, first stage).
pub struct PrimarySynthesizer {
    pub u: usize,
    pub threads: usize,
}

impl PrimarySynthesizer {
    pub fn new(u: usize, threads: usize) -> Self {
        PrimarySynthesizer { u, threads }
    }

    /// Build the primary (all-precise) parallel program for `net`.
    pub fn synthesize(&self, net: &Network) -> Result<SynthesisPlan> {
        if self.u == 0 || !self.u.is_power_of_two() {
            return Err(Error::Invalid(format!("u={} must be a power of two", self.u)));
        }
        let info = shapes::infer(net)?;
        // Alignment precondition: every conv width must divide u so that
        // fork concatenation keeps stack boundaries aligned (IV.B).
        let mut misaligned = Vec::new();
        net.visit(&mut |l| {
            if let crate::model::LayerOp::Conv { m, .. } = l.op {
                if m % self.u != 0 {
                    misaligned.push(format!("{} (m={m})", l.name));
                }
            }
        });
        if !misaligned.is_empty() {
            return Err(Error::Invalid(format!(
                "net {}: conv widths not divisible by u={}: {}",
                net.name,
                self.u,
                misaligned.join(", ")
            )));
        }
        let layers = info
            .param_layers
            .iter()
            .map(|pl| LayerPlan {
                layer: pl.name.clone(),
                parallelism: Parallelism::Olp,
                mode: ArithMode::Precise,
                threads: self.threads,
                alpha: pl.output.elements(),
            })
            .collect();
        Ok(SynthesisPlan { net: net.name.clone(), u: self.u, threads: self.threads, layers })
    }
}

/// Software Synthesizer (Fig. 3, final stage): stamp the analysis'
/// per-layer modes into the primary plan.
pub fn finalize(primary: &SynthesisPlan, modes: &ModeAssignment) -> SynthesisPlan {
    let mut plan = primary.clone();
    for lp in &mut plan.layers {
        lp.mode = modes.mode_of(&lp.layer);
    }
    plan
}

/// Compile a synthesized plan into an immediately executable
/// [`ExecutionPlan`] with batch capacity 1 — see
/// [`compile_plan_batched`] for serving-style capacities.
pub fn compile_plan(
    plan: &SynthesisPlan,
    net: &Network,
    params: &EngineParams,
) -> Result<ExecutionPlan> {
    compile_plan_batched(plan, net, params, 1)
}

/// Compile a synthesized plan into an immediately executable
/// [`ExecutionPlan`] (via [`crate::engine::PlanBuilder`]): weights
/// baked per the plan's layer modes **and packed into streaming panels**
/// (tap-major conv panels, column-blocked dense panels — see
/// [`crate::layout`]), per-conv-layer row tiles from the L1/L2 cost
/// model, buffer arena sized `batch x`, thread-pool chunking fixed on
/// macro-item boundaries — the "synthesized software" in its runnable
/// form, executing up to `batch` images per walk. Honours the plan's
/// thread-workload allocation when it is uniform (ablation plans lower
/// FLP/KLP executors).
pub fn compile_plan_batched(
    plan: &SynthesisPlan,
    net: &Network,
    params: &EngineParams,
    batch: usize,
) -> Result<ExecutionPlan> {
    // One lowering path: the synthesis plan bridges into the Schedule
    // IR and plan compilation consumes that (per-layer parallelism is
    // honored — ablation plans mixing OLP with FLP/KLP lower exactly as
    // written, with layout reorders at family boundaries).
    crate::engine::PlanBuilder::new(net, params).schedule(plan.to_schedule()).batch(batch).build()
}

/// Execute a plan on the native engine (compile + single run; hold the
/// [`compile_plan`] result to amortise compilation across requests).
pub fn execute_plan(
    plan: &SynthesisPlan,
    net: &Network,
    params: &EngineParams,
    input: &[f32],
) -> Result<Vec<f32>> {
    compile_plan(plan, net, params)?.run(input)
}

/// Predict the plan's latency on a simulated device. Layers in inexact
/// modes run at the vectorised rate, precise layers at the scalar
/// parallel rate — the per-layer mixture Table I's "Imprecise" column
/// assumes when the analysis accepts every layer.
pub fn predict_latency_ms(plan: &SynthesisPlan, net: &Network, device: &DeviceModel) -> f64 {
    let modes: BTreeMap<&str, ArithMode> =
        plan.layers.iter().map(|l| (l.layer.as_str(), l.mode)).collect();
    let parallel = crate::soc::simulate(net, device, ProcessingMode::Parallel);
    let imprecise = crate::soc::simulate(net, device, ProcessingMode::Imprecise);
    parallel
        .layers
        .iter()
        .zip(&imprecise.layers)
        .map(|(p, i)| {
            match modes.get(p.name.as_str()) {
                Some(ArithMode::Precise) | None => p.total_ms(),
                // Relaxed unlocks vectors too (paper IV.C); model both
                // inexact modes at the vectorised rate.
                Some(_) => i.total_ms(),
            }
        })
        .sum()
}

/// Predict a tuned schedule's per-image latency on a simulated device —
/// the serve front-end's admission-control bridge. A `schedule.json`
/// artifact lowers into a [`SynthesisPlan`] (validating it against the
/// net) and runs through [`predict_latency_ms`], giving the admission
/// controller an analytic service estimate with no on-device warm-up.
pub fn predict_schedule_latency_ms(
    schedule: &Schedule,
    net: &Network,
    device: &DeviceModel,
) -> Result<f64> {
    let plan = SynthesisPlan::from_schedule(schedule, net)?;
    Ok(predict_latency_ms(&plan, net, device))
}

/// Predict a schedule's **steady-state** per-batch cost on a simulated
/// device under staged pipelined execution
/// ([`crate::engine::hetero`]): layers are grouped into contiguous
/// per-backend stages in net order (unscheduled layers — pools, LRN —
/// ride the stage in progress, exactly like the plan partitioner) and
/// the result is the **bottleneck** stage's latency sum. With stages
/// overlapping across consecutive batches the slowest stage sets the
/// service rate, not the stage-time sum — so a uniform schedule
/// degenerates to [`predict_schedule_latency_ms`].
pub fn predict_schedule_throughput_ms(
    schedule: &Schedule,
    net: &Network,
    device: &DeviceModel,
) -> Result<f64> {
    use crate::engine::schedule::BackendTarget;
    let plan = SynthesisPlan::from_schedule(schedule, net)?;
    let modes: BTreeMap<&str, ArithMode> =
        plan.layers.iter().map(|l| (l.layer.as_str(), l.mode)).collect();
    let parallel = crate::soc::simulate(net, device, ProcessingMode::Parallel);
    let imprecise = crate::soc::simulate(net, device, ProcessingMode::Imprecise);
    let mut cur = parallel
        .layers
        .iter()
        .find_map(|p| schedule.layers.get(p.name.as_str()).map(|ls| ls.backend))
        .unwrap_or(BackendTarget::Native);
    let mut stages: Vec<(BackendTarget, f64)> = Vec::new();
    for (p, i) in parallel.layers.iter().zip(&imprecise.layers) {
        let ms = match modes.get(p.name.as_str()) {
            Some(ArithMode::Precise) | None => p.total_ms(),
            Some(_) => i.total_ms(),
        };
        if let Some(ls) = schedule.layers.get(p.name.as_str()) {
            cur = ls.backend;
        }
        match stages.last_mut() {
            Some((b, acc)) if *b == cur => *acc += ms,
            _ => stages.push((cur, ms)),
        }
    }
    Ok(stages.into_iter().map(|(_, ms)| ms).fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ExecConfig;
    use crate::model::zoo;
    use crate::soc::devices;
    use crate::util::rng::Rng;

    #[test]
    fn primary_plan_is_olp_precise() {
        let net = zoo::squeezenet();
        let plan = PrimarySynthesizer::new(4, 4).synthesize(&net).unwrap();
        assert_eq!(plan.layers.len(), 26);
        assert!(plan
            .layers
            .iter()
            .all(|l| l.parallelism == Parallelism::Olp && l.mode == ArithMode::Precise));
        assert_eq!(plan.inexact_layers(), 0);
    }

    #[test]
    fn alpha_matches_paper_definition() {
        // alpha = M x Wout x Hout for conv layers (Fig. 4).
        let net = zoo::alexnet();
        let plan = PrimarySynthesizer::new(4, 4).synthesize(&net).unwrap();
        let conv1 = plan.layers.iter().find(|l| l.layer == "conv1").unwrap();
        assert_eq!(conv1.alpha, 96 * 55 * 55);
    }

    #[test]
    fn misaligned_u_rejected() {
        // u=32 does not divide tinynet's 16-wide conv1.
        let net = zoo::tinynet();
        let err = PrimarySynthesizer::new(32, 1).synthesize(&net).unwrap_err();
        assert!(err.to_string().contains("conv1"), "{err}");
        assert!(PrimarySynthesizer::new(3, 1).synthesize(&net).is_err());
    }

    #[test]
    fn finalize_stamps_modes() {
        let net = zoo::tinynet();
        let primary = PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise)
            .with("fc5", ArithMode::Precise);
        let plan = finalize(&primary, &modes);
        assert_eq!(plan.inexact_layers(), 4);
        assert_eq!(
            plan.layers.iter().find(|l| l.layer == "fc5").unwrap().mode,
            ArithMode::Precise
        );
    }

    #[test]
    fn plan_json_roundtrip() {
        let net = zoo::tinynet();
        let primary = PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap();
        let plan = finalize(
            &primary,
            &ModeAssignment::uniform(ArithMode::Imprecise),
        );
        let back = SynthesisPlan::from_json(&Json::parse(&plan.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back, plan);
    }

    #[test]
    fn execute_plan_matches_engine() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let plan = PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap();
        let mut rng = Rng::new(1);
        let input = rng.normal_vec(net.input.elements());
        let a = execute_plan(&plan, &net, &params, &input).unwrap();
        let b = engine::run_mapmajor(
            &net,
            &params,
            &input,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 2, ..Default::default() },
        )
        .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn compiled_plan_amortises_across_requests() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let plan = finalize(
            &PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap(),
            &ModeAssignment::uniform(ArithMode::Imprecise),
        );
        let mut compiled = compile_plan(&plan, &net, &params).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..3 {
            let input = rng.normal_vec(net.input.elements());
            let a = compiled.run(&input).unwrap();
            let b = execute_plan(&plan, &net, &params, &input).unwrap();
            assert_eq!(a, b, "resident plan drifted from one-shot execution");
        }
        assert_eq!(compiled.runs(), 3);
    }

    #[test]
    fn batched_compiled_plan_matches_singles() {
        // One walk over a dynamic batch is bitwise the per-image flow.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let plan = finalize(
            &PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap(),
            &ModeAssignment::uniform(ArithMode::Imprecise),
        );
        let mut batched = compile_plan_batched(&plan, &net, &params, 4).unwrap();
        assert_eq!(batched.capacity(), 4);
        let mut rng = Rng::new(3);
        let inputs: Vec<Vec<f32>> =
            (0..3).map(|_| rng.normal_vec(net.input.elements())).collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let rows = batched.run_batch(&refs).unwrap();
        for (row, input) in rows.iter().zip(&inputs) {
            assert_eq!(row, &execute_plan(&plan, &net, &params, input).unwrap());
        }
    }

    #[test]
    fn schedule_bridge_roundtrips_both_directions() {
        let net = zoo::tinynet();
        let primary = PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap();
        let plan = finalize(
            &primary,
            &ModeAssignment::uniform(ArithMode::Imprecise).with("fc5", ArithMode::Precise),
        );
        let sched = plan.to_schedule();
        assert_eq!(sched.pool.threads, 2);
        assert_eq!(sched.layers.len(), plan.layers.len());
        assert_eq!(sched.layers["fc5"].mode, ArithMode::Precise);
        let back = SynthesisPlan::from_schedule(&sched, &net).unwrap();
        assert_eq!(back, plan);
        // And the schedule path compiles to the same numerics as the
        // one-shot execute_plan flow.
        let params = EngineParams::random(&net, 6, 4).unwrap();
        let mut rng = Rng::new(7);
        let input = rng.normal_vec(net.input.elements());
        let mut compiled = compile_plan(&plan, &net, &params).unwrap();
        assert_eq!(
            compiled.run(&input).unwrap(),
            execute_plan(&plan, &net, &params, &input).unwrap()
        );
    }

    #[test]
    fn execute_plan_u_mismatch_rejected() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let plan = PrimarySynthesizer::new(8, 1).synthesize(&net).unwrap();
        let input = vec![0.0; net.input.elements()];
        assert!(execute_plan(&plan, &net, &params, &input).is_err());
    }

    #[test]
    fn predicted_latency_monotone_in_inexact_layers() {
        let net = zoo::squeezenet();
        let device = devices::nexus5();
        let primary = PrimarySynthesizer::new(4, 4).synthesize(&net).unwrap();
        let all_imprecise = finalize(
            &primary,
            &ModeAssignment::uniform(ArithMode::Imprecise),
        );
        let t_precise = predict_latency_ms(&primary, &net, &device);
        let t_imprecise = predict_latency_ms(&all_imprecise, &net, &device);
        assert!(t_imprecise < t_precise, "{t_imprecise} vs {t_precise}");
        // Matches the plain simulator endpoints.
        let sim_par =
            crate::soc::simulate(&net, &device, ProcessingMode::Parallel).total_ms();
        assert!((t_precise / sim_par - 1.0).abs() < 1e-9);
    }

    #[test]
    fn schedule_latency_bridge_validates_and_predicts() {
        // The admission-control bridge: schedule in, milliseconds out.
        let net = zoo::tinynet();
        let precise = Schedule::default_for(&net, 4);
        let t_precise = predict_schedule_latency_ms(&precise, &net, &devices::nexus5()).unwrap();
        assert!(t_precise.is_finite() && t_precise > 0.0, "{t_precise}");
        let mut imprecise = precise.clone();
        for ls in imprecise.layers.values_mut() {
            ls.mode = ArithMode::Imprecise;
        }
        let t_imprecise =
            predict_schedule_latency_ms(&imprecise, &net, &devices::nexus5()).unwrap();
        assert!(t_imprecise < t_precise, "{t_imprecise} vs {t_precise}");
        // A schedule for a different net is rejected, not mispredicted.
        let other = zoo::alexnet();
        assert!(predict_schedule_latency_ms(&precise, &other, &devices::nexus5()).is_err());
    }

    #[test]
    fn throughput_model_is_bottleneck_not_sum() {
        use crate::engine::schedule::BackendTarget;
        let net = zoo::tinynet();
        let device = devices::nexus5();
        let uniform = Schedule::default_for(&net, 4);
        let flat = predict_schedule_latency_ms(&uniform, &net, &device).unwrap();
        // Uniform: one stage, bottleneck == the full sum.
        let t_uniform = predict_schedule_throughput_ms(&uniform, &net, &device).unwrap();
        assert!((t_uniform / flat - 1.0).abs() < 1e-9, "{t_uniform} vs {flat}");
        // Staged: the bottleneck stage is a strict subset of the layers,
        // so predicted steady-state cost drops below the flat sum.
        let mut staged = uniform.clone();
        staged.layers.get_mut("conv2").unwrap().backend = BackendTarget::Mock;
        let t_staged = predict_schedule_throughput_ms(&staged, &net, &device).unwrap();
        assert!(t_staged < flat, "{t_staged} vs {flat}");
        assert!(t_staged > 0.0);
    }
}
