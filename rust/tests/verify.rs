//! Mutation-testing suite for the static plan verifier
//! (`engine::verify`), plus the clean sweep.
//!
//! The verifier is only worth trusting if it demonstrably *rejects*
//! broken plans — so each test here takes a known-good compiled plan,
//! seeds one corruption through the test-only
//! `ExecutionPlan::apply_mutation` hook, and asserts the exact
//! `Error::Verify` rule fires. Each of the four documented rule classes
//! (race-freedom, def/layout, arena, mode/tile) is covered by at least
//! two distinct corruptions. The sweep at the bottom asserts the
//! converse: every zoo model x every autotuner candidate family
//! verifies clean at capacities {1, 4, 8}.

use cappuccino::engine::verify::{PlanMutation, VerifyRule};
use cappuccino::engine::{
    verify_schedule, ArithMode, EngineParams, ExecutionPlan, ModeAssignment, Parallelism,
    PlanBuilder, PoolSettings, Schedule,
};
use cappuccino::model::{zoo, Network};
use cappuccino::Error;

const U: usize = cappuccino::DEFAULT_U;

fn uniform_plan(
    net: &Network,
    mode: ArithMode,
    policy: Parallelism,
    packing: bool,
    threads: usize,
    batch: usize,
) -> ExecutionPlan {
    let params = EngineParams::random(net, 7, U).unwrap();
    PlanBuilder::new(net, &params)
        .modes(&ModeAssignment::uniform(mode))
        .policy(policy)
        .packing(packing)
        .threads(threads)
        .batch(batch)
        .build()
        .unwrap()
}

/// A packed OLP tinynet plan — the default lowering family.
fn base_plan() -> ExecutionPlan {
    uniform_plan(&zoo::tinynet(), ArithMode::Imprecise, Parallelism::Olp, true, 2, 2)
}

/// tinynet with `conv2` forced row-major (FLP) inside an otherwise
/// packed OLP schedule: the lowering emits `Reorder` steps at both
/// layout boundaries and an FLP reduction region.
fn mixed_plan() -> ExecutionPlan {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 7, U).unwrap();
    let mut sched = Schedule::from_uniform(
        &net,
        U,
        &ModeAssignment::uniform(ArithMode::Imprecise),
        Parallelism::Olp,
        true,
        None,
        PoolSettings { threads: 2, affinity: false, cores: None },
    )
    .unwrap();
    sched.layers.get_mut("conv2").unwrap().parallelism = Parallelism::Flp;
    PlanBuilder::new(&net, &params).schedule(sched).batch(2).build().unwrap()
}

/// Seed `m` into `plan` and assert the verifier rejects it with exactly
/// `want` — a typed `Error::Verify` naming a step and a layer.
fn assert_rejects(mut plan: ExecutionPlan, m: PlanMutation, want: VerifyRule) {
    assert!(plan.apply_mutation(m), "plan has no site for mutation {m:?}");
    match plan.verify() {
        Err(Error::Verify { step, layer, rule, detail }) => {
            assert_eq!(
                rule, want,
                "mutation {m:?} fired {rule:?} at step {step} ({layer}): {detail}; \
                 expected {want:?}"
            );
            assert!(!layer.is_empty(), "violation must name the step's layer");
            assert!(!detail.is_empty(), "violation must carry a detail message");
        }
        Err(other) => panic!("mutation {m:?} surfaced a non-verify error: {other}"),
        Ok(()) => panic!("mutation {m:?} was NOT rejected by the verifier"),
    }
}

// --- rule class 1: race-freedom ---------------------------------------------

#[test]
fn race_alias_conv_src_dst_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::AliasConvSrcDst, VerifyRule::RaceFreedom);
}

#[test]
fn race_alias_concat_is_rejected() {
    // Needs a fork/join net: googlenet's inception concats.
    let plan = uniform_plan(&zoo::googlenet(), ArithMode::Imprecise, Parallelism::Olp, true, 2, 1);
    assert_rejects(plan, PlanMutation::AliasConcat, VerifyRule::RaceFreedom);
}

#[test]
fn race_truncated_reduce_rows_are_rejected() {
    // FLP reduction region with a 2-thread pool: dropping partial
    // buffers makes two chunks share one — a write/write race.
    assert_rejects(mixed_plan(), PlanMutation::TruncateReduce, VerifyRule::RaceFreedom);
}

#[test]
fn race_truncated_thread_scratch_rows_are_rejected() {
    assert_rejects(base_plan(), PlanMutation::TruncateThreadScratch, VerifyRule::RaceFreedom);
}

// --- rule class 2: def-before-use + layout consistency ----------------------

#[test]
fn def_use_before_def_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::UseBeforeDef, VerifyRule::DefBeforeUse);
}

#[test]
fn layout_dropped_reorder_is_rejected() {
    // Replacing the boundary reorder with a raw copy silently
    // reinterprets map-major lanes as row-major — the exact bug class
    // the multi-backend placement work makes easy to introduce.
    assert_rejects(mixed_plan(), PlanMutation::ReorderToCopy, VerifyRule::LayoutConsistency);
}

#[test]
fn layout_same_width_reorder_is_rejected() {
    assert_rejects(mixed_plan(), PlanMutation::ReorderSameWidth, VerifyRule::LayoutConsistency);
}

// --- rule class 3: arena safety ---------------------------------------------

#[test]
fn arena_undersized_register_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::UndersizeArena, VerifyRule::ArenaSafety);
}

#[test]
fn arena_undersized_scratch_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::UndersizeScratch, VerifyRule::ArenaSafety);
}

// --- rule class 4: mode/tile preconditions ----------------------------------

fn quant_plan() -> ExecutionPlan {
    uniform_plan(&zoo::tinynet(), ArithMode::QuantI8, Parallelism::Olp, true, 2, 2)
}

#[test]
fn mode_dropped_quant_panels_are_rejected() {
    assert_rejects(quant_plan(), PlanMutation::QuantDropPanels, VerifyRule::ModePrecondition);
}

#[test]
fn mode_unpacked_quant_is_rejected() {
    assert_rejects(quant_plan(), PlanMutation::QuantUnpack, VerifyRule::ModePrecondition);
}

#[test]
fn tile_zero_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::TileZero, VerifyRule::TilePrecondition);
}

#[test]
fn tile_unclamped_is_rejected() {
    assert_rejects(base_plan(), PlanMutation::TileUnclamped, VerifyRule::TilePrecondition);
}

// --- diagnostics ------------------------------------------------------------

#[test]
fn verify_error_display_names_the_rule_and_step() {
    let mut plan = base_plan();
    assert!(plan.apply_mutation(PlanMutation::TileZero));
    let e = plan.verify().unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("tile-precondition"), "missing rule name: {msg}");
    assert!(msg.contains("plan step"), "missing step index: {msg}");
}

// --- pre-lowering schedule lints --------------------------------------------

#[test]
fn schedule_lint_placement_without_packing() {
    let net = zoo::tinynet();
    let mut sched = Schedule::from_uniform(
        &net,
        U,
        &ModeAssignment::uniform(ArithMode::Imprecise),
        Parallelism::Olp,
        true,
        None,
        PoolSettings { threads: 2, affinity: true, cores: None },
    )
    .unwrap();
    verify_schedule(&sched).unwrap();
    let ls = sched.layers.get_mut("conv1").unwrap();
    ls.placement = true;
    ls.packing = false;
    match verify_schedule(&sched) {
        Err(Error::Verify { rule: VerifyRule::ModePrecondition, layer, .. }) => {
            assert_eq!(layer, "conv1");
        }
        other => panic!("placement-without-packing not linted: {other:?}"),
    }
}

#[test]
fn schedule_lint_vector_width_without_packing() {
    let net = zoo::tinynet();
    let mut sched = Schedule::from_uniform(
        &net,
        U,
        &ModeAssignment::uniform(ArithMode::Imprecise),
        Parallelism::Olp,
        true,
        None,
        PoolSettings { threads: 1, affinity: false, cores: None },
    )
    .unwrap();
    let ls = sched.layers.get_mut("conv1").unwrap();
    ls.vector_width = 4;
    ls.packing = false;
    assert!(matches!(
        verify_schedule(&sched),
        Err(Error::Verify { rule: VerifyRule::ModePrecondition, .. })
    ));
}

// --- the clean sweep --------------------------------------------------------

/// Every zoo model x every autotuner candidate family verifies clean at
/// capacities {1, 4, 8}. The families mirror what `autotune` explores:
/// packed/unpacked OLP, row-major FLP/KLP, forced-scalar rows
/// (`vector_width = 1`), the quantized int8 kernels, and placement.
#[test]
fn zoo_x_candidate_families_verify_clean_at_all_capacities() {
    let combos: &[(ArithMode, Parallelism, bool, usize, bool)] = &[
        (ArithMode::Precise, Parallelism::Olp, true, 1, false),
        (ArithMode::Imprecise, Parallelism::Olp, true, 4, false),
        (ArithMode::QuantI8, Parallelism::Olp, true, 4, false),
        (ArithMode::Imprecise, Parallelism::Olp, false, 4, false),
        (ArithMode::Imprecise, Parallelism::Flp, true, 4, false),
        (ArithMode::Imprecise, Parallelism::Klp, true, 4, false),
        (ArithMode::Imprecise, Parallelism::Olp, true, 4, true),
    ];
    for net in zoo::all() {
        let params = EngineParams::random(&net, 7, U).unwrap();
        for &(mode, policy, packing, threads, affinity) in combos {
            let plan = PlanBuilder::new(&net, &params)
                .modes(&ModeAssignment::uniform(mode))
                .policy(policy)
                .packing(packing)
                .threads(threads)
                .affinity(affinity)
                .batch(4)
                .build()
                .unwrap_or_else(|e| {
                    panic!("{} {mode:?}/{policy:?} packing={packing}: {e}", net.name)
                });
            for cap in [1usize, 4, 8] {
                let sibling = plan.with_capacity(cap);
                sibling.verify().unwrap_or_else(|e| {
                    panic!(
                        "{} {mode:?}/{policy:?} packing={packing} affinity={affinity}: \
                         capacity {cap} failed verify: {e}",
                        net.name
                    )
                });
            }
        }
        // The forced-scalar candidate family (vector_width = 1).
        let mut sched = Schedule::from_uniform(
            &net,
            U,
            &ModeAssignment::uniform(ArithMode::Imprecise),
            Parallelism::Olp,
            true,
            None,
            PoolSettings { threads: 4, affinity: false, cores: None },
        )
        .unwrap();
        for ls in sched.layers.values_mut() {
            ls.vector_width = 1;
        }
        verify_schedule(&sched).unwrap();
        let plan = PlanBuilder::new(&net, &params).schedule(sched).batch(4).build().unwrap();
        for cap in [1usize, 4, 8] {
            plan.with_capacity(cap).verify().unwrap();
        }
    }
}
