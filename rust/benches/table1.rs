//! Bench: regenerate paper Table I (execution time + speedup for three
//! CNNs on three simulated devices under three processing modes).
//!
//! Protocol matches section V.A: 100 repetitions per cell, min and max
//! omitted, mean of the remaining 98 reported. Asserts the shape
//! invariants the paper claims (baseline >> parallel >= imprecise,
//! speedups within the coarse band) so regressions fail the bench.

use cappuccino::bench::Table;
use cappuccino::model::zoo;
use cappuccino::soc::{self, ProcessingMode};

fn main() {
    let nets = ["alexnet", "squeezenet", "googlenet"];
    let mut table = Table::new(&[
        "net", "device", "baseline(ms)", "parallel(ms)", "imprecise(ms)", "speedup",
    ]);
    let mut all_ok = true;
    let (mut min_speedup, mut max_speedup) = (f64::INFINITY, 0.0f64);

    for net_name in nets {
        let net = zoo::by_name(net_name).unwrap();
        for device in soc::catalog() {
            let base =
                soc::measure_trimmed(&net, &device, ProcessingMode::JavaBaseline, 100, 0.01, 1);
            let par = soc::measure_trimmed(&net, &device, ProcessingMode::Parallel, 100, 0.01, 2);
            let imp = soc::measure_trimmed(&net, &device, ProcessingMode::Imprecise, 100, 0.01, 3);
            let speedup = base / imp;
            min_speedup = min_speedup.min(speedup);
            max_speedup = max_speedup.max(speedup);
            // Paper shape invariants.
            if !(base > par && par > imp) {
                eprintln!("ORDER VIOLATION: {net_name}/{}", device.name);
                all_ok = false;
            }
            table.row(&[
                net_name.into(),
                device.name.into(),
                format!("{base:.2}"),
                format!("{par:.2}"),
                format!("{imp:.2}"),
                format!("{speedup:.2}x"),
            ]);
        }
    }

    println!("# Table I — execution time on simulated devices (trimmed mean of 100)\n");
    table.print();
    println!("\nspeedup band: {min_speedup:.1}x .. {max_speedup:.1}x (paper: 31.95x .. 272.03x)");
    assert!(all_ok, "mode ordering violated");
    assert!(
        min_speedup > 10.0 && max_speedup < 500.0,
        "speedup band out of range"
    );
    println!("table1 bench OK");
}
