//! Ablation: OLP vs FLP vs KLP thread workload allocation (paper
//! section IV.A's design argument).
//!
//! Two views:
//!
//! 1. **Measured** — the native engine's real implementations of all
//!    three policies on representative conv layers. KLP/FLP pay for
//!    per-thread partial buffers + the reduction pass; OLP writes
//!    disjoint outputs with no synchronisation. (On this single-core
//!    testbed the *overhead* difference is what shows; the thread-count
//!    sweep is structural.)
//! 2. **Simulated** — the SoC model's view of the same tradeoff via the
//!    reduction/zero-sync cost structure embedded in each policy.

use cappuccino::bench::{bench, ms, BenchConfig, Table};
use cappuccino::engine::parallel::{parallel_for, parallel_for_spawn};
use cappuccino::engine::{
    cast_weights, conv_mm, conv_nchw_flp, conv_nchw_klp, conv_nchw_scalar, ArithMode, MapTensor,
};
use cappuccino::layout;
use cappuccino::util::rng::Rng;

struct LayerCase {
    name: &'static str,
    c: usize,
    h: usize,
    m: usize,
    k: usize,
    s: usize,
    p: usize,
}

// Layer geometries drawn from the paper's nets (downscaled spatially to
// keep the bench under a minute).
const CASES: &[LayerCase] = &[
    LayerCase { name: "alexnet-conv2-like", c: 96, h: 27, m: 128, k: 5, s: 1, p: 2 },
    LayerCase { name: "squeezenet-e3-like", c: 32, h: 27, m: 64, k: 3, s: 1, p: 1 },
    LayerCase { name: "googlenet-b1-like", c: 192, h: 28, m: 64, k: 1, s: 1, p: 0 },
];

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(0xAB1A);
    let mut table = Table::new(&[
        "layer", "threads", "scalar(ms)", "olp-mm(ms)", "flp(ms)", "klp(ms)", "olp wins",
    ]);

    for case in CASES {
        let LayerCase { name, c, h, m, k, s, p } = *case;
        let w = h;
        let input = rng.normal_vec(c * h * w);
        let weights = rng.normal_vec(m * c * k * k);
        let bias = rng.normal_vec(m);
        let u = 4;
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        // Baked (compile-time mode-cast) weights for the inexact rows.
        let w_mm = cast_weights(
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            ArithMode::Imprecise,
        );
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let w_baked = cast_weights(&weights, ArithMode::Imprecise);

        for threads in [1usize, 2, 4] {
            let scalar = bench("scalar", cfg, || {
                std::hint::black_box(conv_nchw_scalar(
                    &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise,
                ));
            });
            let olp = bench("olp", cfg, || {
                std::hint::black_box(conv_mm(
                    &mm_in, &w_mm, &b_mm, m, k, s, p, true, ArithMode::Imprecise, threads,
                ));
            });
            let flp = bench("flp", cfg, || {
                std::hint::black_box(conv_nchw_flp(
                    &input, c, h, w, &w_baked, &bias, m, k, s, p, true,
                    ArithMode::Imprecise, threads,
                ));
            });
            let klp = bench("klp", cfg, || {
                std::hint::black_box(conv_nchw_klp(
                    &input, c, h, w, &w_baked, &bias, m, k, s, p, true,
                    ArithMode::Imprecise, threads,
                ));
            });
            let olp_wins = olp.mean_ms <= flp.mean_ms && olp.mean_ms <= klp.mean_ms;
            table.row(&[
                name.into(),
                threads.to_string(),
                ms(scalar.mean_ms),
                ms(olp.mean_ms),
                ms(flp.mean_ms),
                ms(klp.mean_ms),
                if olp_wins { "yes".into() } else { "no".into() },
            ]);
        }
    }

    println!("# Ablation — thread workload allocation (OLP vs FLP vs KLP)\n");
    table.print();

    // -- Execution substrate: persistent pool vs per-call scoped spawn ----
    // The dispatch-overhead ablation behind the compiled-plan executor:
    // same chunked workload, threads either woken from the long-lived
    // pool or spawned fresh per call (the pre-plan behaviour every conv
    // layer of every inference used to pay).
    let mut pool_table = Table::new(&["work items", "threads", "pool(ms)", "spawn(ms)", "spawn/pool"]);
    let sink = std::sync::atomic::AtomicU64::new(0);
    for &(items, threads) in &[(64usize, 4usize), (1024, 4), (16384, 8)] {
        let work = |_: usize, r: std::ops::Range<usize>| {
            let mut acc = 0u64;
            for i in r {
                acc = acc.wrapping_add((i as u64).wrapping_mul(2654435761));
            }
            sink.fetch_add(acc, std::sync::atomic::Ordering::Relaxed);
        };
        let pool = bench("pool", cfg, || parallel_for(items, threads, work));
        let spawn = bench("spawn", cfg, || parallel_for_spawn(items, threads, work));
        pool_table.row(&[
            items.to_string(),
            threads.to_string(),
            ms(pool.mean_ms),
            ms(spawn.mean_ms),
            format!("{:.2}x", spawn.mean_ms / pool.mean_ms.max(1e-9)),
        ]);
    }
    println!("\n# Ablation — persistent pool vs scoped spawn dispatch\n");
    pool_table.print();
    println!("\npaper's argument (sec IV.A): OLP avoids the reduction +");
    println!("inter-thread transfer KLP/FLP require and reuses kernels across");
    println!("outputs; the measured columns show the reduction overhead directly.");
    println!("ablation_parallelism bench OK");
}
