"""AOT compile path: lower every serving artifact to HLO *text* and emit
the artifact manifest, the trained TinyNet model file, the synthetic
validation dataset, and golden outputs for the Rust runtime tests.

This is the only place python touches the system; ``make artifacts`` runs
it once and the Rust binary is self-contained afterwards.

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact naming: ``{net}_{mode}_b{batch}.hlo.txt``; every artifact's
function signature is ``fn(x_mm, w0, b0, w1, b1, ...) -> (logits,)`` with
parameters in ``model.param_order`` order, map-major layout, ``u = 4``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset as D
from . import model as M
from . import modelfile as MF
from . import train_tiny as T
from .kernels import ref

U = 4

# (net, mode, batch) triples lowered to artifacts. GoogLeNet imprecise is
# skipped by default to bound `make artifacts` time; pass --full to add it.
DEFAULT_ARTIFACTS = [
    ("tinynet", "precise", 1), ("tinynet", "precise", 4),
    ("tinynet", "precise", 8),
    ("tinynet", "imprecise", 1), ("tinynet", "imprecise", 4),
    ("tinynet", "imprecise", 8),
    ("squeezenet", "precise", 1), ("squeezenet", "imprecise", 1),
    ("alexnet", "precise", 1), ("alexnet", "imprecise", 1),
    ("googlenet", "precise", 1),
]
FULL_EXTRA = [("googlenet", "imprecise", 1)]

DATASET_N = 2560
DATASET_TRAIN = 2048
DATASET_SEED = 7
TRAIN_STEPS = 400


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def mm_input_shape(input_shape, batch, u=U):
    c, h, w = input_shape
    cb = -(-c // u)
    return (batch, cb, h, w, u)


def mm_param_shapes(spec, input_shape, u=U):
    """Map-major (w, b) shapes per layer name, in param order."""
    _, by_name = infer = M.infer_shapes(spec, input_shape)
    first_fc = M._first_dense_after_flatten(spec)
    flat = M._shape_before_flatten(spec, input_shape)
    shapes = []
    lookup = _layer_lookup(spec)
    for name in M.param_order(spec):
        lay = lookup[name]
        if lay["op"] == "conv":
            c = by_name[name][0]
            mb, cb = -(-lay["m"] // u), -(-c // u)
            shapes.append((name, (mb, u, cb, lay["k"], lay["k"], u), (mb, u)))
        else:
            i = by_name[name][0]
            if name == first_fc:
                c, h, w = flat
                i = -(-c // u) * u * h * w
            shapes.append((name, (lay["o"], i), (lay["o"],)))
    return shapes


def _layer_lookup(spec):
    out = {}

    def walk(lays):
        for lay in lays:
            if lay["op"] in ("conv", "dense"):
                out[lay["name"]] = lay
            elif lay["op"] == "fork":
                for br in lay["branches"]:
                    walk(br)

    walk(M.expand(spec))
    return out


def lower_artifact(net: str, mode: str, batch: int, out_dir: str, log=print):
    """Lower one (net, mode, batch) artifact; returns its manifest entry."""
    spec_fn, input_shape, n_classes = M.NETS[net]
    spec = spec_fn()
    apply = M.build_apply(spec, input_shape, U)
    pshapes = mm_param_shapes(spec, input_shape)
    order = [n for n, _, _ in pshapes]

    def fn(x, *flat):
        params = {name: (flat[2 * i], flat[2 * i + 1])
                  for i, name in enumerate(order)}
        return (apply(params, x, mode),)

    x_spec = jax.ShapeDtypeStruct(mm_input_shape(input_shape, batch),
                                  jnp.float32)
    arg_specs = [x_spec]
    for _, ws, bs in pshapes:
        arg_specs.append(jax.ShapeDtypeStruct(ws, jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct(bs, jnp.float32))

    name = f"{net}_{mode}_b{batch}"
    t0 = time.time()
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    log(f"  {name}: {len(text) / 1e6:.1f} MB HLO text "
        f"({time.time() - t0:.1f}s)")
    return {
        "name": name, "net": net, "mode": mode, "batch": batch,
        "hlo": f"{name}.hlo.txt",
        "input_shape": list(mm_input_shape(input_shape, batch)),
        "output_shape": [batch, n_classes],
        "params": [{"name": n, "w": list(ws), "b": list(bs)}
                   for n, ws, bs in pshapes],
    }


def export_spec(spec):
    """Primitive-expanded spec as JSON-friendly layer list for Rust."""
    def conv_json(lay):
        return {k: lay[k] for k in ("op", "name", "m", "k", "s", "p", "relu")}

    out = []
    for lay in M.expand(spec):
        op = lay["op"]
        if op == "conv":
            out.append(conv_json(lay))
        elif op == "fork":
            out.append({"op": "fork", "name": lay["name"], "branches": [
                [conv_json(l) if l["op"] == "conv" else dict(l)
                 for l in br] for br in lay["branches"]]})
        else:
            out.append(dict(lay))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--full", action="store_true",
                    help="also lower the optional (slow) artifacts")
    ap.add_argument("--only", default=None,
                    help="comma list of net names to lower (debugging)")
    args = ap.parse_args(argv)
    out = args.out
    os.makedirs(out, exist_ok=True)

    # 1. Dataset ----------------------------------------------------------
    print("[aot] generating synthetic dataset ...")
    images, labels = D.generate(DATASET_N, seed=DATASET_SEED)
    D.write_dataset(os.path.join(out, "dataset.bin"), images, labels,
                    DATASET_TRAIN)

    # 2. TinyNet training --------------------------------------------------
    print("[aot] training TinyNet ...")
    params = T.train(images[:DATASET_TRAIN], labels[:DATASET_TRAIN],
                     steps=TRAIN_STEPS)
    val_acc = T.accuracy(params, images[DATASET_TRAIN:],
                         labels[DATASET_TRAIN:])
    print(f"[aot] TinyNet val accuracy: {val_acc:.4f}")
    MF.write_modelfile(os.path.join(out, "tinynet.capp"),
                       MF.params_to_tensors(params))
    # Map-major reordered copy: lets Rust cross-check its own reorder.
    spec = M.tinynet_spec()
    pmm = M.reorder_params(spec, (D.C, D.H, D.W), params, U)
    MF.write_modelfile(os.path.join(out, "tinynet_mm.capp"),
                       MF.params_to_tensors(pmm))

    # 3. Golden outputs for the Rust runtime tests -------------------------
    apply = M.build_apply(spec, (D.C, D.H, D.W), U)
    val = images[DATASET_TRAIN: DATASET_TRAIN + 8]
    x_mm = jnp.stack([ref.nchw_to_mapmajor(jnp.asarray(v), U) for v in val])
    golden = {
        "x_mm": np.asarray(x_mm),
        "x_nchw": val,
        "labels": np.asarray(labels[DATASET_TRAIN: DATASET_TRAIN + 8],
                             np.float32).reshape(-1),
        "logits_precise": np.asarray(apply(pmm, x_mm, "precise")),
        "logits_relaxed": np.asarray(apply(pmm, x_mm, "relaxed")),
        "logits_imprecise": np.asarray(apply(pmm, x_mm, "imprecise")),
    }
    MF.write_modelfile(os.path.join(out, "golden_tinynet.capp"), golden)

    # 4. HLO artifacts ------------------------------------------------------
    triples = list(DEFAULT_ARTIFACTS) + (FULL_EXTRA if args.full else [])
    if args.only:
        keep = set(args.only.split(","))
        triples = [t for t in triples if t[0] in keep]
    print(f"[aot] lowering {len(triples)} artifacts ...")
    entries = [lower_artifact(net, mode, batch, out)
               for net, mode, batch in triples]

    # 5. Manifest ------------------------------------------------------------
    manifest = {
        "u": U,
        "dataset": {"file": "dataset.bin", "n": DATASET_N,
                    "n_train": DATASET_TRAIN,
                    "input_shape": [D.C, D.H, D.W],
                    "classes": D.NUM_CLASSES},
        "tinynet_val_accuracy": val_acc,
        "artifacts": entries,
        "nets": {
            net: {
                "input_shape": list(ishape),
                "classes": ncls,
                "layers": export_spec(spec_fn()),
            }
            for net, (spec_fn, ishape, ncls) in M.NETS.items()
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote manifest with {len(entries)} artifacts to {out}")


if __name__ == "__main__":
    main()
