//! # Cappuccino — CNN inference software synthesis for mobile SoCs
//!
//! Reproduction of *"Cappuccino: Efficient Inference Software Synthesis
//! for Mobile System-on-Chips"* (Motamedi, Fong, Ghiasi, 2017) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time Python): map-major vectorised convolution /
//!   dense Pallas kernels (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): the paper's three CNNs (AlexNet,
//!   SqueezeNet, GoogLeNet) plus TinyNet, lowered once to HLO text
//!   (`python/compile/aot.py` → `artifacts/`).
//! * **Layer 3** (this crate): the Cappuccino system itself — network
//!   description parsing, compile-time parameter reordering, the
//!   synthesizer, the inexact-computing analyzer, the native execution
//!   engine, a mobile-SoC simulator (the paper's testbed substitute),
//!   the PJRT runtime that executes the AOT artifacts, and a serving
//!   front-end. Python never runs on the request path.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | error type, PRNG, JSON, misc substrates |
//! | [`config`] | `.cappnet` network descriptions + `.capp` model files |
//! | [`model`] | layer IR, shape inference, FLOP counting, model zoo |
//! | [`layout`] | map-major reordering, packed tap-major / column-blocked weight panels, the paper's eqs. (3)–(5) |
//! | [`engine`] | native execution engine (OLP/KLP/FLP, vector modes) |
//! | [`engine::plan`] | batch-first compiled plans: `PlanBuilder` → `ExecutionPlan::run_batch`, `B x` buffer arena, baked+packed weights, per-layer conv tiles from an L1/L2 cost model, per-thread kernel scratch, flat step sequence |
//! | [`engine::schedule`] | Schedule IR — the one per-layer tuning surface (parallelism, packing, tiling, mode, placement, vector width + pool settings); every `PlanBuilder` setter lowers into it; serializes to the `schedule.json` artifact |
//! | [`engine::simd`] | explicit-width SIMD lanes (`f32x4`/`f32x8`, widening int8 dot) over `core::arch` intrinsics with a bitwise-identical scalar fallback; `CAPPUCCINO_SIMD=0` forces the fallback |
//! | [`engine::verify`] | static plan verifier — an effect system over the Step IR proving race-freedom, def-before-use + layout consistency, arena safety, mode/tile preconditions, and stage-cut soundness of staged plans before a plan ever runs; `cappuccino check`, typed `Error::Verify` |
//! | [`engine::hetero`] | heterogeneous staged execution: partitions a plan at schedule backend boundaries into per-backend stages joined by explicit `Transfer` wires, and runs them as an overlapping pipeline (one worker + bounded queue per stage) — bitwise identical to the uniform plan |
//! | [`engine::parallel`] | topology-aware persistent worker pool (per-cluster deques, idle-only stealing, batch-tagged scopes, cost-weighted placement) + thread workload allocation policies |
//! | [`engine::topology`] | CPU topology probe (sysfs `cpu_capacity`/packages, affinity-mask aware, uniform fallback), `sched_setaffinity` pinning, serve-worker `CoreSet`s |
//! | [`faults`] | deterministic fault injection: seeded, plan-addressable panic/error injection points (`CAPPUCCINO_FAULTS` / `serve --faults`), compiled to one atomic load when disabled |
//! | [`soc`] | mobile SoC simulator: latency + energy + CNNDroid models |
//! | [`data`] | synthetic validation dataset IO |
//! | [`metrics`] | latency histograms, throughput, energy accounting |
//! | [`synth`] | primary-program + software synthesizers (plans) |
//! | [`autotune`] | on-device schedule search: budgeted greedy tuner, warmup + median-of-N timed plan walks per candidate, `cappuccino tune` → `schedule.json` |
//! | [`inexact`] | per-layer arithmetic-mode analysis |
//! | [`runtime`] | PJRT artifact loading/execution (`xla` crate, vendoring patch in the module header) |
//! | [`runtime::backends`] | staged-execution backend registry: resolves a schedule's `BackendTarget` to a stage executor, incl. the deterministic mock accelerator (`CAPPUCCINO_MOCK_LATENCY`) |
//! | [`serve`] | production serve front-end: admission control, SLO deadlines, continuous batching, multi-model tenancy |
//! | [`serve::frontend`] | the request pipeline itself — typed rejections, drain-time admission, deadline-aware batch forming, lossless shutdown, and the per-tenant supervisor: contained-fault replies, capped-backoff worker respawn, poison-pill quarantine, fallback-schedule degradation |
//! | [`serve::tenancy`] | resident tenants from `schedule.json` artifacts: per-model plans, admission estimates, disjoint core partitions |
//! | [`serve::workload`] | arrival processes (incl. bounded-Pareto heavy tails) + the open-loop replay driver behind `serve --replay` |
//! | [`bench`] | in-repo micro-benchmark harness (criterion stand-in) |
//! | [`testing`] | in-repo property-testing helper (proptest stand-in) |

#![deny(unsafe_op_in_unsafe_fn)]

pub mod autotune;
pub mod bench;
pub mod config;
pub mod data;
pub mod engine;
pub mod faults;
pub mod inexact;
pub mod layout;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod soc;
pub mod synth;
pub mod testing;
pub mod util;

pub use util::error::{Error, Result};

/// The vector width used throughout the repo's artifacts (paper's `u`).
pub const DEFAULT_U: usize = 4;

/// Locate the `artifacts/` directory: `$CAPPUCCINO_ARTIFACTS` or the
/// crate-relative default.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("CAPPUCCINO_ARTIFACTS") {
        return p.into();
    }
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}
