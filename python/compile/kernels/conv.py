"""Layer-1 Pallas kernel: map-major vectorised direct convolution.

This is the paper's compute hot-spot (Fig. 6) re-thought for TPU-style
hardware (DESIGN.md section "Hardware-Adaptation"):

* The paper's ``u``-way SIMD superword loads become the trailing *lane*
  dimension of the map-major layout ``(Cb, H, W, u)``. One ``pl.load`` of
  a ``(..., u)`` block is the paper's single wide memory access.
* The paper's per-thread OLP workload (one thread = one output pixel,
  eqs. 3-5) becomes the Pallas grid: one program instance computes the
  output stack ``(mb, :, :, u)`` for one image — a stack of ``u`` OFMs,
  written directly in map-major order, i.e. the "zero-overhead dynamic
  reordering of OFMs" of section IV.B.1 holds by construction.
* The intra-thread vectorised MAC of Fig. 6 (load ``u`` IFM words +
  ``u`` kernel words, multiply-accumulate elementwise) is the einsum over
  the lane axis ``v`` in the inner loop below.

The kernel is lowered with ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so the interpret path is the
correctness (and artifact) path, and real-TPU performance is estimated
analytically in DESIGN.md from the BlockSpec VMEM footprint.

Arithmetic modes (section IV.C) are compile-time variants of the same
kernel: ``precise`` (IEEE f32), ``relaxed`` (f32, denormals flushed),
``imprecise`` (bf16 multiplicands, f32 accumulate, denormals flushed).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _mode_cast(x: jnp.ndarray, mode: str) -> jnp.ndarray:
    """In-kernel operand transform for the arithmetic mode."""
    if mode == "precise":
        return x
    flushed = jnp.where(jnp.abs(x) < ref.F32_MIN_NORMAL, 0.0, x) + 0.0
    if mode == "relaxed":
        return flushed
    if mode == "imprecise":
        return flushed.astype(jnp.bfloat16)
    raise ValueError(f"unknown arithmetic mode: {mode!r}")


def _conv_kernel(ifm_ref, w_ref, b_ref, o_ref, *, k: int, stride: int,
                 hout: int, wout: int, mode: str):
    """One grid step: image ``b``, output stack ``mb``.

    Block shapes (leading block dims of size 1 squeezed by indexing):

    * ``ifm_ref`` — ``(1, Cb, H, W, u)``   the whole padded input image
    * ``w_ref``   — ``(1, u, Cb, K, K, u)`` weights of the ``u`` OFMs in
                      this stack (dim 1 = output lane ``o``)
    * ``b_ref``   — ``(1, u)``              biases of the stack
    * ``o_ref``   — ``(1, 1, Hout, Wout, u)`` the output stack, map-major
    """
    ifm = _mode_cast(ifm_ref[0], mode)          # (Cb, H, W, u)
    w = _mode_cast(w_ref[0], mode)              # (u, Cb, K, K, u)
    bias = b_ref[0]                             # (u,)

    acc = jnp.zeros((hout, wout, w.shape[0]), dtype=jnp.float32)
    # Static K x K loop: each iteration is one vectorised MAC sweep of
    # Fig. 6 — a strided (h, w) window of every input stack against one
    # kernel tap, contracted over (input stack c, lane v).
    for kh in range(k):
        for kw in range(k):
            patch = ifm[:, kh: kh + (hout - 1) * stride + 1: stride,
                        kw: kw + (wout - 1) * stride + 1: stride, :]
            tap = w[:, :, kh, kw, :]            # (u_out, Cb, u_in)
            acc = acc + jnp.einsum(
                "chwv,ocv->hwo", patch, tap,
                preferred_element_type=jnp.float32)
    o_ref[0, 0] = acc + bias[None, None, :]


def conv2d_mapmajor(ifm: jnp.ndarray, w_mm: jnp.ndarray, b_mm: jnp.ndarray,
                    *, stride: int = 1, pad: int = 0,
                    mode: str = "precise") -> jnp.ndarray:
    """Map-major convolution via ``pl.pallas_call``.

    Args:
      ifm:  ``(B, Cb, H, W, u)`` map-major input feature maps.
      w_mm: ``(Mb, u, Cb, K, K, u)`` map-major reordered weights.
      b_mm: ``(Mb, u)`` biases.
      stride, pad: convolution stride and symmetric spatial zero-padding.
      mode: arithmetic mode — ``precise`` / ``relaxed`` / ``imprecise``.

    Returns:
      ``(B, Mb, Hout, Wout, u)`` map-major OFMs (f32).
    """
    if ifm.ndim != 5:
        raise ValueError(f"ifm must be (B, Cb, H, W, u), got {ifm.shape}")
    bsz, cb, h, wdim, u = ifm.shape
    mb, u_out, cb_w, k, k2, u_in = w_mm.shape
    if (cb_w, u_in) != (cb, u) or k != k2 or u_out != u:
        raise ValueError(f"weight shape {w_mm.shape} does not match ifm {ifm.shape}")
    if pad:
        ifm = jnp.pad(ifm, ((0, 0), (0, 0), (pad, pad), (pad, pad), (0, 0)))
        h, wdim = h + 2 * pad, wdim + 2 * pad
    hout = (h - k) // stride + 1
    wout = (wdim - k) // stride + 1
    if hout <= 0 or wout <= 0:
        raise ValueError(f"window k={k} stride={stride} too large for "
                         f"padded input {h}x{wdim}")

    kern = functools.partial(_conv_kernel, k=k, stride=stride,
                             hout=hout, wout=wout, mode=mode)
    return pl.pallas_call(
        kern,
        grid=(bsz, mb),
        in_specs=[
            pl.BlockSpec((1, cb, h, wdim, u), lambda b, m: (b, 0, 0, 0, 0)),
            pl.BlockSpec((1, u, cb, k, k, u), lambda b, m: (m, 0, 0, 0, 0, 0)),
            pl.BlockSpec((1, u), lambda b, m: (m, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hout, wout, u),
                               lambda b, m: (b, m, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, mb, hout, wout, u), jnp.float32),
        interpret=True,
    )(ifm, w_mm, b_mm)


def conv2d_mapmajor_single(ifm: jnp.ndarray, w_mm: jnp.ndarray,
                           b_mm: jnp.ndarray, **kw) -> jnp.ndarray:
    """Unbatched convenience wrapper: ``(Cb,H,W,u) -> (Mb,Hout,Wout,u)``."""
    return conv2d_mapmajor(ifm[None], w_mm, b_mm, **kw)[0]


def vmem_footprint_bytes(ifm_shape, w_shape, out_shape) -> int:
    """Estimated VMEM bytes one grid step holds resident (DESIGN.md perf).

    interpret=True gives no hardware numbers; this is the analytic
    footprint of the BlockSpecs above: one input image + one weight stack
    + one output stack, all f32.
    """
    per = 4  # f32
    n_in = math.prod(ifm_shape[1:])
    n_w = math.prod(w_shape[1:])
    n_out = math.prod(out_shape[1:])
    return per * (n_in + n_w + n_out)
