//! Ablation: map-major layout + u-way vectorised MAC vs conventional
//! row-major scalar execution (paper section IV.B).
//!
//! Sweeps the vector width u over {1, 2, 4, 8, 16} on a fixed conv
//! layer: u=1 map-major degenerates to scalar-with-reordered-layout, so
//! the sweep isolates the superword-MAC benefit from the layout change
//! itself. Also reports the row-major scalar reference.

use cappuccino::bench::{bench, ms, BenchConfig, Table};
use cappuccino::engine::{cast_weights, conv_mm, conv_nchw_scalar, ArithMode, MapTensor};
use cappuccino::layout;
use cappuccino::util::rng::Rng;

fn main() {
    let cfg = BenchConfig::from_env();
    let mut rng = Rng::new(0x1A10);
    // Mid-network geometry: plenty of channels for lane fill.
    let (c, h, w, m, k, s, p) = (64usize, 28usize, 28usize, 64usize, 3usize, 1usize, 1usize);
    let input = rng.normal_vec(c * h * w);
    let weights = rng.normal_vec(m * c * k * k);
    let bias = rng.normal_vec(m);

    let scalar = bench("rowmajor-scalar", cfg, || {
        std::hint::black_box(conv_nchw_scalar(
            &input, c, h, w, &weights, &bias, m, k, s, p, true, ArithMode::Precise,
        ));
    });

    let mut table = Table::new(&["layout", "u", "time(ms)", "vs row-major"]);
    table.row(&[
        "row-major scalar".into(),
        "-".into(),
        ms(scalar.mean_ms),
        "1.00x".into(),
    ]);

    let mut best_u = 1;
    let mut best_ms = f64::INFINITY;
    for u in [1usize, 2, 4, 8, 16] {
        let mm_in = MapTensor::from_nchw(&input, c, h, w, u);
        // Weights baked into the imprecise domain once, compile-time.
        let w_mm = cast_weights(
            &layout::weights_to_mapmajor(&weights, m, c, k, u),
            ArithMode::Imprecise,
        );
        let b_mm = layout::bias_to_mapmajor(&bias, u);
        let meas = bench(format!("mm-u{u}"), cfg, || {
            std::hint::black_box(conv_mm(
                &mm_in, &w_mm, &b_mm, m, k, s, p, true, ArithMode::Imprecise, 1,
            ));
        });
        if meas.mean_ms < best_ms {
            best_ms = meas.mean_ms;
            best_u = u;
        }
        table.row(&[
            "map-major".into(),
            u.to_string(),
            ms(meas.mean_ms),
            format!("{:.2}x", scalar.mean_ms / meas.mean_ms),
        ]);
    }

    println!("# Ablation — data layout & vector width (sec IV.B)\n");
    table.print();
    println!("\nbest u = {best_u} ({:.2}x over row-major scalar)", scalar.mean_ms / best_ms);
    println!("(the paper's RenderScript target has 4-lane NEON vectors; on this");
    println!("host the autovectorised u-wide MAC plays the same role)");

    // Structural invariant: some u must beat the scalar reference.
    assert!(
        best_ms < scalar.mean_ms,
        "map-major vectorisation never beat scalar ({best_ms:.2} vs {:.2})",
        scalar.mean_ms
    );
    println!("ablation_layout bench OK");
}
