//! Serving layer: admission control → batch forming → worker execution
//! over pluggable backends.
//!
//! Cappuccino synthesizes *inference software*; this module is the
//! deployment harness around it — one engine, a thin app-facing
//! protocol surface, shaped as a three-stage pipeline (the detailed
//! contract lives in [`frontend`]):
//!
//! 1. **Admission** ([`Router`], in [`frontend`]) — requests name a
//!    model and optionally carry a deadline (explicit or via a named
//!    SLO class). Each tenant's admission controller predicts queue
//!    drain time from the model's analytic latency estimate
//!    ([`crate::synth::predict_latency_ms`] via its loaded `Schedule`)
//!    and load-sheds infeasible requests as typed
//!    [`Rejected::DeadlineInfeasible`] before they occupy queue space;
//!    full bounded queues shed as [`Rejected::QueueFull`].
//! 2. **Batch forming** (continuous batching) — each worker admits
//!    arrivals into the currently *forming* batch up to a size/time
//!    budget ([`BatchPolicy`]), closing early when the oldest member's
//!    deadline slack is about to expire. A formed batch executes as
//!    **one** backend call at the smallest adequate AOT capacity; the
//!    native engine backend runs only live rows of a partial batch,
//!    the PJRT backend zero-pads to capacity and truncates replies.
//! 3. **Workers** — one thread per tenant, owning the execution
//!    backend. PJRT objects are not `Send`, so backends are constructed
//!    *on* the worker thread from a `Send` factory; weights stay
//!    resident across requests. Co-hosted tenants get **disjoint**
//!    [`CoreSet`]s ([`crate::engine::Topology::partition`]) so they
//!    stop trampling each other's caches — queue, admission window,
//!    worker, and cores are all per-tenant (one model's congestion
//!    never delays another's requests).
//!
//! **Backpressure contract**: a submit either returns a reply channel —
//! and that request **will** be answered, shutdown included (workers
//! drain accepted work past the shutdown signal) — or a typed
//! [`Error::Rejected`](crate::util::error::Error::Rejected) naming the
//! reason. Nothing buffers without bound; nothing admitted is dropped.
//!
//! ## Failure model
//!
//! A production tenant must survive its own backend. The failure model
//! assumes any `infer_batch` call can fail — a contained panic
//! surfacing as [`Error::TaskPanicked`](crate::util::error::Error),
//! a typed error, or an injected fault from [`crate::faults`] — and
//! guarantees, via the per-tenant **supervisor** in [`frontend`]:
//!
//! * **No silent drops, ever.** Every member of a faulted batch gets a
//!   reply: a retried success, or a typed
//!   [`Rejected::Fault`] quarantine answer. The admission window is
//!   released exactly once per request either way, so pending counts
//!   stay exact across faults.
//! * **Poison-pill isolation.** Members of a faulted batch are retried
//!   as singleton batches (budgeted per request); a request that faults
//!   alone is quarantined instead of taking fresh neighbours down with
//!   it on every retry.
//! * **Respawn with capped backoff.** After a fault the worker rebuilds
//!   its backend from the tenant's factory (factories are `Fn`, not
//!   `FnOnce`, exactly so they can be re-invoked); factory failures
//!   back off exponentially up to a cap, and a factory that never
//!   recovers drains the queue with `Rejected::Fault` replies before
//!   the worker exits — still no silent drops.
//! * **Degradation and recovery.** Repeated faults inside a window
//!   degrade the tenant to its optional fallback schedule
//!   (`serve --fallback-schedule`); a fault-free window restores the
//!   primary and records the degraded interval in
//!   [`crate::metrics::FaultStats`].
//!
//! Tenants fail independently: supervision state, backend, queue, and
//! fault counters are all per-tenant, so one model's chaos never
//! perturbs another's replies (the shared engine pool contains worker
//! panics without poisoning itself — see [`crate::engine::parallel`]).
//!
//! [`tenancy`] builds multi-model [`Tenant`] sets from `schedule.json`
//! artifacts; [`workload`] generates arrival traces and replays them
//! for latency-under-load measurement. Python never appears anywhere on
//! this path.

pub mod frontend;
pub mod tenancy;
pub mod workload;

pub use frontend::{
    Rejected, RequestOptions, Router, Server, ServeRequest, ServeResponse, SloClass, SloTable,
    SupervisorPolicy, Tenant, TenantInfo,
};
pub use tenancy::{build_engine_tenants, parse_models, TenancyConfig, TenantSpec};
pub use workload::{replay, ArrivalProcess, ReplayOutcome, ReplaySpec};

pub use crate::engine::topology::CoreSet;

use std::sync::atomic::Ordering;

use crate::metrics::{LatencyByClass, LatencyHistogram, ServeCounters, Throughput};
use crate::util::error::{Error, Result};

/// Execution backend run by a worker thread.
pub trait Backend {
    /// Expected per-image input element count.
    fn input_len(&self) -> usize;
    /// AOT-available batch capacities, ascending (native backends may
    /// return any set; `[1]` means no batching).
    fn batch_sizes(&self) -> &[usize];
    /// Run a batch (`images.len() <= capacity`) at the given capacity;
    /// returns one logits row per input image.
    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>>;
}

/// Factory constructing a backend *on* the worker thread (PJRT is not
/// `Send`). `Fn`, not `FnOnce`: the supervisor re-invokes it to respawn
/// a backend after a contained fault.
pub type BackendFactory = Box<dyn Fn() -> Result<Box<dyn Backend>> + Send>;

/// Batch-forming policy (plus the worker's placement request).
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Upper bound on batch size (further capped by the backend).
    pub max_batch: usize,
    /// Time budget of a forming batch: how long it stays open for more
    /// requests after the first arrives (deadline slack can close it
    /// earlier; see [`frontend`]).
    pub max_delay: std::time::Duration,
    /// Bound of the per-model request queue (backpressure limit).
    pub queue_depth: usize,
    /// Optional core set the model's worker thread is pinned to
    /// (`sched_setaffinity`; silently a no-op off Linux or when the
    /// kernel rejects the mask). Co-hosted models should request
    /// **disjoint** sets — [`crate::engine::Topology::partition`] hands
    /// them out. With `threads = 1` the whole inference runs inline on
    /// the pinned worker thread; multi-chunk parallel regions still run
    /// on the shared engine pool.
    pub cores: Option<CoreSet>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(2),
            queue_depth: 64,
            cores: None,
        }
    }
}

/// Shared serving metrics.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub counters: ServeCounters,
    pub latency: LatencyHistogram,
    /// Latency broken out per SLO class ("default" for untagged).
    pub by_class: LatencyByClass,
    pub throughput: Throughput,
    /// Per-tenant fault-tolerance counters (supervisor-fed).
    pub faults: crate::metrics::FaultRegistry,
}

impl ServeMetrics {
    /// Metrics with per-class latency slots for the given SLO classes.
    pub fn with_classes(names: &[String]) -> ServeMetrics {
        ServeMetrics { by_class: LatencyByClass::with_classes(names), ..Default::default() }
    }

    pub fn summary(&self) -> String {
        let c = &self.counters;
        let mut s = format!(
            "requests={} completed={} rejected={} (queue_full={} deadline={} unknown_model={} \
             other={}) deadline_met={} deadline_missed={} batches={} mean_batch={:.2} rps={:.1} \
             latency[{}]",
            c.requests.load(Ordering::Relaxed),
            c.completed.load(Ordering::Relaxed),
            c.rejected.load(Ordering::Relaxed),
            c.rejected_queue_full.load(Ordering::Relaxed),
            c.rejected_deadline.load(Ordering::Relaxed),
            c.rejected_unknown_model.load(Ordering::Relaxed),
            c.rejected_other.load(Ordering::Relaxed),
            c.deadline_met.load(Ordering::Relaxed),
            c.deadline_missed.load(Ordering::Relaxed),
            c.batches.load(Ordering::Relaxed),
            c.mean_batch_size(),
            self.throughput.per_second(),
            self.latency.summary(),
        );
        let classes = self.by_class.summary();
        if !classes.is_empty() {
            s.push_str(" classes[");
            s.push_str(&classes);
            s.push(']');
        }
        let faults = self.faults.summary();
        if !faults.is_empty() {
            s.push_str(" faults[");
            s.push_str(&faults);
            s.push(']');
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Backends
// ---------------------------------------------------------------------------

/// Native-engine backend configuration (no artifacts needed). The
/// factory builds one batch-capacity [`crate::engine::ExecutionPlan`]
/// per AOT batch size on the worker thread (baked weights `Arc`-shared
/// across capacities via
/// [`crate::engine::ExecutionPlan::with_capacity`] — parameters are
/// never duplicated), so weights and the `B x`-sized buffer arenas stay
/// resident across requests — the native analogue of the PJRT backend's
/// device-resident executables. A formed batch executes as **one** plan
/// walk ([`crate::engine::ExecutionPlan::run_batch`]), not a per-image
/// loop; partial batches only walk live rows.
pub struct EngineBackend {
    net: crate::model::Network,
    params: crate::engine::EngineParams,
    modes: crate::engine::ModeAssignment,
    threads: usize,
    /// Explicit per-layer schedule (a `schedule.json` artifact from
    /// `cappuccino tune`); `None` lowers the uniform modes/threads
    /// configuration. Either way plan compilation goes through the one
    /// [`crate::engine::Schedule`] surface.
    schedule: Option<crate::engine::Schedule>,
    batches: Vec<usize>,
    input_len: usize,
}

impl EngineBackend {
    pub fn new(
        net: crate::model::Network,
        params: crate::engine::EngineParams,
        modes: crate::engine::ModeAssignment,
        threads: usize,
        max_batch: usize,
    ) -> Self {
        let input_len = net.input.elements();
        EngineBackend {
            net,
            params,
            modes,
            threads,
            schedule: None,
            batches: (0..).map(|i| 1 << i).take_while(|&b| b <= max_batch.max(1)).collect(),
            input_len,
        }
    }

    /// Serve a tuned schedule artifact: per-layer parallelism, packing,
    /// tiling, modes, and the pool settings all come from `schedule`
    /// (validated against the net at worker startup). This is the
    /// `serve --schedule schedule.json` path — the configuration
    /// measured by `cappuccino tune` runs unchanged in production.
    pub fn with_schedule(
        net: crate::model::Network,
        params: crate::engine::EngineParams,
        schedule: crate::engine::Schedule,
        max_batch: usize,
    ) -> Self {
        let modes = schedule.mode_assignment();
        let threads = schedule.pool.threads;
        let mut backend = EngineBackend::new(net, params, modes, threads, max_batch);
        backend.schedule = Some(schedule);
        backend
    }

    /// Factory for [`Server::start`]: plan compilation happens on the
    /// worker thread (mirroring the PJRT startup path) and failures
    /// propagate through the server's startup channel. The network is
    /// compiled **once** at the largest capacity; every other capacity
    /// is derived with `with_capacity`, sharing the baked weights.
    /// Re-invocable: a supervisor respawn recompiles from the same
    /// retained configuration.
    ///
    /// A schedule placing layers on more than one backend
    /// ([`crate::engine::Schedule::is_staged`]) transparently serves
    /// through the staged pipeline instead: the plan is partitioned at
    /// its backend boundaries ([`crate::engine::StagedPlan`]) and a
    /// [`crate::engine::Pipeline`] worker set is spun up, with the
    /// mock backend's latency model taken from `CAPPUCCINO_MOCK_LATENCY`
    /// ([`crate::runtime::backends::BackendRegistry::from_env`]). The
    /// replies stay bitwise identical to the uniform single-backend
    /// plan.
    pub fn factory(self) -> BackendFactory {
        Box::new(move || {
            let max_capacity = self.batches.last().copied().unwrap_or(1);
            // Either way the builder lowers into the one Schedule
            // surface; an explicit artifact is applied verbatim, the
            // uniform configuration through the fluent sugar.
            let mut builder = crate::engine::PlanBuilder::new(&self.net, &self.params)
                .modes(&self.modes)
                .threads(self.threads)
                .batch(max_capacity);
            if let Some(s) = self.schedule.clone() {
                builder = builder.schedule(s);
            }
            let base = builder.build()?;
            if self.schedule.as_ref().is_some_and(|s| s.is_staged()) {
                let staged = crate::engine::StagedPlan::from_plan(&base)?;
                let registry = crate::runtime::backends::BackendRegistry::from_env()?;
                let pipeline = crate::engine::Pipeline::new(&staged, &registry, 2)?;
                return Ok(Box::new(PipelinedEngineBackend {
                    pipeline,
                    batches: vec![max_capacity],
                    input_len: self.input_len,
                }) as Box<dyn Backend>);
            }
            // Derive the smaller capacities, then reuse `base` as the
            // largest — no throwaway duplicate of the biggest arena.
            let smaller = self.batches.len().saturating_sub(1);
            let mut plans: Vec<crate::engine::ExecutionPlan> = self.batches[..smaller]
                .iter()
                .map(|&b| base.with_capacity(b))
                .collect();
            plans.push(base);
            Ok(Box::new(CompiledEngineBackend {
                plans,
                batches: self.batches.clone(),
                input_len: self.input_len,
            }) as Box<dyn Backend>)
        })
    }
}

/// The worker-resident form of [`EngineBackend`]: compiled plans only.
struct CompiledEngineBackend {
    plans: Vec<crate::engine::ExecutionPlan>,
    batches: Vec<usize>,
    input_len: usize,
}

impl Backend for CompiledEngineBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>> {
        let idx = self
            .batches
            .iter()
            .position(|&b| b == capacity)
            .unwrap_or(self.batches.len().saturating_sub(1));
        let plan = self
            .plans
            .get_mut(idx)
            .ok_or_else(|| Error::Serve("engine backend has no compiled plans".into()))?;
        // Injection point at the serve/engine boundary: an `err:backend`
        // spec exercises the supervisor's fault-reply path without going
        // through plan-step containment; `panic:backend` exercises the
        // worker-side catch_unwind.
        match crate::faults::check("backend") {
            Some(crate::faults::FaultKind::Err) => {
                return Err(Error::Serve("injected error at serve backend".into()));
            }
            Some(crate::faults::FaultKind::Panic) => panic!("injected fault at backend"),
            None => {}
        }
        // One plan walk for the whole formed batch: only the
        // `images.len() <= capacity` live rows are computed, so padded
        // lanes can never surface stale or duplicated data in replies.
        plan.run_batch(images)
    }
}

/// The worker-resident form of a **staged** [`EngineBackend`]: a
/// multi-backend schedule served through the overlapping stage pipeline
/// ([`crate::engine::Pipeline`]). One capacity — partial batches run
/// live rows only, like the flat engine backend. The worker's
/// synchronous `infer_batch` submits and waits, so cross-*batch*
/// overlap comes from the continuous batcher keeping the worker fed;
/// the pipeline's lossless drop doubles as the drain path on respawn.
struct PipelinedEngineBackend {
    pipeline: crate::engine::Pipeline,
    batches: Vec<usize>,
    input_len: usize,
}

impl Backend for PipelinedEngineBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(&mut self, images: &[&[f32]], _capacity: usize) -> Result<Vec<Vec<f32>>> {
        // Same serve/engine-boundary injection point as the flat
        // backend, so `err:backend` / `panic:backend` chaos specs
        // exercise staged tenants identically.
        match crate::faults::check("backend") {
            Some(crate::faults::FaultKind::Err) => {
                return Err(Error::Serve("injected error at serve backend".into()));
            }
            Some(crate::faults::FaultKind::Panic) => panic!("injected fault at backend"),
            None => {}
        }
        self.pipeline.infer_batch(images)
    }
}

/// PJRT backend: one compiled executable per AOT batch size, weights
/// device-resident. Constructed on the worker thread via
/// [`pjrt_factory`].
pub struct PjrtBackend {
    models: Vec<crate::runtime::LoadedModel>, // ascending batch
    batches: Vec<usize>,
    c: usize,
    h: usize,
    w: usize,
    u: usize,
}

impl Backend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.c * self.h * self.w
    }

    fn batch_sizes(&self) -> &[usize] {
        &self.batches
    }

    fn infer_batch(&mut self, images: &[&[f32]], capacity: usize) -> Result<Vec<Vec<f32>>> {
        let idx = self
            .batches
            .iter()
            .position(|&b| b == capacity)
            .ok_or_else(|| Error::Serve(format!("no artifact with batch {capacity}")))?;
        let model = &self.models[idx];
        let x = crate::runtime::batch_to_mapmajor(images, self.c, self.h, self.w, self.u, capacity);
        let rows = model.infer_rows(&x)?;
        Ok(rows.into_iter().take(images.len()).collect())
    }
}

/// Build a PJRT backend factory for `(net, mode)` using every batch size
/// in the manifest.
pub fn pjrt_factory(
    artifacts_dir: std::path::PathBuf,
    net: String,
    mode: String,
    source_seed: Option<u64>,
) -> BackendFactory {
    Box::new(move || {
        let manifest = crate::runtime::Manifest::load(&artifacts_dir)?;
        let network = manifest
            .nets
            .get(&net)
            .ok_or_else(|| Error::Invalid(format!("manifest has no net {net:?}")))?;
        let (c, h, w) = network.input.as_maps()?;
        let runtime = crate::runtime::Runtime::new()?;
        let source = match source_seed {
            Some(seed) => crate::runtime::ParamSource::Random(seed),
            None => crate::runtime::ParamSource::MapMajorFile(
                crate::config::ModelFile::read_from(
                    artifacts_dir.join(format!("{net}_mm.capp")),
                )?,
            ),
        };
        let batches = manifest.batch_sizes(&net, &mode);
        if batches.is_empty() {
            return Err(Error::Invalid(format!("no artifacts for {net}/{mode}")));
        }
        let mut models = Vec::new();
        for &b in &batches {
            let spec = manifest.find(&net, &mode, b)?;
            models.push(runtime.load(&manifest, spec, &source)?);
        }
        Ok(Box::new(PjrtBackend { models, batches, c, h, w, u: manifest.u }) as Box<dyn Backend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ArithMode, EngineParams, ModeAssignment};
    use crate::model::zoo;
    use crate::util::rng::Rng;

    #[test]
    fn partial_batch_at_capacity_matches_single_image_runs() {
        // Regression (batch-first redesign): a 3-request batch executed
        // at capacity 8 must reply with each request's own logits —
        // padded lanes (and stale rows from earlier full batches) must
        // never reach a reply. Exercised directly against the backend so
        // the capacity is pinned rather than left to the batcher's
        // smallest-adequate choice.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 11, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let backend =
            EngineBackend::new(net.clone(), params.clone(), modes.clone(), 2, 8);
        let mut backend = (backend.factory())().unwrap();
        assert_eq!(backend.batch_sizes().last(), Some(&8));

        let mut rng = Rng::new(12);
        let images: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(3 * 16 * 16)).collect();
        let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
        // Prime every lane with a full batch, then run the partial one:
        // whatever the full batch left behind must not leak.
        let full = backend.infer_batch(&refs, 8).unwrap();
        assert_eq!(full.len(), 8);
        let partial = backend.infer_batch(&refs[..3], 8).unwrap();
        assert_eq!(partial.len(), 3, "one reply per live request, none for padding");

        // Oracle: fresh single-image plans.
        let mut single = crate::engine::PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(2)
            .build()
            .unwrap();
        for (i, row) in partial.iter().enumerate() {
            assert_eq!(row, &single.run(&images[i]).unwrap(), "lane {i} leaked");
        }
    }

    #[test]
    fn schedule_backend_matches_uniform_backend() {
        // A serve worker fed a schedule artifact must produce bitwise
        // the logits of the equivalent uniform-setter backend — the
        // tune → serve artifact path cannot perturb numerics.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 21, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let uniform = EngineBackend::new(net.clone(), params.clone(), modes.clone(), 2, 4);
        let mut uniform = (uniform.factory())().unwrap();
        let sched = crate::engine::Schedule::from_uniform(
            &net,
            4,
            &modes,
            crate::engine::Parallelism::Olp,
            true,
            None,
            crate::engine::PoolSettings { threads: 2, affinity: false, cores: None },
        )
        .unwrap();
        let scheduled = EngineBackend::with_schedule(net, params, sched, 4);
        let mut scheduled = (scheduled.factory())().unwrap();
        let mut rng = Rng::new(22);
        let imgs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(3 * 16 * 16)).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            uniform.infer_batch(&refs, 4).unwrap(),
            scheduled.infer_batch(&refs, 4).unwrap()
        );
    }

    #[test]
    fn staged_schedule_backend_matches_uniform_backend() {
        // A schedule splitting layers across backends must serve
        // through the pipelined backend — and still reply bitwise the
        // uniform backend's logits, partial batches included.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 31, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let uniform = EngineBackend::new(net.clone(), params.clone(), modes.clone(), 2, 4);
        let mut uniform = (uniform.factory())().unwrap();
        let mut sched = crate::engine::Schedule::from_uniform(
            &net,
            4,
            &modes,
            crate::engine::Parallelism::Olp,
            true,
            None,
            crate::engine::PoolSettings { threads: 2, affinity: false, cores: None },
        )
        .unwrap();
        sched.layers.get_mut("conv2").unwrap().backend = crate::engine::BackendTarget::Mock;
        assert!(sched.is_staged());
        let staged = EngineBackend::with_schedule(net, params, sched, 4);
        let mut staged = (staged.factory())().unwrap();
        assert_eq!(staged.batch_sizes(), &[4], "pipelined backend serves one capacity");
        let mut rng = Rng::new(32);
        let imgs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(3 * 16 * 16)).collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        assert_eq!(
            uniform.infer_batch(&refs, 4).unwrap(),
            staged.infer_batch(&refs, 4).unwrap()
        );
        // Partial batch through the pipeline: live rows only.
        assert_eq!(
            uniform.infer_batch(&refs[..3], 4).unwrap(),
            staged.infer_batch(&refs[..3], 4).unwrap()
        );
    }

    #[test]
    fn summary_includes_class_breakdown_when_present() {
        let m = ServeMetrics::with_classes(&["gold".to_string()]);
        m.by_class
            .record(Some("gold"), std::time::Duration::from_millis(3));
        let s = m.summary();
        assert!(s.contains("classes["), "{s}");
        assert!(s.contains("gold"), "{s}");
        // Untagged metrics keep the bare format.
        let bare = ServeMetrics::default().summary();
        assert!(!bare.contains("classes["), "{bare}");
    }
}
