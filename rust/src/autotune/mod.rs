//! On-device schedule autotuning — search the per-layer tuning surface
//! with real micro-benchmarks.
//!
//! Cappuccino's analytic models ([`crate::engine::conv::ConvTiling::choose`],
//! [`crate::synth::predict_latency_ms`]) pick good defaults, but
//! heterogeneous mobile silicon rewards *measuring*: the fastest
//! per-layer configuration differs across SoCs and even across layers
//! of one network. [`tune`] runs a budgeted greedy search over the
//! [`Schedule`] IR on the machine it executes on:
//!
//! 1. **Seed** — the search starts from the analytic defaults (every
//!    layer OLP + packed + cost-model tiles) and visits layers in
//!    descending analytic-FLOP order (the same cost model that feeds
//!    the SoC predictor), so a small budget is spent where the model
//!    says the time goes.
//! 2. **Pool stage** — candidate pool-chunk counts (powers of two up to
//!    [`TuneConfig::max_threads`]) are timed and the best kept.
//! 3. **Per-layer stage** — for each conv layer: row-tile variants
//!    around the cost model's choice, unpacked weights, and the FLP/KLP
//!    allocation policies; for each dense layer: unpacked weights. Both
//!    also try the PR-6 kernel knobs: `vector_width = 1` (force the
//!    scalar row kernels — occasionally faster on narrow layers) and
//!    the quantized int8 kernels ([`ArithMode::QuantI8`], packed OLP
//!    only, withheld for widths that cannot be lane-padded). Every
//!    candidate plan is compiled and timed for real — warmup walks, then
//!    median of [`TuneConfig::reps`] timed [`run_batch`] walks — and a
//!    candidate must beat the incumbent by >1% to be adopted (hysteresis
//!    against timer noise).
//!
//! 4. **Backend-split stage** (opt-in via [`TuneConfig::backends`]) —
//!    with two backend targets given (e.g. `native,mock`), every
//!    net-order cut point is tried: the first *k* layers on the first
//!    backend, the rest on the second. Each candidate is compiled,
//!    partitioned into a staged plan
//!    ([`crate::engine::hetero::StagedPlan`]), statically verified
//!    (stage-cut rules included), and its stages timed for real on
//!    their resolved executors. The score is the **bottleneck stage's**
//!    time — the pipeline throughput model: with stages overlapping,
//!    steady-state cost per batch is `max` over stages, not the sum —
//!    and a split only wins if its bottleneck beats the flat walk.
//!
//! The **f32** arithmetic modes are **not** searched: they change
//! numerics, and belong to the accuracy-gated analysis in
//! [`crate::inexact`]. Pass the chosen assignment in
//! [`TuneConfig::modes`]; the tuner preserves it. The one exception is
//! [`ArithMode::QuantI8`], offered as a per-layer *speed* candidate
//! (int8 panels quarter the weight traffic, so it is often the
//! fastest path); a schedule that adopted it should still clear the
//! tolerance gate (`inexact::evaluate_accuracy`) before serving.
//!
//! The result is a [`TuneReport`] whose [`Schedule`] serializes to
//! `schedule.json` (`cappuccino tune --out schedule.json`) and feeds
//! straight into `cappuccino serve --schedule` or
//! [`crate::engine::PlanBuilder::schedule`] — the measured software
//! configuration as a durable artifact.
//!
//! [`run_batch`]: crate::engine::ExecutionPlan::run_batch

use std::collections::HashMap;
use std::time::Instant;

use crate::engine::conv::ConvTiling;
use crate::engine::network::ModeAssignment;
use crate::engine::parallel::Parallelism;
use crate::engine::hetero::StagedPlan;
use crate::engine::schedule::{BackendTarget, LayerSchedule, PoolSettings, Schedule};
use crate::engine::{ArithMode, EngineParams, PlanBuilder};
use crate::runtime::backends::BackendRegistry;
use crate::model::{shapes, LayerOp, Network};
use crate::synth::{predict_latency_ms, SynthesisPlan};
use crate::util::ceil_div;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Autotuning configuration.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Batch capacity the schedule is tuned for (and the batch each
    /// timed walk executes).
    pub batch: usize,
    /// Largest pool-chunk count tried (powers of two from 1).
    pub max_threads: usize,
    /// Untimed warmup walks per candidate.
    pub warmup: usize,
    /// Timed walks per candidate; the median is the candidate's score.
    pub reps: usize,
    /// Hard cap on timed candidate measurements (the seed measurement
    /// included) — the CI smoke budget is single digits, a real tuning
    /// run tens to hundreds.
    pub budget: usize,
    /// Per-layer arithmetic modes to preserve (from [`crate::inexact`]
    /// or the paper's all-imprecise outcome). Not searched.
    pub modes: ModeAssignment,
    /// Seed for the synthetic timing inputs.
    pub seed: u64,
    /// Backend targets for the opt-in split search (stage 4): empty
    /// disables it; with two entries every net-order cut between them
    /// is tried (`cappuccino tune --backends native,mock`). The mock
    /// executor's latency model comes from `CAPPUCCINO_MOCK_LATENCY`.
    pub backends: Vec<BackendTarget>,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            batch: 8,
            max_threads: 4,
            warmup: 2,
            reps: 5,
            budget: 64,
            modes: ModeAssignment::uniform(ArithMode::Imprecise),
            seed: 0xCAFE,
            backends: Vec::new(),
        }
    }
}

/// One timed candidate.
#[derive(Debug, Clone)]
pub struct Trial {
    /// Layer name, or `"(pool)"` for the pool stage.
    pub layer: String,
    /// Human-readable candidate description (e.g. `tile tm=4 th=8`).
    pub candidate: String,
    pub median_ms: f64,
    /// Did this candidate become the incumbent?
    pub accepted: bool,
}

/// The autotuner's output: the tuned schedule plus the evidence.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub schedule: Schedule,
    /// Median walk time of the analytic-default schedule (the seed).
    pub default_ms: f64,
    /// Median walk time of the tuned schedule.
    pub tuned_ms: f64,
    /// Timed measurements actually spent (<= budget).
    pub measurements: usize,
    pub trials: Vec<Trial>,
    /// SoC-model prediction for the tuned schedule on the first catalog
    /// device (via the [`SynthesisPlan`] bridge), for comparison against
    /// the measured numbers.
    pub predicted_ms: Option<f64>,
    /// Candidates the plan compiler or the static verifier
    /// ([`crate::engine::verify`]) rejected before any timing —
    /// `"layer candidate: error"` lines. A rejection costs no budget
    /// and is evidence, not a failure: the tuner must never time (let
    /// alone emit) a schedule that does not verify.
    pub rejected: Vec<String>,
}

impl TuneReport {
    /// Measured end-to-end speedup of tuned over the analytic defaults.
    pub fn speedup(&self) -> f64 {
        self.default_ms / self.tuned_ms
    }
}

/// A candidate must beat the incumbent by >1% to be adopted.
const ACCEPT_RATIO: f64 = 0.99;

/// Per-conv-layer geometry the candidate generator needs.
struct LayerGeom {
    name: String,
    /// `None` for dense layers.
    conv: Option<ConvGeom>,
    /// Analytic FLOPs (search-order key).
    flops: f64,
}

struct ConvGeom {
    c: usize,
    w: usize,
    m: usize,
    ho: usize,
    k: usize,
    s: usize,
    p: usize,
}

fn layer_geometry(net: &Network) -> Result<Vec<LayerGeom>> {
    let info = shapes::infer(net)?;
    let mut conv_ops: HashMap<String, (usize, usize, usize)> = HashMap::new();
    net.visit(&mut |l| {
        if let LayerOp::Conv { k, s, p, .. } = l.op {
            conv_ops.insert(l.name.clone(), (k, s, p));
        }
    });
    let flops: HashMap<&str, f64> =
        info.costs.iter().map(|c| (c.name.as_str(), c.flops)).collect();
    let mut out = Vec::new();
    for pl in &info.param_layers {
        let conv = match conv_ops.get(&pl.name) {
            Some(&(k, s, p)) => {
                let (c, _, w) = pl.input.as_maps()?;
                let (m, ho, _) = pl.output.as_maps()?;
                Some(ConvGeom { c, w, m, ho, k, s, p })
            }
            None => None,
        };
        out.push(LayerGeom {
            name: pl.name.clone(),
            conv,
            flops: flops.get(pl.name.as_str()).copied().unwrap_or(0.0),
        });
    }
    // Most expensive first: a small budget goes where the cost model
    // says the time is.
    out.sort_by(|a, b| b.flops.total_cmp(&a.flops));
    Ok(out)
}

/// Candidate variants for one layer, derived from its current schedule
/// (mode and placement are preserved).
fn layer_candidates(
    geom: &LayerGeom,
    u: usize,
    cur: &LayerSchedule,
) -> Vec<(String, LayerSchedule)> {
    let mut out = Vec::new();
    if let Some(g) = &geom.conv {
        let (cb, mb) = (ceil_div(g.c, u), ceil_div(g.m, u));
        let wp = g.w + 2 * g.p;
        let base = ConvTiling::choose(cb, wp, u, g.k, g.s, mb, g.ho);
        let raw = [
            ConvTiling { tm: base.tm * 2, th: base.th },
            ConvTiling { tm: (base.tm / 2).max(1), th: base.th },
            ConvTiling { tm: base.tm, th: base.th * 2 },
            ConvTiling { tm: base.tm, th: (base.th / 2).max(1) },
            ConvTiling { tm: 1, th: 1 },
        ];
        let mut seen = vec![base];
        for t in raw {
            let t = t.clamped(mb, g.ho);
            if !seen.contains(&t) {
                seen.push(t);
                out.push((
                    format!("tile tm={} th={}", t.tm, t.th),
                    LayerSchedule { tiling: Some(t), ..*cur },
                ));
            }
        }
        out.push(("packing=off".into(), LayerSchedule { packing: false, tiling: None, ..*cur }));
        out.push((
            "parallelism=flp".into(),
            LayerSchedule { parallelism: Parallelism::Flp, ..*cur },
        ));
        out.push((
            "parallelism=klp".into(),
            LayerSchedule { parallelism: Parallelism::Klp, ..*cur },
        ));
    } else {
        out.push(("packing=off".into(), LayerSchedule { packing: false, ..*cur }));
    }
    // PR-6 kernel knobs, conv and dense alike. Forced-scalar rows are
    // bitwise invisible (pure speed); the quantized int8 kernels change
    // numerics and are accuracy-gated downstream (`crate::inexact`) —
    // here they compete on time only. Quant lowers packed OLP only, and
    // conv additionally needs a lane-paddable width.
    if cur.vector_width != 1 {
        out.push(("vector_width=1".into(), LayerSchedule { vector_width: 1, ..*cur }));
    }
    let quant_ok = geom.conv.is_none() || matches!(u, 1 | 2 | 4 | 8);
    if cur.mode != ArithMode::QuantI8 && quant_ok {
        out.push((
            "mode=quant_i8".into(),
            LayerSchedule {
                mode: ArithMode::QuantI8,
                packing: true,
                parallelism: Parallelism::Olp,
                ..*cur
            },
        ));
    }
    out
}

fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

/// Compile `schedule` and time one full `run_batch` walk: `warmup`
/// untimed walks, then the median of `reps` timed ones.
fn measure(
    net: &Network,
    params: &EngineParams,
    schedule: &Schedule,
    batch: usize,
    inputs: &[&[f32]],
    warmup: usize,
    reps: usize,
) -> Result<f64> {
    let mut plan = PlanBuilder::new(net, params)
        .schedule(schedule.clone())
        .batch(batch)
        .build()?;
    // Every candidate is statically verified before it is timed — in
    // release builds too, where `build` alone would skip the pass. A
    // schedule that races or under-sizes its arena must lose here, not
    // in production.
    plan.verify()?;
    for _ in 0..warmup {
        plan.run_batch(inputs)?;
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        plan.run_batch(inputs)?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(median(samples))
}

/// Tune a per-layer [`Schedule`] for `net` on **this** machine. See the
/// module docs for the search; every timing is a real plan compile +
/// batch walk, so the returned schedule is the measured-fastest
/// configuration the budget could find, never a model's guess.
pub fn tune(net: &Network, params: &EngineParams, cfg: &TuneConfig) -> Result<TuneReport> {
    if cfg.batch == 0 {
        return Err(Error::Config("tune batch 0: need at least one image per walk".into()));
    }
    if cfg.reps == 0 {
        return Err(Error::Config("tune reps 0: need at least one timed walk".into()));
    }
    if cfg.budget == 0 {
        return Err(Error::Config(
            "tune budget 0: need at least the seed measurement".into(),
        ));
    }
    let mut sched = Schedule::from_uniform(
        net,
        params.u,
        &cfg.modes,
        Parallelism::Olp,
        true,
        None,
        PoolSettings { threads: 1, affinity: false, cores: None },
    )?;

    let mut rng = Rng::new(cfg.seed);
    let inputs: Vec<Vec<f32>> =
        (0..cfg.batch).map(|_| rng.normal_vec(net.input.elements())).collect();
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let time = |s: &Schedule| measure(net, params, s, cfg.batch, &refs, cfg.warmup, cfg.reps);

    let mut used = 0usize;
    let mut trials = Vec::new();
    let mut rejected = Vec::new();

    // Seed: the analytic defaults at one pool chunk.
    let default_ms = time(&sched)?;
    used += 1;
    let mut best_ms = default_ms;

    // Pool stage: chunk counts, powers of two.
    let mut threads = 2usize;
    while threads <= cfg.max_threads && used < cfg.budget {
        let mut cand = sched.clone();
        cand.pool.threads = threads;
        let ms = time(&cand)?;
        used += 1;
        let accepted = ms < best_ms * ACCEPT_RATIO;
        trials.push(Trial {
            layer: "(pool)".into(),
            candidate: format!("threads={threads}"),
            median_ms: ms,
            accepted,
        });
        if accepted {
            sched = cand;
            best_ms = ms;
        }
        threads *= 2;
    }

    // Per-layer stage: each layer adopts its best measured variant.
    let mut exhausted = false;
    for geom in &layer_geometry(net)? {
        let cur = sched.layers[geom.name.as_str()];
        let mut layer_best_ms = best_ms;
        let mut layer_best: Option<LayerSchedule> = None;
        for (label, cand_ls) in layer_candidates(geom, params.u, &cur) {
            if used >= cfg.budget {
                exhausted = true;
                break;
            }
            let mut cand = sched.clone();
            cand.layers.insert(geom.name.clone(), cand_ls);
            // A candidate the plan compiler rejects (e.g. packing=off
            // or FLP under a quant_i8 layer) or the static verifier
            // refuses to certify is skipped, not fatal — logged in the
            // report, and costs no budget, since nothing was measured.
            let ms = match time(&cand) {
                Ok(ms) => ms,
                Err(e @ (Error::Config(_) | Error::Verify { .. })) => {
                    rejected.push(format!("{} {label}: {e}", geom.name));
                    continue;
                }
                Err(e) => return Err(e),
            };
            used += 1;
            let accepted = ms < layer_best_ms * ACCEPT_RATIO;
            trials.push(Trial {
                layer: geom.name.clone(),
                candidate: label,
                median_ms: ms,
                accepted,
            });
            if accepted {
                layer_best_ms = ms;
                layer_best = Some(cand_ls);
            }
        }
        // Adopt the layer's winner even when the budget ran out
        // mid-layer: a measured, accepted candidate must never be
        // missing from the emitted schedule (trials and schedule would
        // disagree otherwise).
        if let Some(ls) = layer_best {
            sched.layers.insert(geom.name.clone(), ls);
            best_ms = layer_best_ms;
        }
        if exhausted {
            break;
        }
    }

    // Backend-split stage (opt-in): try every net-order cut between
    // the two given backends on the tuned schedule. The score is the
    // bottleneck stage's measured time (pipeline throughput model); a
    // split is only adopted when that bottleneck beats the flat walk —
    // otherwise the transfer + imbalance overhead loses to no split.
    if cfg.backends.len() >= 2 && used < cfg.budget {
        let names = net.param_layer_names();
        let registry = BackendRegistry::from_env()?;
        let (front, back) = (cfg.backends[0], cfg.backends[1]);
        let mut split_best: Option<(Schedule, f64)> = None;
        for cut in 1..names.len() {
            if used >= cfg.budget {
                break;
            }
            let mut cand = sched.clone();
            for (i, name) in names.iter().enumerate() {
                let b = if i < cut { front } else { back };
                if let Some(ls) = cand.layers.get_mut(name) {
                    ls.backend = b;
                }
            }
            // Compile, partition, and statically verify the real staged
            // plan (stage-cut rules included), then time each stage on
            // its resolved executor — the same substrate serve runs.
            let timed = (|| -> Result<f64> {
                let plan =
                    PlanBuilder::new(net, params).schedule(cand.clone()).batch(cfg.batch).build()?;
                let mut staged = StagedPlan::from_plan(&plan)?;
                staged.verify()?;
                for _ in 0..cfg.warmup {
                    staged.run_batch_seq(&refs, &registry)?;
                }
                let mut samples = Vec::with_capacity(cfg.reps);
                for _ in 0..cfg.reps {
                    let stage_ms = staged.stage_times_ms(&refs, &registry)?;
                    samples.push(stage_ms.iter().copied().fold(0.0f64, f64::max));
                }
                Ok(median(samples))
            })();
            let ms = match timed {
                Ok(ms) => ms,
                Err(e @ (Error::Config(_) | Error::Verify { .. } | Error::Xla(_))) => {
                    rejected.push(format!("(split) cut={cut}: {e}"));
                    continue;
                }
                Err(e) => return Err(e),
            };
            used += 1;
            let accepted = ms < best_ms * ACCEPT_RATIO
                && split_best.as_ref().map_or(true, |&(_, b)| ms < b);
            trials.push(Trial {
                layer: "(split)".into(),
                candidate: format!("{front}|{back} cut={cut} (bottleneck)"),
                median_ms: ms,
                accepted,
            });
            if accepted {
                split_best = Some((cand, ms));
            }
        }
        if let Some((s, ms)) = split_best {
            sched = s;
            best_ms = ms;
        }
    }

    // SoC-model cross-check via the synthesis bridge.
    let predicted_ms = crate::soc::catalog().into_iter().next().and_then(|device| {
        SynthesisPlan::from_schedule(&sched, net)
            .ok()
            .map(|plan| predict_latency_ms(&plan, net, &device))
    });

    Ok(TuneReport {
        schedule: sched,
        default_ms,
        tuned_ms: best_ms,
        measurements: used,
        trials,
        predicted_ms,
        rejected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::util::json::Json;

    fn quick_cfg() -> TuneConfig {
        TuneConfig {
            batch: 2,
            max_threads: 2,
            warmup: 0,
            reps: 1,
            budget: 6,
            modes: ModeAssignment::uniform(ArithMode::Imprecise),
            seed: 9,
            backends: Vec::new(),
        }
    }

    #[test]
    fn tune_respects_budget_and_emits_a_valid_schedule() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 1, 4).unwrap();
        let report = tune(&net, &params, &quick_cfg()).unwrap();
        assert!(report.measurements <= 6);
        assert!(!report.trials.is_empty());
        assert!(report.default_ms > 0.0 && report.tuned_ms > 0.0);
        // The incumbent only ever improves, so tuned <= default.
        assert!(report.tuned_ms <= report.default_ms);
        report.schedule.validate_for(&net, 4).unwrap();
        // f32 modes are preserved, never searched; quant_i8 is the one
        // mode the tuner may adopt on its own (as a speed candidate).
        for ls in report.schedule.layers.values() {
            assert!(matches!(ls.mode, ArithMode::Imprecise | ArithMode::QuantI8));
        }
        assert!(report.predicted_ms.unwrap_or(0.0) > 0.0);
    }

    #[test]
    fn tuned_schedule_roundtrips_to_an_identical_plan() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 2, 4).unwrap();
        let report = tune(&net, &params, &quick_cfg()).unwrap();
        let text = report.schedule.to_json().to_string();
        let loaded = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(loaded, report.schedule);
        let mut a = PlanBuilder::new(&net, &params)
            .schedule(report.schedule.clone())
            .batch(2)
            .build()
            .unwrap();
        let mut b = PlanBuilder::new(&net, &params).schedule(loaded).batch(2).build().unwrap();
        let mut rng = Rng::new(3);
        let x1 = rng.normal_vec(net.input.elements());
        let x2 = rng.normal_vec(net.input.elements());
        assert_eq!(
            a.run_batch(&[&x1[..], &x2[..]]).unwrap(),
            b.run_batch(&[&x1[..], &x2[..]]).unwrap()
        );
    }

    #[test]
    fn pr6_candidates_cover_scalar_and_quant_with_lane_gate() {
        let net = zoo::tinynet();
        let geoms = layer_geometry(&net).unwrap();
        let conv = geoms.iter().find(|g| g.conv.is_some()).unwrap();
        let dense = geoms.iter().find(|g| g.conv.is_none()).unwrap();
        let cur = LayerSchedule { mode: ArithMode::Imprecise, ..LayerSchedule::default() };
        for g in [conv, dense] {
            let cands = layer_candidates(g, 4, &cur);
            assert!(cands
                .iter()
                .any(|(l, ls)| l == "vector_width=1" && ls.vector_width == 1));
            let (_, q) = cands.iter().find(|(l, _)| l == "mode=quant_i8").unwrap();
            assert!(
                q.mode == ArithMode::QuantI8
                    && q.packing
                    && q.parallelism == Parallelism::Olp
            );
        }
        // u = 3 cannot be lane-padded: the quant candidate is withheld
        // for conv layers (dense has no width constraint).
        assert!(!layer_candidates(conv, 3, &cur).iter().any(|(l, _)| l == "mode=quant_i8"));
        assert!(layer_candidates(dense, 3, &cur).iter().any(|(l, _)| l == "mode=quant_i8"));
        // A layer already forced scalar / quantized gets no duplicate.
        let scalar_quant = LayerSchedule {
            mode: ArithMode::QuantI8,
            vector_width: 1,
            ..LayerSchedule::default()
        };
        let cands = layer_candidates(conv, 4, &scalar_quant);
        assert!(!cands.iter().any(|(l, _)| l == "vector_width=1" || l == "mode=quant_i8"));
    }

    #[test]
    fn adopted_pr6_candidates_roundtrip_and_compile() {
        // A schedule that adopted the quant_i8 and vector_width
        // candidates must survive the JSON artifact round trip and
        // compile into a runnable plan — the tune -> serve contract for
        // the new knobs.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 7, 4).unwrap();
        let geoms = layer_geometry(&net).unwrap();
        let conv = geoms.iter().find(|g| g.conv.is_some()).unwrap();
        let dense = geoms.iter().find(|g| g.conv.is_none()).unwrap();
        let mut sched = Schedule::default_for(&net, 4);
        let cur = LayerSchedule { mode: ArithMode::Imprecise, ..LayerSchedule::default() };
        let quant = layer_candidates(conv, 4, &cur)
            .into_iter()
            .find(|(l, _)| l == "mode=quant_i8")
            .unwrap()
            .1;
        let scalar = layer_candidates(dense, 4, &cur)
            .into_iter()
            .find(|(l, _)| l == "vector_width=1")
            .unwrap()
            .1;
        sched.layers.insert(conv.name.clone(), quant);
        sched.layers.insert(dense.name.clone(), scalar);
        sched.validate_for(&net, 4).unwrap();
        let text = sched.to_json().to_string();
        let loaded = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(loaded, sched);
        let mut plan = PlanBuilder::new(&net, &params).schedule(loaded).build().unwrap();
        let x = Rng::new(8).normal_vec(net.input.elements());
        assert!(plan.run(&x).unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn backend_split_stage_searches_cuts_and_emits_staged_or_flat() {
        // With --backends native,mock the tuner must try net-order cut
        // points as real verified staged plans, record them as (split)
        // trials, and — whichever way the timings fall — emit a schedule
        // that still compiles and partitions cleanly.
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let cfg = TuneConfig {
            budget: 12,
            backends: vec![BackendTarget::Native, BackendTarget::Mock],
            ..quick_cfg()
        };
        let report = tune(&net, &params, &cfg).unwrap();
        assert!(
            report.trials.iter().any(|t| t.layer == "(split)"),
            "split stage must record trials: {:?}",
            report.trials
        );
        report.schedule.validate_for(&net, 4).unwrap();
        let plan = PlanBuilder::new(&net, &params)
            .schedule(report.schedule.clone())
            .batch(2)
            .build()
            .unwrap();
        let staged = StagedPlan::from_plan(&plan).unwrap();
        staged.verify().unwrap();
        if report.schedule.is_staged() {
            assert!(staged.stage_count() >= 2);
        } else {
            assert_eq!(staged.stage_count(), 1);
        }
    }

    #[test]
    fn degenerate_tune_configs_are_config_errors() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 4, 4).unwrap();
        for cfg in [
            TuneConfig { batch: 0, ..quick_cfg() },
            TuneConfig { reps: 0, ..quick_cfg() },
            TuneConfig { budget: 0, ..quick_cfg() },
        ] {
            assert!(matches!(tune(&net, &params, &cfg), Err(Error::Config(_))));
        }
    }
}
