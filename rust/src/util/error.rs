//! Crate-wide error type.

use std::fmt;

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for every subsystem; variants carry enough context to
/// be actionable from the CLI without a backtrace.
#[derive(Debug)]
pub enum Error {
    /// Parse failure in a `.cappnet` / `.capp` / JSON / manifest input.
    Parse { what: String, detail: String },
    /// A request or configuration is structurally invalid.
    Invalid(String),
    /// A tuning-surface (schedule / builder) configuration is rejected
    /// before compilation: degenerate knobs (`threads = 0`,
    /// `batch = 0`), mode or schedule entries naming layers the network
    /// does not have, or a schedule whose layer set / vector width does
    /// not match the network it is applied to.
    Config(String),
    /// Shape/layout mismatch between tensors or layers.
    Shape(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// PJRT / XLA runtime failure.
    Xla(String),
    /// A serving-side failure (queue closed, worker spawn, …).
    Serve(String),
    /// A request the serve front-end refused at admission — typed so
    /// clients and the replay driver can tell load-shedding reasons
    /// apart (queue backpressure vs deadline-infeasible vs unknown
    /// model) without string matching.
    Rejected(crate::serve::Rejected),
    /// A pool task panicked during a plan walk. The panic was contained
    /// (caught at the task boundary; the pool and its locks stay fully
    /// usable) and surfaced as this typed error instead of unwinding
    /// through `run_batch`. `step` is the plan step index, `layer` the
    /// lowered step's label (layer name or step kind).
    TaskPanicked { step: usize, layer: String },
    /// The static plan verifier ([`crate::engine::verify`]) rejected a
    /// compiled plan or a schedule before it could run: a race, a
    /// layout/def-use inconsistency, an under-sized arena, or a broken
    /// mode/tile precondition. `step` is the offending plan step index
    /// (0 for pre-lowering schedule lints), `layer` its label, and
    /// `rule` the rule class that fired.
    Verify {
        step: usize,
        layer: String,
        rule: crate::engine::verify::VerifyRule,
        detail: String,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Parse { what, detail } => write!(f, "parse error in {what}: {detail}"),
            Error::Invalid(msg) => write!(f, "invalid: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(msg) => write!(f, "xla error: {msg}"),
            Error::Serve(msg) => write!(f, "serve error: {msg}"),
            Error::Rejected(r) => write!(f, "rejected: {r}"),
            Error::TaskPanicked { step, layer } => {
                write!(f, "task panicked at plan step {step} ({layer}); panic contained")
            }
            Error::Verify { step, layer, rule, detail } => {
                write!(f, "verify: {} at plan step {step} ({layer}): {detail}", rule.as_str())
            }
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Convenience constructor for parse errors.
    pub fn parse(what: impl Into<String>, detail: impl Into<String>) -> Self {
        Error::Parse { what: what.into(), detail: detail.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        let e = Error::parse("manifest.json", "unexpected token");
        assert_eq!(e.to_string(), "parse error in manifest.json: unexpected token");
        assert!(Error::Shape("a vs b".into()).to_string().contains("a vs b"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
