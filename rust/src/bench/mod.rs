//! In-repo micro-benchmark harness (criterion is not in the vendored
//! crate set). Used by every `rust/benches/*.rs` target.
//!
//! Protocol per benchmark: warm-up iterations, then `n` timed samples,
//! reported with the paper's trimmed-mean protocol (drop min/max —
//! section V.A) plus median and spread. Results can be printed as an
//! aligned table, which the Table I–III benches use to emit the same
//! rows the paper reports.

use std::time::Instant;

use crate::metrics::trimmed_mean;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Trimmed mean, milliseconds.
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub samples: usize,
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 3, samples: 10 }
    }
}

impl BenchConfig {
    /// Honour `CAPPUCCINO_BENCH_FAST=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("CAPPUCCINO_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig { warmup: 1, samples: 3 }
        } else {
            Self::default()
        }
    }
}

/// Time `f` under the protocol; `f` must perform one full operation.
pub fn bench(name: impl Into<String>, cfg: BenchConfig, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut samples_ms = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples.max(1) {
        let t0 = Instant::now();
        f();
        samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mut sorted = samples_ms.clone();
    sorted.sort_by(f64::total_cmp);
    Measurement {
        name: name.into(),
        mean_ms: trimmed_mean(&samples_ms),
        median_ms: sorted[sorted.len() / 2],
        min_ms: sorted[0],
        max_ms: *sorted.last().unwrap(),
        samples: samples_ms.len(),
    }
}

/// Simple aligned-table printer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Format helper: `12.34` / `1234` style millisecond cells.
pub fn ms(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Format helper: `12.3x` speedup cells.
pub fn speedup(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_samples() {
        let m = bench("noop", BenchConfig { warmup: 1, samples: 5 }, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(m.samples, 5);
        assert!(m.min_ms <= m.median_ms && m.median_ms <= m.max_ms);
    }

    #[test]
    fn bench_measures_sleep() {
        let m = bench("sleep", BenchConfig { warmup: 0, samples: 3 }, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert!(m.mean_ms >= 1.8, "mean {}", m.mean_ms);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(&["alexnet".into(), "947.15".into()]);
        t.row(&["x".into(), "1.0".into()]);
        let s = t.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn format_helpers() {
        assert_eq!(ms(1234.6), "1235");
        assert_eq!(ms(12.345), "12.35");
        assert_eq!(ms(0.5), "0.5000");
        assert_eq!(speedup(40.47), "40.47x");
    }
}
