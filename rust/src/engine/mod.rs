//! Native execution engine — the synthesized program's runtime body.
//!
//! Cappuccino's synthesizer emits a *plan* (see [`crate::synth`]); this
//! module is the machine that executes plans: map-major tensors,
//! OLP-threaded vectorised convolutions (section IV.A/IV.B), per-layer
//! arithmetic modes (section IV.C), plus the baseline and the rejected
//! KLP/FLP policies for the ablation benches.
//!
//! The steady-state entry point is [`plan::ExecutionPlan`], built via
//! [`plan::PlanBuilder`]: compile once (shape inference, weight baking
//! **and packing into tap-major / column-blocked panels**, per-layer
//! tile selection from an L1/L2 cost model, buffer-arena sizing for a
//! batch capacity `B`), then execute whole dynamic batches with
//! [`plan::ExecutionPlan::run_batch`] — one plan walk per batch, zero
//! steady-state allocation at any `u` (per-thread kernel scratch lives
//! in the arena) and zero thread spawns (all parallel sections run on
//! the persistent [`parallel`] pool). Single-image `run` is just
//! `B = 1`.
//!
//! The pool itself is **topology-aware** ([`topology`] probes core
//! clusters and pins workers; [`parallel`] gives each cluster its own
//! work deque with idle-only stealing), and
//! [`plan::PlanBuilder::affinity`] turns on cost-weighted placement of
//! packed conv macro items across clusters — placement moves work
//! between cores, never changes what is computed.
//!
//! Inner loops run on the [`simd`] lane abstraction: explicit-width
//! `f32x4`/`f32x8` and `i16x8`/`i32x8` registers with intrinsics
//! backends behind target-feature detection and a bitwise-equivalent
//! scalar fallback (`CAPPUCCINO_SIMD=0` forces it). The quantized
//! [`mode::ArithMode::QuantI8`] mode rides the same packed panels with
//! `i8` weights and widening `i32` accumulation.
//!
//! The whole tuning surface — per-layer parallelism, packing, tiling,
//! arithmetic mode, placement, vector width, plus the pool settings —
//! is the
//! [`schedule::Schedule`] IR: every `PlanBuilder` fluent setter lowers
//! into one, [`plan::PlanBuilder::schedule`] accepts a heterogeneous
//! one directly, and schedules serialize to the `schedule.json`
//! artifact that [`crate::autotune`] emits and `serve --schedule`
//! consumes. A schedule may also place layers on different *backends*
//! ([`schedule::BackendTarget`]): [`hetero`] partitions such a plan
//! into per-backend stages with explicit transfer wires and runs the
//! stages as an overlapping pipeline.

pub mod conv;
pub mod hetero;
pub mod mode;
pub mod network;
pub mod ops;
pub mod parallel;
pub mod plan;
pub mod schedule;
pub mod simd;
pub mod tensor;
pub mod topology;
pub mod verify;

pub use conv::{
    cast_weights, conv_mm, conv_mm_packed, conv_nchw_flp, conv_nchw_klp, conv_nchw_scalar,
    ConvTiling,
};
pub use mode::ArithMode;
pub use network::{
    run_baseline, run_baseline_legacy, run_mapmajor, run_mapmajor_legacy, EngineParams,
    ExecConfig, ModeAssignment,
};
pub use parallel::{
    chunk_ranges_weighted, global_pool, pool_threads_spawned, with_pool, ClusterInfo,
    Parallelism, ThreadPool,
};
pub use hetero::{Pipeline, StagedMutation, StagedPlan};
pub use plan::{ExecutionPlan, PlanBuilder, StepKind};
pub use schedule::{BackendTarget, LayerSchedule, PoolSettings, Schedule};
pub use verify::{verify_schedule, VerifyRule};
pub use tensor::{MapTensor, Tensor};
pub use topology::{pin_current_thread, CoreCluster, CoreSet, Topology};
