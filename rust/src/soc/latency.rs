//! Latency model: per-layer roofline over the device catalog.
//!
//! For each primitive layer the simulator takes
//! `t = max(compute term, memory term) + dispatch overhead` where the
//! compute rate depends on the processing mode:
//!
//! * baseline — single-thread Java interpreter throughput;
//! * parallel — all cores, scalar precise arithmetic (RenderScript
//!   precise mode serialises vector element processing — paper §IV.C);
//! * imprecise — vector units unlocked; the per-layer *vector
//!   efficiency* models how well the map-major MAC fills `u` lanes:
//!   1x1 convolutions (channel-dominated) vectorise perfectly, large
//!   kernels and thin input layers less so, dense layers are mostly
//!   memory-bound anyway.
//!
//! The simulated measurement protocol mirrors section V.A: every query
//! can be sampled `n` times with small Gaussian measurement noise and
//! reported through the trimmed mean.

use crate::model::{shapes, Network};
use crate::soc::devices::{DeviceModel, ProcessingMode};
use crate::util::rng::Rng;

/// Per-layer simulated timing.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    pub kind: &'static str,
    pub compute_ms: f64,
    pub memory_ms: f64,
    pub dispatch_ms: f64,
}

impl LayerTiming {
    pub fn total_ms(&self) -> f64 {
        self.compute_ms.max(self.memory_ms) + self.dispatch_ms
    }
}

/// Full simulation result for (network, device, mode).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub network: String,
    pub device: &'static str,
    pub mode: ProcessingMode,
    pub layers: Vec<LayerTiming>,
}

impl SimReport {
    pub fn total_ms(&self) -> f64 {
        self.layers.iter().map(|l| l.total_ms()).sum()
    }

    /// The slowest layers, for profiling output.
    pub fn hotspots(&self, n: usize) -> Vec<&LayerTiming> {
        let mut v: Vec<&LayerTiming> = self.layers.iter().collect();
        v.sort_by(|a, b| b.total_ms().total_cmp(&a.total_ms()));
        v.truncate(n);
        v
    }
}

/// Simulate one layer under a mode.
fn simulate_layer(
    cost: &shapes::LayerCost,
    veff: f64,
    device: &DeviceModel,
    mode: ProcessingMode,
) -> LayerTiming {
    let bytes = cost.param_bytes + cost.input_bytes + cost.output_bytes;
    let (compute_ms, memory_ms, dispatch_ms) = match mode {
        ProcessingMode::JavaBaseline => {
            // Interpreted scalar loop: compute-bound by definition; the
            // interpreter factor swallows memory behaviour.
            (cost.flops / (device.java_mflops * 1e6) * 1e3, 0.0, 0.0)
        }
        ProcessingMode::Parallel => {
            let rate = device.parallel_gflops() * 1e9;
            (
                cost.flops / rate * 1e3,
                bytes / (device.mem_bw_gbs * 1e9) * 1e3,
                device.dispatch_ms,
            )
        }
        ProcessingMode::Imprecise => {
            let rate = device.imprecise_gflops() * 1e9 * veff;
            (
                cost.flops / rate * 1e3,
                bytes / (device.mem_bw_gbs * 1e9) * 1e3,
                device.dispatch_ms,
            )
        }
    };
    LayerTiming {
        name: cost.name.clone(),
        kind: cost.kind,
        compute_ms,
        memory_ms,
        dispatch_ms,
    }
}

/// Simulate a full network on a device under a processing mode.
pub fn simulate(net: &Network, device: &DeviceModel, mode: ProcessingMode) -> SimReport {
    let info = shapes::infer(net).expect("network must shape-check before simulation");
    let layers = info
        .costs
        .iter()
        .map(|c| simulate_layer(c, vector_efficiency_cached(c, &info), device, mode))
        .collect();
    SimReport { network: net.name.clone(), device: device.name, mode, layers }
}

/// `vector_efficiency` without re-running shape inference per layer.
fn vector_efficiency_cached(cost: &shapes::LayerCost, info: &shapes::NetworkInfo) -> f64 {
    match cost.kind {
        "conv" => {
            let pl = info.param_layer(&cost.name).expect("conv has params");
            let (c_in, _, _) = pl.input.as_maps().unwrap_or((4, 0, 0));
            let k_eff = match pl.k {
                1 => 1.00,
                2 | 3 => 0.90,
                4 | 5 => 0.80,
                _ => 0.55,
            };
            let c_eff = (c_in as f64 / 4.0).min(1.0).max(0.25);
            k_eff * c_eff
        }
        "dense" => 0.35,
        _ => 0.50,
    }
}

/// Sampled measurement with the paper's protocol (section V.A): `n`
/// repetitions with ±`noise` relative Gaussian measurement jitter, min
/// and max dropped, mean of the rest.
pub fn measure_trimmed(
    net: &Network,
    device: &DeviceModel,
    mode: ProcessingMode,
    n: usize,
    noise: f64,
    seed: u64,
) -> f64 {
    let nominal = simulate(net, device, mode).total_ms();
    let mut rng = Rng::new(seed ^ 0xCAFE);
    let samples: Vec<f64> = (0..n.max(1))
        .map(|_| nominal * (1.0 + noise * rng.normal() as f64))
        .collect();
    crate::metrics::trimmed_mean(&samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::soc::devices;

    #[test]
    fn modes_strictly_ordered_everywhere() {
        // Table I invariant: baseline >> parallel >= imprecise.
        for device in devices::catalog() {
            for net in [zoo::alexnet(), zoo::squeezenet(), zoo::googlenet()] {
                let base = simulate(&net, &device, ProcessingMode::JavaBaseline).total_ms();
                let par = simulate(&net, &device, ProcessingMode::Parallel).total_ms();
                let imp = simulate(&net, &device, ProcessingMode::Imprecise).total_ms();
                assert!(base > par * 5.0, "{}/{}: {base} vs {par}", device.name, net.name);
                assert!(par > imp, "{}/{}: {par} vs {imp}", device.name, net.name);
            }
        }
    }

    #[test]
    fn speedup_bands_match_paper_shape() {
        // Paper: overall speedups between ~32x and ~272x; our model must
        // land every cell in a compatible coarse band (10x .. 500x).
        for device in devices::catalog() {
            for net in [zoo::alexnet(), zoo::squeezenet(), zoo::googlenet()] {
                let base = simulate(&net, &device, ProcessingMode::JavaBaseline).total_ms();
                let imp = simulate(&net, &device, ProcessingMode::Imprecise).total_ms();
                let speedup = base / imp;
                assert!(
                    (10.0..500.0).contains(&speedup),
                    "{}/{}: speedup {speedup:.1}",
                    device.name,
                    net.name
                );
            }
        }
    }

    #[test]
    fn baseline_magnitudes_match_paper_column() {
        // Calibrated java_mflops should land baselines within 2x of the
        // paper's measured values.
        let cases = [
            ("alexnet", devices::nexus5(), 33848.0),
            ("squeezenet", devices::nexus5(), 43932.0),
            ("googlenet", devices::nexus5(), 84404.0),
            ("alexnet", devices::nexus6p(), 8626.0),
            ("alexnet", devices::galaxy_s7(), 8698.0),
        ];
        for (net_name, device, paper_ms) in cases {
            let net = zoo::by_name(net_name).unwrap();
            let ms = simulate(&net, &device, ProcessingMode::JavaBaseline).total_ms();
            let ratio = ms / paper_ms;
            assert!(
                (0.4..2.5).contains(&ratio),
                "{}/{}: model {ms:.0}ms vs paper {paper_ms}ms (ratio {ratio:.2})",
                device.name,
                net_name
            );
        }
    }

    #[test]
    fn imprecise_subsecond_for_small_nets() {
        // Paper: "execution time in all but one case is below a second".
        for device in devices::catalog() {
            for net in [zoo::alexnet(), zoo::squeezenet()] {
                let imp = simulate(&net, &device, ProcessingMode::Imprecise).total_ms();
                assert!(imp < 1000.0, "{}/{}: {imp}ms", device.name, net.name);
            }
        }
    }

    #[test]
    fn hotspots_sorted() {
        let net = zoo::alexnet();
        let rep = simulate(&net, &devices::nexus5(), ProcessingMode::Parallel);
        let hs = rep.hotspots(3);
        assert_eq!(hs.len(), 3);
        assert!(hs[0].total_ms() >= hs[1].total_ms());
    }

    #[test]
    fn trimmed_measurement_close_to_nominal() {
        let net = zoo::squeezenet();
        let d = devices::nexus5();
        let nominal = simulate(&net, &d, ProcessingMode::Imprecise).total_ms();
        let measured = measure_trimmed(&net, &d, ProcessingMode::Imprecise, 100, 0.01, 7);
        assert!((measured / nominal - 1.0).abs() < 0.01, "{measured} vs {nominal}");
    }

    #[test]
    fn vector_efficiency_shape() {
        // 1x1 convs must vectorise better than 11x11, thin-input conv1
        // must be derated.
        let net = zoo::alexnet();
        let info = shapes::infer(&net).unwrap();
        let conv1 = info.costs.iter().find(|c| c.name == "conv1").unwrap();
        let conv3 = info.costs.iter().find(|c| c.name == "conv3").unwrap();
        let e1 = vector_efficiency_cached(conv1, &info);
        let e3 = vector_efficiency_cached(conv3, &info);
        assert!(e1 < e3, "conv1 {e1} vs conv3 {e3}");
    }
}
