//! Software synthesis for every paper network: `.cappnet` descriptions
//! in, synthesis plans out — the batch counterpart of the `cappuccino
//! synthesize` CLI.
//!
//! Demonstrates the file-format round trip the paper's toolflow implies:
//! the zoo networks are serialised to `.cappnet`, re-parsed, synthesized
//! (OLP + map-major + per-layer modes), and the resulting plans written
//! as JSON next to a per-network latency prediction across the device
//! catalog.
//!
//! Run: `cargo run --release --example synthesize`

use cappuccino::config::{parse_cappnet, write_cappnet};
use cappuccino::engine::{ArithMode, ModeAssignment};
use cappuccino::model::zoo;
use cappuccino::soc;
use cappuccino::synth::{finalize, predict_latency_ms, PrimarySynthesizer, SynthesisPlan};
use cappuccino::util::json::Json;

fn main() -> cappuccino::Result<()> {
    let out_dir = std::env::temp_dir().join("cappuccino_synthesize");
    std::fs::create_dir_all(&out_dir)?;

    for net in zoo::all() {
        // Round-trip through the network description format.
        let text = write_cappnet(&net);
        let cappnet_path = out_dir.join(format!("{}.cappnet", net.name));
        std::fs::write(&cappnet_path, &text)?;
        let reparsed = parse_cappnet(&text)?;
        assert_eq!(
            reparsed.param_layer_names(),
            net.param_layer_names(),
            "{}: .cappnet round trip lost layers",
            net.name
        );

        // Synthesize: primary program, then the paper's outcome (all
        // layers imprecise — section V.B.2) as the final software.
        let primary = PrimarySynthesizer::new(4, 4).synthesize(&reparsed)?;
        let plan = finalize(&primary, &ModeAssignment::uniform(ArithMode::Imprecise));
        let plan_path = out_dir.join(format!("{}.plan.json", net.name));
        std::fs::write(&plan_path, plan.to_json().to_string())?;

        // Re-load the plan to prove the JSON is self-contained.
        let loaded =
            SynthesisPlan::from_json(&Json::parse(&std::fs::read_to_string(&plan_path)?)?)?;
        assert_eq!(loaded, plan);

        println!(
            "{:<11} -> {} ({} layers, {} inexact)",
            net.name,
            plan_path.display(),
            plan.layers.len(),
            plan.inexact_layers()
        );
        for d in soc::catalog() {
            println!(
                "    {:<10} predicted {:>9.2} ms",
                d.name,
                predict_latency_ms(&plan, &reparsed, &d)
            );
        }
    }
    println!("\nsynthesize OK (outputs in {})", out_dir.display());
    Ok(())
}
