"""The ``.capp`` model-file format — Cappuccino's second input (Fig. 3).

A trivially parseable little-endian binary container for named float32
tensors, written at build time by python and read at run time by
``rust/src/config/modelfile.rs`` (the two implementations are
cross-checked by an integration test).

Layout::

  magic   8 bytes  b"CAPPMODL"
  version u32      1
  count   u32      number of tensors
  tensor* :
    name_len u16, name bytes (utf-8)
    ndim     u8,  dims u32 * ndim
    dtype    u8   (0 = f32)
    data     f32 * prod(dims), little-endian
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"CAPPMODL"
VERSION = 1
DTYPE_F32 = 0


def write_modelfile(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write named f32 tensors; iteration order is preserved."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr, dtype="<f4")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(struct.pack("<B", DTYPE_F32))
            f.write(arr.tobytes())


def read_modelfile(path: str) -> dict[str, np.ndarray]:
    """Read a ``.capp`` file back into ``{name: f32 array}``."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != MAGIC:
        raise ValueError(f"{path}: bad magic {data[:8]!r}")
    version, count = struct.unpack_from("<II", data, 8)
    if version != VERSION:
        raise ValueError(f"{path}: unsupported version {version}")
    off = 16
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off); off += 2
        name = data[off: off + nlen].decode("utf-8"); off += nlen
        (ndim,) = struct.unpack_from("<B", data, off); off += 1
        dims = struct.unpack_from(f"<{ndim}I", data, off); off += 4 * ndim
        (dtype,) = struct.unpack_from("<B", data, off); off += 1
        if dtype != DTYPE_F32:
            raise ValueError(f"{path}: tensor {name}: unsupported dtype {dtype}")
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, "<f4", count=n, offset=off).reshape(dims)
        off += 4 * n
        out[name] = arr.copy()
    return out


def params_to_tensors(params) -> dict[str, np.ndarray]:
    """Flatten ``{layer: (w, b)}`` params into capp tensor naming
    (``layer/w``, ``layer/b``)."""
    out = {}
    for name, (w, b) in params.items():
        out[f"{name}/w"] = np.asarray(w)
        out[f"{name}/b"] = np.asarray(b)
    return out


def tensors_to_params(tensors: dict[str, np.ndarray]):
    """Inverse of :func:`params_to_tensors`."""
    params = {}
    for key, arr in tensors.items():
        name, kind = key.rsplit("/", 1)
        params.setdefault(name, [None, None])
        params[name][0 if kind == "w" else 1] = arr
    return {k: (v[0], v[1]) for k, v in params.items()}
