//! Multi-model tenancy: build resident [`Tenant`]s from `schedule.json`
//! artifacts.
//!
//! The `serve --models a=schedule_a.json,b=schedule_b.json` path: each
//! tenant loads its own tuned schedule, compiles its own per-capacity
//! plan set (weights shared across capacities, never across tenants),
//! gets its own bounded queue and worker thread, and — when core
//! partitioning is on — a **disjoint** [`CoreSet`] carved from the host
//! topology so co-resident models stop trampling each other's caches.
//! The schedule also feeds [`crate::synth::predict_schedule_latency_ms`]
//! to give the tenant's admission controller its analytic per-image
//! service estimate — tenancy is what turns deadline admission from a
//! queue-depth check into a model-specific drain-time prediction.

use std::time::Duration;

use crate::engine::topology::{CoreSet, Topology};
use crate::engine::{EngineParams, Schedule};
use crate::model::zoo;
use crate::serve::frontend::Tenant;
use crate::serve::{BatchPolicy, EngineBackend};
use crate::soc::DeviceModel;
use crate::util::error::{Error, Result};

/// One `name=schedule.json` entry from the `--models` flag.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    pub schedule_path: String,
}

/// Parse the `--models` flag: `name=path[,name=path...]`. Names must be
/// unique; both halves must be non-empty.
pub fn parse_models(spec: &str) -> Result<Vec<TenantSpec>> {
    let mut out: Vec<TenantSpec> = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, path) = part.split_once('=').ok_or_else(|| {
            Error::Invalid(format!("--models: expected name=schedule.json, got {part:?}"))
        })?;
        let (name, path) = (name.trim(), path.trim());
        if name.is_empty() || path.is_empty() {
            return Err(Error::Invalid(format!("--models: empty name or path in {part:?}")));
        }
        if out.iter().any(|t| t.name == name) {
            return Err(Error::Invalid(format!("--models: tenant {name:?} given twice")));
        }
        out.push(TenantSpec { name: name.into(), schedule_path: path.into() });
    }
    if out.is_empty() {
        return Err(Error::Invalid("--models: no tenants specified".into()));
    }
    Ok(out)
}

/// Shared settings for building engine tenants.
#[derive(Debug, Clone)]
pub struct TenancyConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_depth: usize,
    /// Partition the host topology into one disjoint [`CoreSet`] per
    /// tenant (overrides any core set carried in a schedule). Off, each
    /// tenant uses its schedule's own `pool.cores` (possibly none).
    pub partition_cores: bool,
    /// Reference device for the admission controller's analytic
    /// per-image latency estimate.
    pub device: DeviceModel,
    /// Weight seed base (tenant `i` uses `seed + i` — demo weights;
    /// real deployments would load parameter files).
    pub seed: u64,
    /// Optional known-good fallback schedule artifact
    /// (`--fallback-schedule`): applied to every tenant whose net
    /// matches the artifact's, as the supervisor's degraded-mode
    /// factory (same weights, fallback configuration). Tenants for a
    /// different net serve without a fallback.
    pub fallback_schedule: Option<String>,
    /// Supervisor knobs shared by every tenant built here.
    pub supervision: crate::serve::SupervisorPolicy,
}

impl TenancyConfig {
    pub fn new(device: DeviceModel) -> TenancyConfig {
        let d = BatchPolicy::default();
        TenancyConfig {
            max_batch: d.max_batch,
            max_delay: d.max_delay,
            queue_depth: d.queue_depth,
            partition_cores: true,
            device,
            seed: 7,
            fallback_schedule: None,
            supervision: crate::serve::SupervisorPolicy::default(),
        }
    }
}

/// Build one engine [`Tenant`] per spec: load its schedule, resolve its
/// network, derive its admission estimate, and assign disjoint cores.
pub fn build_engine_tenants(specs: &[TenantSpec], cfg: &TenancyConfig) -> Result<Vec<Tenant>> {
    let partitions: Vec<Option<CoreSet>> = if cfg.partition_cores && specs.len() > 1 {
        Topology::probe().partition(specs.len()).into_iter().map(Some).collect()
    } else {
        vec![None; specs.len()]
    };
    let fallback_schedule = match &cfg.fallback_schedule {
        Some(path) => Some(Schedule::load(path)?),
        None => None,
    };
    specs
        .iter()
        .zip(partitions)
        .enumerate()
        .map(|(i, (spec, partition))| {
            let schedule = Schedule::load(&spec.schedule_path)?;
            let net = zoo::by_name(&schedule.net).ok_or_else(|| {
                Error::Invalid(format!(
                    "tenant {:?}: schedule names unknown net {:?}",
                    spec.name, schedule.net
                ))
            })?;
            let image_ms =
                crate::synth::predict_schedule_latency_ms(&schedule, &net, &cfg.device)?;
            let params = EngineParams::random(&net, cfg.seed + i as u64, schedule.u)?;
            let cores = partition.or(schedule.pool.cores);
            let input_len = net.input.elements();
            // Degraded-mode factory: the fallback artifact with this
            // tenant's own weights, when the nets match.
            let fallback = fallback_schedule
                .as_ref()
                .filter(|f| f.net == schedule.net)
                .map(|f| {
                    EngineBackend::with_schedule(
                        net.clone(),
                        params.clone(),
                        f.clone(),
                        cfg.max_batch,
                    )
                    .factory()
                });
            let backend = EngineBackend::with_schedule(net, params, schedule, cfg.max_batch);
            Ok(Tenant {
                name: spec.name.clone(),
                factory: backend.factory(),
                policy: BatchPolicy {
                    max_batch: cfg.max_batch,
                    max_delay: cfg.max_delay,
                    queue_depth: cfg.queue_depth,
                    cores,
                },
                image_ms: Some(image_ms),
                input_len,
                fallback,
                supervision: cfg.supervision,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::serve::{Server, SloTable};
    use crate::soc::devices;
    use crate::util::rng::Rng;

    #[test]
    fn parse_models_accepts_pairs_and_rejects_garbage() {
        let specs = parse_models("a=schedule_a.json, b=schedule_b.json").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0], TenantSpec {
            name: "a".into(),
            schedule_path: "schedule_a.json".into()
        });
        assert_eq!(specs[1].name, "b");
        assert!(parse_models("").is_err());
        assert!(parse_models("a").is_err());
        assert!(parse_models("a=").is_err());
        assert!(parse_models("=x.json").is_err());
        assert!(parse_models("a=x.json,a=y.json").is_err());
    }

    #[test]
    fn tenants_from_schedules_serve_with_estimates_and_disjoint_cores() {
        // Write two distinct tinynet schedules, build tenants, and run a
        // request through each: the tune → serve artifact path end to
        // end, with per-tenant admission estimates attached.
        let dir = std::env::temp_dir().join(format!("capp-tenancy-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let net = zoo::tinynet();
        let s1 = Schedule::default_for(&net, 4);
        let mut s2 = Schedule::default_for(&net, 4);
        s2.pool.threads = 2;
        let p1 = dir.join("schedule_a.json");
        let p2 = dir.join("schedule_b.json");
        s1.save(&p1).unwrap();
        s2.save(&p2).unwrap();

        let specs = parse_models(&format!(
            "a={},b={}",
            p1.to_string_lossy(),
            p2.to_string_lossy()
        ))
        .unwrap();
        let cfg = TenancyConfig::new(devices::nexus5());
        let tenants = build_engine_tenants(&specs, &cfg).unwrap();
        assert_eq!(tenants.len(), 2);
        let cores: Vec<_> = tenants.iter().map(|t| t.policy.cores.unwrap()).collect();
        assert!(cores[0].disjoint(&cores[1]), "tenant core sets overlap");
        for t in &tenants {
            assert!(t.image_ms.unwrap() > 0.0);
            assert_eq!(t.input_len, 3 * 16 * 16);
        }

        let server = Server::start_tenants(tenants, SloTable::default()).unwrap();
        assert_eq!(server.tenants().len(), 2);
        let mut rng = Rng::new(9);
        for name in ["a", "b"] {
            let resp = server
                .router()
                .infer_blocking(name, rng.normal_vec(3 * 16 * 16))
                .unwrap();
            assert_eq!(resp.logits.len(), 8);
        }
        server.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fallback_schedule_attaches_to_matching_tenants_and_builds() {
        use crate::serve::Backend as _;
        let dir = std::env::temp_dir().join(format!("capp-fallback-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let net = zoo::tinynet();
        let primary = Schedule::default_for(&net, 4);
        let mut fb = Schedule::default_for(&net, 4);
        fb.pool.threads = 1;
        let p = dir.join("primary.json");
        let f = dir.join("fallback.json");
        primary.save(&p).unwrap();
        fb.save(&f).unwrap();

        let specs = parse_models(&format!("a={}", p.to_string_lossy())).unwrap();
        let mut cfg = TenancyConfig::new(devices::nexus5());
        cfg.fallback_schedule = Some(f.to_string_lossy().into_owned());
        let tenants = build_engine_tenants(&specs, &cfg).unwrap();
        let fallback = tenants[0].fallback.as_ref().expect("matching net must get a fallback");
        // The degraded-mode factory must build a working backend (and
        // stay re-invocable — call it twice).
        for _ in 0..2 {
            let b = fallback().unwrap();
            assert_eq!(b.input_len(), 3 * 16 * 16);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_schedule_and_unknown_net_are_typed_errors() {
        let cfg = TenancyConfig::new(devices::nexus5());
        let specs = vec![TenantSpec {
            name: "a".into(),
            schedule_path: "/nonexistent/schedule.json".into(),
        }];
        assert!(build_engine_tenants(&specs, &cfg).is_err());
    }
}
