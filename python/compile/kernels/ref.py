"""Pure-jnp reference oracle for the Cappuccino kernels, plus the layout
transforms the paper builds on (section IV.B).

Everything here is deliberately written in the most obvious way possible
(``lax.conv_general_dilated`` in NCHW, plain transposes for the map-major
reorder) so the Pallas kernels in ``conv.py`` / ``dense.py`` have an
independent ground truth.

Layout vocabulary used throughout the repo:

* ``nchw``      — conventional row-major feature maps, shape ``(C, H, W)``
                  (batched: ``(B, C, H, W)``).
* ``map-major`` — the paper's vector-friendly layout (Fig. 5): channels
                  are grouped into stacks of ``u``; within a stack, the
                  ``u`` channel values of one spatial position are
                  contiguous. Shape ``(Cb, H, W, u)`` with
                  ``Cb = ceil(C / u)`` (batched: ``(B, Cb, H, W, u)``).

Weights:

* conventional — ``(M, C, K, K)``
* map-major    — ``(Mb, u, Cb, K, K, u)``: output-channel stacks of ``u``
                  (dim 1 = output lane), input-channel stacks of ``u``
                  (last dim = input lane). This is the compile-time
                  parameter reordering of section III / IV.B.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# Smallest positive normal float32; used by the relaxed/imprecise modes to
# emulate RenderScript's non-IEEE handling of denormals (flush-to-zero).
F32_MIN_NORMAL = np.float32(2.0 ** -126)

MODES = ("precise", "relaxed", "imprecise")


# ---------------------------------------------------------------------------
# Layout transforms (paper section IV.B, Fig. 5 / Fig. 7)
# ---------------------------------------------------------------------------

def pad_channels(x_nchw: jnp.ndarray, u: int) -> jnp.ndarray:
    """Zero-pad the channel dim of a ``(C, H, W)`` tensor to a multiple of u."""
    c = x_nchw.shape[0]
    cb = math.ceil(c / u)
    pad = cb * u - c
    if pad == 0:
        return x_nchw
    return jnp.pad(x_nchw, ((0, pad), (0, 0), (0, 0)))


def nchw_to_mapmajor(x_nchw: jnp.ndarray, u: int) -> jnp.ndarray:
    """``(C, H, W)`` -> ``(Cb, H, W, u)`` map-major reorder (Fig. 5).

    Channel ``c`` lands in stack ``c // u``, lane ``c % u``. Channels are
    zero-padded up to a multiple of ``u`` first (the paper pads the input
    image from 3 to ``u`` maps implicitly through the weight reorder).
    """
    x = pad_channels(x_nchw, u)
    cb = x.shape[0] // u
    # (Cb, u, H, W) -> (Cb, H, W, u)
    return x.reshape(cb, u, *x.shape[1:]).transpose(0, 2, 3, 1)


def mapmajor_to_nchw(x_mm: jnp.ndarray, c: int | None = None) -> jnp.ndarray:
    """``(Cb, H, W, u)`` -> ``(C, H, W)``; drops channel padding if ``c`` given."""
    cb, h, w, u = x_mm.shape
    x = x_mm.transpose(0, 3, 1, 2).reshape(cb * u, h, w)
    if c is not None:
        x = x[:c]
    return x


def weights_to_mapmajor(w: jnp.ndarray, u: int) -> jnp.ndarray:
    """``(M, C, K, K)`` -> ``(Mb, u, Cb, K, K, u)`` compile-time reorder."""
    m, c, kh, kw = w.shape
    mb = math.ceil(m / u)
    cb = math.ceil(c / u)
    w = jnp.pad(w, ((0, mb * u - m), (0, cb * u - c), (0, 0), (0, 0)))
    # (Mb, u, Cb, u, K, K) -> (Mb, u, Cb, K, K, u)
    w = w.reshape(mb, u, cb, u, kh, kw)
    return w.transpose(0, 1, 2, 4, 5, 3)


def bias_to_mapmajor(b: jnp.ndarray, u: int) -> jnp.ndarray:
    """``(M,)`` -> ``(Mb, u)``."""
    m = b.shape[0]
    mb = math.ceil(m / u)
    return jnp.pad(b, (0, mb * u - m)).reshape(mb, u)


# ---------------------------------------------------------------------------
# Thread-id -> (w, h, m) mapping — equations (3), (4), (5)
# ---------------------------------------------------------------------------

def thread_index_to_whm(x: int, u: int, wout: int, hout: int) -> tuple[int, int, int]:
    """The paper's zero-overhead OFM reordering index math.

    Thread ``x`` produces output element ``(m, h, w)`` and stores it at
    offset ``x`` of the output buffer, which by construction is the
    map-major position of ``(m, h, w)``.
    """
    w = (x // u) % wout                         # eq. (3)
    h = (x // (u * wout)) % hout                # eq. (4)
    m = (x % u) + (x // (u * wout * hout)) * u  # eq. (5)
    return w, h, m


def whm_to_thread_index(w: int, h: int, m: int, u: int, wout: int, hout: int) -> int:
    """Inverse of eqs. (3)-(5): map-major linear offset of element (m, h, w)."""
    stack, lane = divmod(m, u)
    return lane + u * (w + wout * (h + hout * stack))


# ---------------------------------------------------------------------------
# Inexact arithmetic emulation (section IV.C)
# ---------------------------------------------------------------------------

def flush_denormals(x: jnp.ndarray) -> jnp.ndarray:
    """Flush-to-zero for float32 denormals; also canonicalises -0.0 -> +0.0.

    This emulates the RenderScript relaxed / imprecise floating-point
    contract ("operations resulting in -0.0 can return +0.0; denormalized
    numbers are not handled per IEEE 754").
    """
    flushed = jnp.where(jnp.abs(x) < F32_MIN_NORMAL, 0.0, x)
    return flushed + 0.0  # +0.0 canonicalises any remaining -0.0


def apply_mode_inputs(x: jnp.ndarray, mode: str) -> jnp.ndarray:
    """Transform operands according to the arithmetic mode.

    * ``precise``   — IEEE 754 float32, untouched.
    * ``relaxed``   — float32 with denormals flushed to zero.
    * ``imprecise`` — denormals flushed, then rounded to bfloat16 (the
      TPU-flavoured analogue of RenderScript's fast vectorised mode; see
      DESIGN.md Hardware-Adaptation).
    """
    if mode == "precise":
        return x
    if mode == "relaxed":
        return flush_denormals(x)
    if mode == "imprecise":
        return flush_denormals(x).astype(jnp.bfloat16)
    raise ValueError(f"unknown arithmetic mode: {mode!r}")


# ---------------------------------------------------------------------------
# Reference convolution / dense in conventional layout
# ---------------------------------------------------------------------------

def conv2d_nchw(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                stride: int = 1, pad: int = 0,
                mode: str = "precise") -> jnp.ndarray:
    """Reference conv: ``(C,H,W) x (M,C,K,K) -> (M,Hout,Wout)``.

    Accumulation is float32 in every mode; ``imprecise`` rounds the
    multiplication operands to bfloat16 first, mirroring the Pallas
    kernel's contract.
    """
    x = apply_mode_inputs(x, mode)
    w = apply_mode_inputs(w, mode)
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        precision=jax.lax.Precision.HIGHEST,
    )[0]
    return out + b[:, None, None]


def dense_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              mode: str = "precise") -> jnp.ndarray:
    """Reference fully-connected layer: ``(I,) x (O,I) -> (O,)``."""
    x = apply_mode_inputs(x, mode)
    w = apply_mode_inputs(w, mode)
    return jnp.dot(w.astype(jnp.float32), x.astype(jnp.float32),
                   precision=jax.lax.Precision.HIGHEST) + b


def conv_out_size(size: int, k: int, stride: int, pad: int) -> int:
    """Output spatial size of a convolution/pool window."""
    return (size + 2 * pad - k) // stride + 1
