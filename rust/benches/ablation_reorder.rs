//! Ablation: zero-overhead OFM reordering (paper section IV.B.1).
//!
//! Cappuccino writes OFMs directly in map-major order via the eq. (3)-(5)
//! index remap, so no transpose ever sits between layers. The naive
//! alternative (what the paper calls "expected to incur time and energy
//! overhead") computes each layer row-major and explicitly reorders its
//! output to map-major before the next layer.
//!
//! This bench measures both pipelines over multi-layer networks and
//! reports the explicit-reorder overhead that Cappuccino eliminates.

use cappuccino::bench::{bench, ms, BenchConfig, Table};
use cappuccino::config::parse_cappnet;
use cappuccino::engine::{
    run_baseline_legacy, ArithMode, EngineParams, ModeAssignment, PlanBuilder,
};
use cappuccino::layout;
use cappuccino::model::Network;
use cappuccino::util::rng::Rng;

/// Naive pipeline: per conv layer, run in row-major (scalar), then pay
/// an explicit nchw->mapmajor reorder of the OFMs (and back) to emulate
/// feeding a vector engine that needs map-major input. Returns total
/// reorder time fraction.
fn naive_with_explicit_reorder(net: &Network, params: &EngineParams, input: &[f32]) -> (Vec<f32>, f64, f64) {
    use std::time::Instant;
    // The baseline executor gives us the row-major pipeline; we charge
    // the explicit reorder per layer on top by replaying the layer
    // output shapes.
    let t0 = Instant::now();
    let out = run_baseline_legacy(net, params, input).unwrap();
    let compute_s = t0.elapsed().as_secs_f64();

    // Explicit per-layer reorder cost: transpose every conv OFM to
    // map-major and back (the dynamic reordering the paper avoids).
    let info = cappuccino::model::shapes::infer(net).unwrap();
    let mut rng = Rng::new(1);
    let mut reorder_s = 0.0;
    for cost in &info.costs {
        if cost.kind != "conv" {
            continue;
        }
        let pl = info.param_layer(&cost.name).unwrap();
        if let Ok((c, h, w)) = pl.output.as_maps() {
            let data = rng.normal_vec(c * h * w);
            let t = Instant::now();
            let mm = layout::nchw_to_mapmajor(&data, c, h, w, 4);
            std::hint::black_box(&mm);
            reorder_s += t.elapsed().as_secs_f64();
        }
    }
    (out, compute_s, reorder_s)
}

fn main() {
    let cfg = BenchConfig::from_env();
    let nets = [
        (
            "mini-squeeze",
            "net mini\ninput 3 63 63\nclasses 64\n\
             conv conv1 m=32 k=3 s=2 p=0\nmaxpool k=3 s=2\n\
             fire fire2 s1=16 e1=32 e3=32\nfire fire3 s1=16 e1=32 e3=32\n\
             conv conv4 m=64 k=1 s=1 p=0\ngap\n",
        ),
        (
            "tiny-deep",
            "net deep\ninput 3 32 32\nclasses 32\n\
             conv c1 m=16 k=3 s=1 p=1\nconv c2 m=16 k=3 s=1 p=1\n\
             maxpool k=2 s=2\nconv c3 m=32 k=3 s=1 p=1\nconv c4 m=32 k=3 s=1 p=1\n\
             maxpool k=2 s=2\nconv c5 m=32 k=3 s=1 p=1\ngap\n",
        ),
    ];

    let mut table = Table::new(&[
        "net", "fused-mm(ms)", "naive compute(ms)", "explicit reorder(ms)", "reorder share",
    ]);

    for (name, desc) in nets {
        let net = parse_cappnet(desc).unwrap();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let mut rng = Rng::new(9);
        let input = rng.normal_vec(net.input.elements());

        // Cappuccino pipeline: map-major end to end, zero reorders.
        // Compiled once — the wrapper would re-bake weights per call.
        let mut plan = PlanBuilder::new(&net, &params)
            .modes(&ModeAssignment::uniform(ArithMode::Imprecise))
            .build()
            .unwrap();
        let fused = bench("fused", cfg, || {
            std::hint::black_box(plan.run(&input).unwrap());
        });

        // Naive pipeline with explicit reorders.
        let mut compute_ms = 0.0;
        let mut reorder_ms = 0.0;
        let naive = bench("naive", cfg, || {
            let (out, c_s, r_s) = naive_with_explicit_reorder(&net, &params, &input);
            std::hint::black_box(out);
            compute_ms = c_s * 1e3;
            reorder_ms = r_s * 1e3;
        });
        let _ = naive;

        table.row(&[
            name.into(),
            ms(fused.mean_ms),
            ms(compute_ms),
            ms(reorder_ms),
            format!("{:.1}%", 100.0 * reorder_ms / (compute_ms + reorder_ms)),
        ]);
    }

    println!("# Ablation — zero-overhead OFM reordering (sec IV.B.1)\n");
    table.print();
    println!("\nCappuccino's map-major store (eqs. 3-5) removes the 'explicit");
    println!("reorder' column entirely; the naive pipeline pays it per layer.");
    println!("ablation_reorder bench OK");
}
