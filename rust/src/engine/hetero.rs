//! Heterogeneous staged execution: per-backend plan partitioning and
//! the pipelined multi-stage executor.
//!
//! A schedule whose layers name more than one
//! [`BackendTarget`] cannot run as one flat step walk: the steps
//! destined for the mock accelerator must execute on *its* executor,
//! and data crossing the boundary needs an explicit handoff. This
//! module turns a compiled [`ExecutionPlan`] into a [`StagedPlan`]:
//!
//! * the **partitioner** ([`StagedPlan::from_plan`]) cuts the flat step
//!   sequence into contiguous per-backend *stages* at backend
//!   boundaries. Every register defined in one stage and read in a
//!   later one is routed through a fresh *wire* register written by an
//!   explicit [`Step::Transfer`] appended at the end of the producing
//!   stage; downstream reads are remapped to the wire. An all-`native`
//!   schedule degenerates to a single stage whose step sequence is
//!   exactly the unstaged plan.
//! * the **verifier hook** ([`StagedPlan::verify`]) first proves the
//!   stage cuts sound (`stage-cut` rule: every cross-stage def crosses
//!   through exactly one transfer, no stage reads another stage's
//!   registers directly — see [`crate::engine::verify`]), then runs the
//!   full plan verifier over the rewritten step sequence.
//! * the **pipelined executor** ([`Pipeline`]) gives each stage a
//!   worker thread owning its backend executor
//!   ([`crate::runtime::backends::StageExecutor`]) and a clone of the
//!   plan's arena, connected by bounded queues. Consecutive batches
//!   overlap — batch *i* runs stage 2 while batch *i + 1* runs
//!   stage 1 — so steady-state throughput approaches the bottleneck
//!   stage's rate instead of the stage-time sum. Backpressure is the
//!   queue bound: `submit` blocks when the pipeline is full. Shutdown
//!   is lossless: dropping the pipeline completes every accepted batch
//!   before the queues close.
//!
//! Numerics: transfers are pure copies and the mock backend runs the
//! identical native kernels, so a staged plan — run via
//! [`StagedPlan::run_batch`], [`StagedPlan::run_batch_seq`] or the
//! [`Pipeline`] — is **bitwise identical** to the uniform single-backend
//! plan. The tests in `rust/tests/hetero.rs` hold that oracle across
//! splits, thread counts, capacities and partial batches.

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::thread::JoinHandle;

use crate::engine::plan::{ExecutionPlan, Step, StepKind};
use crate::engine::schedule::BackendTarget;
use crate::engine::verify::{step_dst, step_srcs};
use crate::runtime::backends::{BackendRegistry, StageExecutor};
use crate::util::error::{Error, Result};

/// One contiguous per-backend slice of a staged plan's step sequence.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// The backend every step in this stage runs on.
    pub(crate) backend: BackendTarget,
    /// Absolute step range in the staged plan (transfers included, at
    /// the end of the producing stage).
    pub(crate) range: Range<usize>,
    /// Wire registers this stage reads that earlier stages wrote.
    pub(crate) imports: Vec<usize>,
    /// Wire registers this stage's transfers write for later stages.
    pub(crate) exports: Vec<usize>,
}

impl StageSpec {
    /// The backend this stage runs on.
    pub fn backend(&self) -> BackendTarget {
        self.backend
    }

    /// Number of steps in this stage (transfers included).
    pub fn step_count(&self) -> usize {
        self.range.len()
    }
}

/// A plan partitioned into per-backend stages with explicit transfer
/// wires — see the module header. Holds one rewritten
/// [`ExecutionPlan`] (single arena: the sequential paths walk it
/// stage by stage) plus the stage table; the [`Pipeline`] clones the
/// plan per worker so stages can run concurrently.
pub struct StagedPlan {
    plan: ExecutionPlan,
    stages: Vec<StageSpec>,
}

impl StagedPlan {
    /// Partition a compiled plan at its schedule's backend boundaries.
    ///
    /// Each parameterised layer's steps take the backend its schedule
    /// entry names; structural steps (reorders, pools, the input
    /// prologue) inherit the surrounding stage's backend, the prologue
    /// that of the first layer. Contiguous same-backend runs become
    /// stages; every cross-stage (def stage < read stage) register is
    /// rewired through a [`Step::Transfer`]. In debug builds (or under
    /// `CAPPUCCINO_VERIFY=1`) the result is immediately re-proved:
    /// stage-cut soundness first, then the full plan verifier.
    pub fn from_plan(plan: &ExecutionPlan) -> Result<StagedPlan> {
        let n = plan.steps.len();
        // Per-step backend: a parameterised layer's label names its
        // schedule entry, structural steps ride the stage in progress.
        let first_backend = plan
            .labels
            .iter()
            .find_map(|l| plan.sched.layers.get(l).map(|s| s.backend))
            .unwrap_or(BackendTarget::Native);
        let mut cur = first_backend;
        let mut step_backend = Vec::with_capacity(n);
        for label in &plan.labels {
            if let Some(ls) = plan.sched.layers.get(label) {
                cur = ls.backend;
            }
            step_backend.push(cur);
        }
        // Contiguous same-backend runs become stages.
        let mut seams: Vec<(BackendTarget, Range<usize>)> = Vec::new();
        for (i, &b) in step_backend.iter().enumerate() {
            match seams.last_mut() {
                Some((rb, r)) if *rb == b => r.end = i + 1,
                _ => seams.push((b, i..i + 1)),
            }
        }
        let n_stages = seams.len();
        let mut stage_of = vec![0usize; n];
        for (t, (_, r)) in seams.iter().enumerate() {
            for i in r.clone() {
                stage_of[i] = t;
            }
        }
        // The IR is SSA: one defining step per register.
        let mut def_stage = vec![0usize; plan.slots.len()];
        for (i, step) in plan.steps.iter().enumerate() {
            def_stage[step_dst(step)] = stage_of[i];
        }
        // Allocate one wire register per cross-stage def.
        let mut slots = plan.slots.clone();
        let mut wire_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (i, step) in plan.steps.iter().enumerate() {
            for s in step_srcs(step) {
                if def_stage[s] < stage_of[i] && !wire_of.contains_key(&s) {
                    slots.push(plan.slots[s]);
                    wire_of.insert(s, slots.len() - 1);
                }
            }
        }
        debug_assert_eq!(
            def_stage[plan.out_slot],
            n_stages - 1,
            "the output register is defined by the final step, hence in the last stage"
        );
        // Rebuild the step sequence stage by stage: the stage's own
        // steps with cross-stage reads remapped onto wires, then the
        // transfers producing this stage's exports.
        let mut steps = Vec::with_capacity(n + wire_of.len());
        let mut labels = Vec::with_capacity(n + wire_of.len());
        let mut stages = Vec::with_capacity(n_stages);
        for (t, (backend, seam)) in seams.into_iter().enumerate() {
            let start = steps.len();
            let mut imports: Vec<usize> = Vec::new();
            for i in seam {
                let mut step = plan.steps[i].clone();
                remap_srcs(&mut step, |s| {
                    if def_stage[s] < t {
                        let w = wire_of[&s];
                        if !imports.contains(&w) {
                            imports.push(w);
                        }
                        w
                    } else {
                        s
                    }
                });
                steps.push(step);
                labels.push(plan.labels[i].clone());
            }
            let mut exports: Vec<usize> = Vec::new();
            for (&s, &w) in &wire_of {
                if def_stage[s] == t {
                    steps.push(Step::Transfer { src: s, dst: w });
                    labels.push(StepKind::Transfer.to_string());
                    exports.push(w);
                }
            }
            stages.push(StageSpec { backend, range: start..steps.len(), imports, exports });
        }
        let staged = StagedPlan {
            plan: plan.with_steps(slots, steps, labels, plan.out_slot),
            stages,
        };
        if cfg!(debug_assertions) || std::env::var_os("CAPPUCCINO_VERIFY").is_some_and(|v| v == "1")
        {
            staged.verify()?;
        }
        Ok(staged)
    }

    /// Number of stages (1 for a uniform schedule).
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// The per-stage backends, in execution order.
    pub fn stage_backends(&self) -> Vec<BackendTarget> {
        self.stages.iter().map(|s| s.backend).collect()
    }

    /// The stage table (ranges, imports, exports).
    pub fn stages(&self) -> &[StageSpec] {
        &self.stages
    }

    /// Prove this staged plan sound: stage-cut rules first (every
    /// cross-stage def crosses through exactly one transfer, no direct
    /// cross-stage reads, output in the final stage), then the full
    /// plan verifier over the rewritten step sequence.
    pub fn verify(&self) -> Result<()> {
        let ranges: Vec<Range<usize>> = self.stages.iter().map(|s| s.range.clone()).collect();
        crate::engine::verify::verify_stage_cuts(&self.plan, &ranges)?;
        self.plan.verify()
    }

    /// One flat walk of the staged step sequence — transfers included —
    /// on the native engine. This is the bitwise reference path: no
    /// backend dispatch, no sleeps, single arena.
    pub fn run_batch(&mut self, images: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.plan.run_batch(images)
    }

    /// Run one batch stage-by-stage **sequentially**, each stage on its
    /// resolved backend executor (mock latency applies). Bitwise
    /// identical to [`StagedPlan::run_batch`]; this is the baseline the
    /// pipelined executor's overlap win is measured against.
    pub fn run_batch_seq(
        &mut self,
        images: &[&[f32]],
        registry: &BackendRegistry,
    ) -> Result<Vec<Vec<f32>>> {
        self.plan.validate_batch(images)?;
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let live = images.len();
        let stages = &self.stages;
        let plan = &mut self.plan;
        for (t, spec) in stages.iter().enumerate() {
            let ex = registry.executor(spec.backend)?;
            let imgs: &[&[f32]] = if t == 0 { images } else { &[] };
            ex.run_stage(plan, spec.range.clone(), imgs, live)?;
        }
        let out_len = plan.output_len();
        let mut rows = Vec::with_capacity(live);
        for r in 0..live {
            let mut row = vec![0.0f32; out_len];
            plan.extract_row_into(r, &mut row);
            rows.push(row);
        }
        Ok(rows)
    }

    /// Wall-clock milliseconds per stage for one sequential walk of
    /// `images` — the autotuner's probe: predicted pipeline time is the
    /// **max** (bottleneck stage), sequential time the sum.
    pub fn stage_times_ms(
        &mut self,
        images: &[&[f32]],
        registry: &BackendRegistry,
    ) -> Result<Vec<f64>> {
        self.plan.validate_batch(images)?;
        let live = images.len();
        let stages = &self.stages;
        let plan = &mut self.plan;
        let mut times = Vec::with_capacity(stages.len());
        for (t, spec) in stages.iter().enumerate() {
            let ex = registry.executor(spec.backend)?;
            let imgs: &[&[f32]] = if t == 0 { images } else { &[] };
            let t0 = std::time::Instant::now();
            ex.run_stage(plan, spec.range.clone(), imgs, live)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        Ok(times)
    }

    /// Derive a sibling staged plan with a different batch capacity
    /// (steps and baked weights shared, arena re-sized — exactly
    /// [`ExecutionPlan::with_capacity`]).
    pub fn with_capacity(&self, batch: usize) -> StagedPlan {
        StagedPlan { plan: self.plan.with_capacity(batch), stages: self.stages.clone() }
    }

    /// Batch capacity of the underlying plan.
    pub fn capacity(&self) -> usize {
        self.plan.capacity()
    }

    /// Expected per-image input length.
    pub fn input_len(&self) -> usize {
        self.plan.input_len()
    }

    /// Logits length per image.
    pub fn output_len(&self) -> usize {
        self.plan.output_len()
    }

    /// Step kinds of the staged sequence, in order — the degenerate
    /// all-native case must equal the unstaged plan's kinds exactly.
    pub fn step_kinds(&self) -> Vec<StepKind> {
        self.plan.step_kinds()
    }

    /// Test-only corruption hook for the stage-cut mutation suite:
    /// apply `m` in place, returning `false` when the plan has no site
    /// it applies to (e.g. a single-stage plan has no transfers).
    /// Every [`StagedMutation`] leaves the *base* plan rules intact —
    /// only the `stage-cut` rule may reject it.
    #[doc(hidden)]
    pub fn apply_staged_mutation(&mut self, m: StagedMutation) -> bool {
        let first_transfer =
            self.plan.steps.iter().position(|s| matches!(s, Step::Transfer { .. }));
        match m {
            StagedMutation::DropTransfer => {
                // A copy is layout-legal between the identically-shaped
                // pair, but the wire is no longer transfer-written: its
                // cross-stage readers now read a plain register.
                let Some(i) = first_transfer else { return false };
                let Step::Transfer { src, dst } = self.plan.steps[i] else { unreachable!() };
                self.plan.steps[i] = Step::Copy { src, dst };
                self.plan.labels[i] = StepKind::Copy.to_string();
                true
            }
            StagedMutation::DoubleTransfer => {
                // Duplicate the transfer inside its own stage: the wire
                // is now defined twice, breaking exactly-one-transfer.
                let Some(i) = first_transfer else { return false };
                let step = self.plan.steps[i].clone();
                let label = self.plan.labels[i].clone();
                self.plan.steps.insert(i + 1, step);
                self.plan.labels.insert(i + 1, label);
                for spec in &mut self.stages {
                    if spec.range.contains(&i) {
                        spec.range.end += 1;
                    } else if spec.range.start > i {
                        spec.range.start += 1;
                        spec.range.end += 1;
                    }
                }
                true
            }
            StagedMutation::LeakCrossStageRead => {
                // Retarget one consumer's wire read back onto the
                // original register — a direct cross-stage read, which
                // def-before-use alone cannot catch.
                let mut orig_of: BTreeMap<usize, (usize, usize)> = BTreeMap::new();
                for (t, spec) in self.stages.iter().enumerate() {
                    for i in spec.range.clone() {
                        if let Step::Transfer { src, dst } = self.plan.steps[i] {
                            orig_of.insert(dst, (src, t));
                        }
                    }
                }
                for (t, spec) in self.stages.iter().enumerate() {
                    for i in spec.range.clone() {
                        let step = &mut self.plan.steps[i];
                        let leak = step_srcs(step).into_iter().find_map(|s| {
                            orig_of
                                .get(&s)
                                .filter(|&&(_, pt)| pt < t)
                                .map(|&(orig, _)| (s, orig))
                        });
                        if let Some((w, orig)) = leak {
                            remap_srcs(step, |s| if s == w { orig } else { s });
                            return true;
                        }
                    }
                }
                false
            }
        }
    }
}

/// Stage-cut-specific plan corruptions — each keeps the base plan
/// verifier green so the suite proves the `stage-cut` rule itself does
/// the rejecting. See [`StagedPlan::apply_staged_mutation`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagedMutation {
    /// Replace a transfer with a plain copy: the wire loses its
    /// transfer definition, so its cross-stage readers leak.
    DropTransfer,
    /// Duplicate a transfer: the wire is defined by two steps.
    DoubleTransfer,
    /// Retarget a consumer's wire read back onto the producing stage's
    /// original register.
    LeakCrossStageRead,
}

impl StagedMutation {
    /// Every staged mutation, for exhaustive suites.
    pub const ALL: [StagedMutation; 3] = [
        StagedMutation::DropTransfer,
        StagedMutation::DoubleTransfer,
        StagedMutation::LeakCrossStageRead,
    ];

    /// Stable name for diagnostics.
    pub fn as_str(&self) -> &'static str {
        match self {
            StagedMutation::DropTransfer => "drop-transfer",
            StagedMutation::DoubleTransfer => "double-transfer",
            StagedMutation::LeakCrossStageRead => "leak-cross-stage-read",
        }
    }
}

/// Remap every register a step reads through `f` (writes untouched).
fn remap_srcs(step: &mut Step, mut f: impl FnMut(usize) -> usize) {
    match step {
        Step::Input { .. } => {}
        Step::ConvMm { src, .. }
        | Step::ConvNchw { src, .. }
        | Step::PoolMm { src, .. }
        | Step::PoolNchw { src, .. }
        | Step::Lrn { src, .. }
        | Step::Gap { src, .. }
        | Step::Copy { src, .. }
        | Step::Dense { src, .. }
        | Step::Softmax { src, .. }
        | Step::Reorder { src, .. }
        | Step::Transfer { src, .. } => *src = f(*src),
        Step::Concat { srcs, .. } => {
            for s in srcs {
                *s = f(*s);
            }
        }
    }
}

/// One batch in flight through the pipeline.
struct Packet {
    live: usize,
    /// Request rows — consumed by the first stage's input prologue.
    images: Vec<Vec<f32>>,
    /// Wire payloads riding with the batch: `(wire register, live rows)`.
    wires: Vec<(usize, Vec<f32>)>,
    /// Filled by the final stage: one logits row per live image.
    rows: Vec<Vec<f32>>,
    /// First stage failure, if any — later stages skip, the error
    /// surfaces from [`Pipeline::recv`].
    err: Option<Error>,
}

/// The pipelined staged executor: one worker thread per stage, bounded
/// queues between them, batches overlapping across stages. See the
/// module header for semantics (FIFO results, backpressure on
/// [`Pipeline::submit`], lossless drop).
pub struct Pipeline {
    feed: Option<SyncSender<Packet>>,
    done: Receiver<Packet>,
    workers: Vec<JoinHandle<()>>,
    in_flight: usize,
    input_len: usize,
    capacity: usize,
    stage_count: usize,
}

impl Pipeline {
    /// Spin up one worker per stage of `staged`, each owning a clone of
    /// the plan (weights stay `Arc`-shared) and its backend's executor
    /// from `registry`; inter-stage queues hold at most `queue_depth`
    /// batches (min 1). Fails fast if any stage's backend has no
    /// executor (`pjrt`).
    pub fn new(
        staged: &StagedPlan,
        registry: &BackendRegistry,
        queue_depth: usize,
    ) -> Result<Pipeline> {
        let depth = queue_depth.max(1);
        let n = staged.stages.len();
        let mut execs = Vec::with_capacity(n);
        for spec in &staged.stages {
            execs.push(registry.executor(spec.backend)?);
        }
        // Producing stage of each wire, then the carry set per queue:
        // wires produced at or before stage k and imported after it
        // must ride the packet leaving stage k.
        let mut prod: BTreeMap<usize, usize> = BTreeMap::new();
        for (t, spec) in staged.stages.iter().enumerate() {
            for &w in &spec.exports {
                prod.insert(w, t);
            }
        }
        let mut carry: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (k, c) in carry.iter_mut().enumerate() {
            for spec in &staged.stages[k + 1..] {
                for &w in &spec.imports {
                    if prod.get(&w).is_some_and(|&p| p <= k) && !c.contains(&w) {
                        c.push(w);
                    }
                }
            }
        }
        let out_len = staged.plan.output_len();
        let (feed_tx, mut prev_rx) = mpsc::sync_channel::<Packet>(depth);
        let mut workers = Vec::with_capacity(n);
        for (k, ex) in execs.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<Packet>(depth);
            let rx_in = std::mem::replace(&mut prev_rx, rx);
            let mut plan = staged.plan.clone();
            let spec = staged.stages[k].clone();
            let carry_out = carry[k].clone();
            let first = k == 0;
            let last = k + 1 == n;
            let worker = std::thread::Builder::new()
                .name(format!("pipe-stage-{k}"))
                .spawn(move || {
                    stage_worker(rx_in, tx, &mut plan, &ex, &spec, &carry_out, first, last, out_len)
                })
                .map_err(|e| Error::Serve(format!("failed to spawn pipeline stage {k}: {e}")))?;
            workers.push(worker);
        }
        Ok(Pipeline {
            feed: Some(feed_tx),
            done: prev_rx,
            workers,
            in_flight: 0,
            input_len: staged.plan.input_len(),
            capacity: staged.plan.capacity(),
            stage_count: n,
        })
    }

    /// Feed one batch into the pipeline. Blocks when the first queue is
    /// full (backpressure); results come back in submission order from
    /// [`Pipeline::recv`].
    pub fn submit(&mut self, images: Vec<Vec<f32>>) -> Result<()> {
        if images.is_empty() {
            return Err(Error::Invalid("cannot submit an empty batch to the pipeline".into()));
        }
        if images.len() > self.capacity {
            return Err(Error::Invalid(format!(
                "batch of {} exceeds pipeline capacity {}",
                images.len(),
                self.capacity
            )));
        }
        for (i, img) in images.iter().enumerate() {
            if img.len() != self.input_len {
                return Err(Error::Shape(format!(
                    "batch row {i}: input len {} vs expected {}",
                    img.len(),
                    self.input_len
                )));
            }
        }
        let pkt = Packet {
            live: images.len(),
            images,
            wires: Vec::new(),
            rows: Vec::new(),
            err: None,
        };
        let feed = self.feed.as_ref().expect("pipeline feed open until drop");
        feed.send(pkt).map_err(|_| Error::Serve("pipeline stage workers exited".into()))?;
        self.in_flight += 1;
        Ok(())
    }

    /// Receive the oldest in-flight batch's logits rows (FIFO — stages
    /// are single workers over order-preserving queues). A stage
    /// failure for that batch surfaces here; later batches are
    /// unaffected.
    pub fn recv(&mut self) -> Result<Vec<Vec<f32>>> {
        if self.in_flight == 0 {
            return Err(Error::Invalid("pipeline has no in-flight batch to receive".into()));
        }
        let pkt = self
            .done
            .recv()
            .map_err(|_| Error::Serve("pipeline stage workers exited".into()))?;
        self.in_flight -= 1;
        match pkt.err {
            Some(e) => Err(e),
            None => Ok(pkt.rows),
        }
    }

    /// Synchronous convenience: submit one batch and wait for its rows.
    /// No overlap — callers wanting pipelining keep several batches in
    /// flight via [`Pipeline::submit`]/[`Pipeline::recv`].
    pub fn infer_batch(&mut self, images: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        self.submit(images.iter().map(|r| r.to_vec()).collect())?;
        self.recv()
    }

    /// Batches currently inside the pipeline.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Number of pipeline stages.
    pub fn stage_count(&self) -> usize {
        self.stage_count
    }

    /// Batch capacity per submitted batch.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Lossless shutdown: complete every accepted batch, then close
        // the feed so the workers drain their queues and exit.
        while self.in_flight > 0 {
            if self.done.recv().is_err() {
                break;
            }
            self.in_flight -= 1;
        }
        self.feed.take();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// One stage's worker loop: receive a batch, load its imported wires
/// into the arena, run the stage range on this stage's executor, then
/// forward the packet — export wires copied out for downstream stages,
/// or logits rows extracted if this is the final stage. A failed batch
/// is passed through untouched so the error reaches [`Pipeline::recv`]
/// in order.
#[allow(clippy::too_many_arguments)]
fn stage_worker(
    rx: Receiver<Packet>,
    tx: SyncSender<Packet>,
    plan: &mut ExecutionPlan,
    ex: &StageExecutor,
    spec: &StageSpec,
    carry_out: &[usize],
    first: bool,
    last: bool,
    out_len: usize,
) {
    while let Ok(mut pkt) = rx.recv() {
        if pkt.err.is_none() {
            for (slot, buf) in &pkt.wires {
                if spec.imports.contains(slot) {
                    plan.arena.bufs[*slot][..buf.len()].copy_from_slice(buf);
                }
            }
            let result = {
                let img_refs: Vec<&[f32]> = if first {
                    pkt.images.iter().map(|v| v.as_slice()).collect()
                } else {
                    Vec::new()
                };
                ex.run_stage(plan, spec.range.clone(), &img_refs, pkt.live)
            };
            match result {
                Ok(()) => {
                    pkt.images.clear();
                    if last {
                        let mut rows = Vec::with_capacity(pkt.live);
                        for r in 0..pkt.live {
                            let mut row = vec![0.0f32; out_len];
                            plan.extract_row_into(r, &mut row);
                            rows.push(row);
                        }
                        pkt.rows = rows;
                        pkt.wires.clear();
                    } else {
                        let mut fwd = Vec::with_capacity(carry_out.len());
                        for &w in carry_out {
                            if spec.exports.contains(&w) {
                                let len = pkt.live * plan.slots[w].len();
                                fwd.push((w, plan.arena.bufs[w][..len].to_vec()));
                            } else if let Some(pos) =
                                pkt.wires.iter().position(|&(s, _)| s == w)
                            {
                                fwd.push(pkt.wires.swap_remove(pos));
                            }
                        }
                        pkt.wires = fwd;
                    }
                }
                Err(e) => pkt.err = Some(e),
            }
        }
        if tx.send(pkt).is_err() {
            break;
        }
    }
    // tx drops here: the downstream worker drains its queue, then exits.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::verify::VerifyRule;
    use crate::engine::{
        ArithMode, EngineParams, ModeAssignment, Parallelism, PlanBuilder, PoolSettings, Schedule,
    };
    use crate::model::zoo;
    use crate::util::rng::Rng;

    fn staged_schedule(net: &crate::model::Network, mock_layers: &[&str]) -> Schedule {
        let mut sched = Schedule::from_uniform(
            net,
            4,
            &ModeAssignment::uniform(ArithMode::Imprecise),
            Parallelism::Olp,
            true,
            None,
            PoolSettings { threads: 2, affinity: false, cores: None },
        )
        .unwrap();
        for (name, ls) in sched.layers.iter_mut() {
            if mock_layers.contains(&name.as_str()) {
                ls.backend = BackendTarget::Mock;
            }
        }
        sched
    }

    #[test]
    fn uniform_schedule_is_single_stage_with_identical_steps() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let plan = PlanBuilder::new(&net, &params).build().unwrap();
        let staged = StagedPlan::from_plan(&plan).unwrap();
        assert_eq!(staged.stage_count(), 1);
        assert_eq!(staged.step_kinds(), plan.step_kinds());
        assert!(staged.stages()[0].imports.is_empty());
        assert!(staged.stages()[0].exports.is_empty());
    }

    #[test]
    fn split_plan_inserts_transfers_and_stays_bitwise() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let sched = staged_schedule(&net, &["conv2"]);
        let mut uniform = PlanBuilder::new(&net, &params).build().unwrap();
        let plan = PlanBuilder::new(&net, &params).schedule(sched).build().unwrap();
        let mut staged = StagedPlan::from_plan(&plan).unwrap();
        assert!(staged.stage_count() >= 2, "conv2 on mock must cut the plan");
        assert!(staged.step_kinds().contains(&StepKind::Transfer));
        staged.verify().unwrap();
        let img = Rng::new(11).normal_vec(uniform.input_len());
        let want = uniform.run(&img).unwrap();
        let got = staged.run_batch(&[&img[..]]).unwrap();
        assert_eq!(got[0], want, "staged flat walk must be bitwise identical");
        let reg = BackendRegistry::default();
        let got_seq = staged.run_batch_seq(&[&img[..]], &reg).unwrap();
        assert_eq!(got_seq[0], want, "sequential staged walk must be bitwise identical");
        let mut pipe = Pipeline::new(&staged, &reg, 2).unwrap();
        let got_pipe = pipe.infer_batch(&[&img[..]]).unwrap();
        assert_eq!(got_pipe[0], want, "pipelined walk must be bitwise identical");
    }

    #[test]
    fn staged_mutations_reject_on_stage_cut_rule_only() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let sched = staged_schedule(&net, &["conv2"]);
        let plan = PlanBuilder::new(&net, &params).schedule(sched).build().unwrap();
        for m in StagedMutation::ALL {
            let mut staged = StagedPlan::from_plan(&plan).unwrap();
            assert!(staged.apply_staged_mutation(m), "mutation {} must apply", m.as_str());
            let err = staged.verify().expect_err("mutated staged plan must be rejected");
            match err {
                Error::Verify { rule, .. } => assert_eq!(
                    rule,
                    VerifyRule::StageCut,
                    "mutation {} must trip the stage-cut rule",
                    m.as_str()
                ),
                other => panic!("expected a verify error, got {other}"),
            }
        }
    }

    #[test]
    fn pipeline_overlaps_and_preserves_fifo_order() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let sched = staged_schedule(&net, &["conv2"]);
        let plan =
            PlanBuilder::new(&net, &params).schedule(sched).batch(2).build().unwrap();
        let mut staged = StagedPlan::from_plan(&plan).unwrap();
        let reg = BackendRegistry::default();
        let imgs: Vec<Vec<f32>> =
            (0..4).map(|i| Rng::new(100 + i).normal_vec(staged.input_len())).collect();
        let mut want = Vec::new();
        for img in &imgs {
            want.push(staged.run_batch(&[&img[..]]).unwrap().remove(0));
        }
        let mut pipe = Pipeline::new(&staged, &reg, 2).unwrap();
        for img in &imgs {
            pipe.submit(vec![img.clone()]).unwrap();
        }
        assert_eq!(pipe.in_flight(), 4);
        for w in &want {
            let rows = pipe.recv().unwrap();
            assert_eq!(&rows[0], w, "pipeline must return batches in submission order");
        }
        assert_eq!(pipe.in_flight(), 0);
    }

    #[test]
    fn pipeline_rejects_bad_batches_and_drains_on_drop() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let sched = staged_schedule(&net, &["conv2"]);
        let plan = PlanBuilder::new(&net, &params).schedule(sched).build().unwrap();
        let staged = StagedPlan::from_plan(&plan).unwrap();
        let reg = BackendRegistry::default();
        let mut pipe = Pipeline::new(&staged, &reg, 1).unwrap();
        assert!(matches!(pipe.submit(Vec::new()), Err(Error::Invalid(_))));
        assert!(matches!(pipe.submit(vec![vec![0.0; 3]]), Err(Error::Shape(_))));
        assert!(matches!(pipe.recv(), Err(Error::Invalid(_))));
        // Leave a batch in flight: drop must complete it, not lose it.
        let img = Rng::new(5).normal_vec(staged.input_len());
        pipe.submit(vec![img]).unwrap();
        drop(pipe);
    }
}
