//! Cappuccino's two file-format inputs (paper Fig. 3):
//!
//! * [`cappnet`] — the *network description file*: a line-oriented text
//!   format describing layer structure (`.cappnet`).
//! * [`modelfile`] — the *model file*: named f32 tensors holding weight
//!   and bias values (`.capp`), format shared with
//!   `python/compile/modelfile.py`.
//!
//! The third input, the validation dataset, lives in [`crate::data`].

pub mod cappnet;
pub mod modelfile;

pub use cappnet::{parse_cappnet, write_cappnet};
pub use modelfile::ModelFile;
