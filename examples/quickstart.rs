//! Quickstart: the whole Cappuccino flow on a small custom network.
//!
//! 1. Describe a CNN in the `.cappnet` text format (paper Fig. 3 input #1).
//! 2. Synthesize the primary parallel program (OLP + map-major, sec IV).
//! 3. Compile weights (compile-time parameter reordering, sec III).
//! 4. Execute on the native engine in precise and imprecise modes.
//! 5. Predict latency on the simulated device catalog.
//!
//! Run: `cargo run --release --example quickstart`

use cappuccino::config::parse_cappnet;
use cappuccino::engine::{ArithMode, EngineParams, ModeAssignment};
use cappuccino::soc;
use cappuccino::synth::{execute_plan, finalize, predict_latency_ms, PrimarySynthesizer};
use cappuccino::util::rng::Rng;

const NETWORK: &str = "
# A small SqueezeNet-flavoured classifier.
net demo
input 3 32 32
classes 16

conv conv1 m=16 k=3 s=2 p=1
fire fire2 s1=8 e1=16 e3=16
fire fire3 s1=8 e1=16 e3=16
maxpool k=2 s=2
conv conv4 m=16 k=1 s=1 p=0
gap
";

fn main() -> cappuccino::Result<()> {
    // 1. Network description -> IR (validated by shape inference).
    let net = parse_cappnet(NETWORK)?;
    let info = cappuccino::model::shapes::infer(&net)?;
    println!(
        "network {:?}: {} param layers, {:.1} MFLOPs/inference",
        net.name,
        net.param_layer_names().len(),
        info.total_flops() / 1e6
    );

    // 2. Primary program synthesis: OLP thread allocation, u=4 vectors.
    let primary = PrimarySynthesizer::new(4, 2).synthesize(&net)?;
    println!(
        "primary plan: {} layers, all {}, alpha(conv1) = {}",
        primary.layers.len(),
        primary.layers[0].mode,
        primary.layers[0].alpha
    );

    // 3. "Model file": random weights here; EngineParams::compile reorders
    //    conventional weights into map-major at compile time.
    let params = EngineParams::random(&net, 42, 4)?;

    // 4. Final software: adopt imprecise arithmetic everywhere (the
    //    paper's measured outcome) and execute both variants.
    let plan_precise = primary.clone();
    let plan_imprecise = finalize(&primary, &ModeAssignment::uniform(ArithMode::Imprecise));

    let mut rng = Rng::new(7);
    let image = rng.normal_vec(net.input.elements());
    let logits_p = execute_plan(&plan_precise, &net, &params, &image)?;
    let logits_i = execute_plan(&plan_imprecise, &net, &params, &image)?;
    let argmax = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    };
    println!("precise   logits[0..4] = {:?} -> class {}", &logits_p[..4], argmax(&logits_p));
    println!("imprecise logits[0..4] = {:?} -> class {}", &logits_i[..4], argmax(&logits_i));
    assert_eq!(argmax(&logits_p), argmax(&logits_i), "modes must agree on the class");

    // 5. Predicted latency on the paper's three phones.
    println!("\npredicted latency (simulated devices):");
    for d in soc::catalog() {
        println!(
            "  {:<10} precise {:>8.3} ms   imprecise {:>8.3} ms",
            d.name,
            predict_latency_ms(&plan_precise, &net, &d),
            predict_latency_ms(&plan_imprecise, &net, &d),
        );
    }
    println!("\nquickstart OK");
    Ok(())
}
