//! Arithmetic modes (paper section IV.C).
//!
//! RenderScript's precise / relaxed / imprecise floating-point contracts
//! mapped to this testbed (DESIGN.md "Hardware-Adaptation"):
//!
//! * [`ArithMode::Precise`] — IEEE 754 f32, denormals honoured.
//! * [`ArithMode::Relaxed`] — f32, denormal operands flushed to zero,
//!   `-0.0` canonicalised to `+0.0`.
//! * [`ArithMode::Imprecise`] — operands additionally rounded to
//!   bfloat16 before multiplication (f32 accumulation) — the TPU-MXU
//!   analogue of RenderScript's fast vectorised mode.
//! * [`ArithMode::QuantI8`] — the real quantized mode: per-layer
//!   symmetric `i8` (scale = `amax/127`, zero-point 0). Weights are
//!   quantized and baked into the packed panels at plan-compile time,
//!   activations are quantized dynamically per image, kernels
//!   accumulate in widening `i32` and requantize back to f32 on store
//!   (`acc * s_x * s_w + bias`). Packed-plan only: the legacy
//!   executors and the f32 parity oracles never see it, so it is
//!   excluded from [`ArithMode::ALL`].
//!
//! The non-Precise modes unlock the vectorised inner loops
//! ([`crate::engine::simd`]), mirroring "vector processing is only
//! available under imprecise computing modes" — [`ArithMode::Precise`]
//! always takes the scalar path. For the f32 modes this is purely a
//! speed choice: the vector kernels are bitwise identical to their
//! scalar oracles.

use std::fmt;
use std::str::FromStr;

/// Smallest positive normal f32 (denormal threshold).
pub const F32_MIN_NORMAL: f32 = 1.17549435e-38;

/// Arithmetic mode for a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ArithMode {
    Precise,
    Relaxed,
    Imprecise,
    /// Symmetric per-layer int8: quantized weights baked into the
    /// packed panels, dynamic activation quantization, widening `i32`
    /// accumulation. Lowered only by the compiled plan's packed path —
    /// shapes that cannot be lane-padded are rejected with
    /// [`crate::Error::Config`] at plan compile.
    QuantI8,
}

impl ArithMode {
    /// The f32 modes — every mode the legacy executors and the bitwise
    /// parity oracles support. [`ArithMode::QuantI8`] is deliberately
    /// excluded: it lowers only through the packed compiled plan and is
    /// accuracy-gated (tolerance-based, not bitwise) via
    /// `inexact::evaluate_accuracy`.
    pub const ALL: [ArithMode; 3] = [ArithMode::Precise, ArithMode::Relaxed, ArithMode::Imprecise];

    pub fn as_str(&self) -> &'static str {
        match self {
            ArithMode::Precise => "precise",
            ArithMode::Relaxed => "relaxed",
            ArithMode::Imprecise => "imprecise",
            ArithMode::QuantI8 => "quant_i8",
        }
    }

    /// Does this mode unlock the vectorised inner loop? (Paper: vector
    /// processing is only available under the non-IEEE modes.) This is
    /// not cosmetic: the plan lowerer consults it when selecting the
    /// kernel, so Precise layers always run the scalar path.
    pub fn vectorized(&self) -> bool {
        !matches!(self, ArithMode::Precise)
    }

    /// Is this the quantized-int8 mode?
    pub fn quantized(&self) -> bool {
        matches!(self, ArithMode::QuantI8)
    }
}

impl fmt::Display for ArithMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for ArithMode {
    type Err = crate::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "precise" => Ok(ArithMode::Precise),
            "relaxed" => Ok(ArithMode::Relaxed),
            "imprecise" => Ok(ArithMode::Imprecise),
            "quant_i8" => Ok(ArithMode::QuantI8),
            other => Err(crate::Error::Invalid(format!("unknown arithmetic mode {other:?}"))),
        }
    }
}

/// Round an f32 to bfloat16 (round-to-nearest-even) and back.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    // RNE on the low 16 bits.
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Flush denormals to +0.0 (also canonicalises -0.0).
#[inline]
pub fn flush_denormal(x: f32) -> f32 {
    if x.abs() < F32_MIN_NORMAL {
        0.0
    } else {
        x
    }
}

/// Operand transform for a mode — mirrors `ref.apply_mode_inputs`.
#[inline]
pub fn mode_cast(x: f32, mode: ArithMode) -> f32 {
    match mode {
        ArithMode::Precise => x,
        ArithMode::Relaxed => flush_denormal(x),
        ArithMode::Imprecise => bf16_round(flush_denormal(x)),
        // Quantization is not an elementwise f32 -> f32 map (it needs
        // the tensor's amax); the QuantI8 kernels own it. The f32 view
        // of a QuantI8 operand is the identity.
        ArithMode::QuantI8 => x,
    }
}

/// Symmetric per-tensor i8 quantization: scale = `amax/127`,
/// zero-point 0, round-to-nearest. Returns `(values, scale)`;
/// an all-zero (or non-finite-free empty) tensor gets scale 1.0.
pub fn quantize_symmetric(src: &[f32]) -> (Vec<i8>, f32) {
    let mut q = vec![0i8; src.len()];
    let scale = quantize_symmetric_into(src, &mut q);
    (q, scale)
}

/// In-place variant of [`quantize_symmetric`] — the plan executor's
/// per-image activation quantization path (arena scratch, zero
/// allocation). Returns the scale.
pub(crate) fn quantize_symmetric_into(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let amax = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax <= 0.0 || !amax.is_finite() {
        dst.fill(0);
        return 1.0;
    }
    let inv = 127.0 / amax;
    for (d, &s) in dst.iter_mut().zip(src) {
        // `as` saturates, so the max-magnitude element maps to +-127.
        *d = (s * inv).round() as i8;
    }
    amax / 127.0
}

/// Elementwise `mode_cast` of a whole slice into a caller-owned buffer
/// (the plan executor's activation-cast scratch path).
pub(crate) fn cast_slice_into(src: &[f32], mode: ArithMode, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = mode_cast(s, mode);
    }
}

/// Static-dispatch operand transform: the engine's inner loops are
/// generic over this so Precise pays zero per-element cost.
pub trait ModeOps: Copy + Send + Sync + 'static {
    const MODE: ArithMode;
    fn cast(x: f32) -> f32;
}

/// IEEE f32.
#[derive(Clone, Copy)]
pub struct Precise;

/// Flush-to-zero f32.
#[derive(Clone, Copy)]
pub struct Relaxed;

/// bf16 operands, f32 accumulate, flush-to-zero.
#[derive(Clone, Copy)]
pub struct Imprecise;

impl ModeOps for Precise {
    const MODE: ArithMode = ArithMode::Precise;
    #[inline(always)]
    fn cast(x: f32) -> f32 {
        x
    }
}

impl ModeOps for Relaxed {
    const MODE: ArithMode = ArithMode::Relaxed;
    #[inline(always)]
    fn cast(x: f32) -> f32 {
        flush_denormal(x)
    }
}

impl ModeOps for Imprecise {
    const MODE: ArithMode = ArithMode::Imprecise;
    #[inline(always)]
    fn cast(x: f32) -> f32 {
        bf16_round(flush_denormal(x))
    }
}

/// Run `f` monomorphised for `mode`.
#[inline]
pub fn with_mode<R>(mode: ArithMode, f: impl FnOnce(ArithMode) -> R) -> R {
    f(mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in ArithMode::ALL.into_iter().chain([ArithMode::QuantI8]) {
            assert_eq!(m.as_str().parse::<ArithMode>().unwrap(), m);
        }
        assert!("fast".parse::<ArithMode>().is_err());
        // ALL stays the f32 / legacy-oracle set.
        assert!(!ArithMode::ALL.contains(&ArithMode::QuantI8));
    }

    #[test]
    fn bf16_round_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(0.0), 0.0);
        // 1.00390625 = 1 + 2^-8: exactly the bf16 ulp at 1.0; RNE to even.
        let x = 1.0 + 2.0_f32.powi(-8);
        let r = bf16_round(x);
        assert!(r == 1.0 || r == 1.0 + 2.0_f32.powi(-7));
        // Relative error of bf16 rounding is <= 2^-8.
        for &v in &[3.14159f32, -2.71828, 1e10, -1e-10, 123.456] {
            let r = bf16_round(v);
            assert!(((r - v) / v).abs() <= 0.00391, "{v} -> {r}");
        }
    }

    #[test]
    fn bf16_preserves_specials() {
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn flush_denormal_contract() {
        assert_eq!(flush_denormal(1e-40), 0.0);
        assert_eq!(flush_denormal(-1e-40), 0.0);
        assert_eq!(flush_denormal(1e-3), 1e-3);
        // -0.0 canonicalised: sign bit cleared.
        assert!(flush_denormal(-0.0).is_sign_positive());
    }

    #[test]
    fn mode_cast_matches_python_oracle() {
        // Matches ref.apply_mode_inputs semantics.
        assert_eq!(mode_cast(1e-40, ArithMode::Precise), 1e-40);
        assert_eq!(mode_cast(1e-40, ArithMode::Relaxed), 0.0);
        assert_eq!(mode_cast(0.15625, ArithMode::Imprecise), 0.15625); // exact in bf16
    }

    #[test]
    fn vectorized_flag() {
        assert!(!ArithMode::Precise.vectorized());
        assert!(ArithMode::Relaxed.vectorized());
        assert!(ArithMode::Imprecise.vectorized());
        assert!(ArithMode::QuantI8.vectorized());
        assert!(ArithMode::QuantI8.quantized());
        assert!(!ArithMode::Imprecise.quantized());
    }

    #[test]
    fn quantize_symmetric_contract() {
        // amax element maps to +-127, scale reconstructs within 1/254.
        let (q, s) = quantize_symmetric(&[0.5, -1.0, 0.25, 0.0]);
        assert_eq!(s, 1.0 / 127.0);
        assert_eq!(q, vec![64, -127, 32, 0]);
        for (&qi, &xi) in q.iter().zip(&[0.5f32, -1.0, 0.25, 0.0]) {
            assert!((qi as f32 * s - xi).abs() <= s / 2.0 + 1e-7);
        }
        // Degenerate tensors quantize to zeros with scale 1.
        let (q, s) = quantize_symmetric(&[0.0, 0.0]);
        assert_eq!((q, s), (vec![0, 0], 1.0));
        let (q, s) = quantize_symmetric(&[f32::INFINITY, 1.0]);
        assert_eq!((q, s), (vec![0, 0], 1.0));
    }
}
