//! Heterogeneous staged-execution suite (`engine::hetero` +
//! `runtime::backends`): the three load-bearing guarantees of the
//! subsystem, checked from outside the crate.
//!
//! 1. **Degenerate soundness** — an all-Native schedule partitions to
//!    exactly one stage whose step sequence *is* the flat plan's, and
//!    stays bitwise identical across thread counts and capacities.
//! 2. **Split parity** — a Native→Mock→Native split (cut at a
//!    map-major/row-major kernel-family boundary) is bitwise identical
//!    to the uniform plan through every execution path: the fused
//!    walk, the sequential staged walk, and the overlapping pipeline —
//!    including partial batches. The same holds for a Native+Mock
//!    split on every zoo net (the acceptance bar).
//! 3. **Verifier teeth** — every transfer-level corruption of a staged
//!    plan is rejected by `verify()` with the stage-cut rule.
//!
//! Plus the strict-parse regression: the misspelled-key fixture loads
//! leniently (typo ignored, backend stays Native) and is rejected by
//! the strict path.

use cappuccino::engine::{
    ArithMode, BackendTarget, EngineParams, ModeAssignment, Parallelism, Pipeline, PlanBuilder,
    PoolSettings, Schedule, StagedMutation, StagedPlan, VerifyRule,
};
use cappuccino::model::{zoo, Network};
use cappuccino::runtime::backends::BackendRegistry;
use cappuccino::util::rng::Rng;
use cappuccino::Error;

/// Uniform (all-Native) schedule over `net` at vector width 4.
fn uniform_sched(net: &Network, threads: usize) -> Schedule {
    Schedule::from_uniform(
        net,
        4,
        &ModeAssignment::uniform(ArithMode::Imprecise),
        Parallelism::Olp,
        true,
        None,
        PoolSettings { threads, affinity: false, cores: None },
    )
    .unwrap()
}

fn images(net: &Network, n: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n).map(|i| Rng::new(seed + i as u64).normal_vec(net.input.elements())).collect()
}

#[test]
fn all_native_schedule_is_the_flat_plan_at_every_shape() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 7, 4).unwrap();
    let registry = BackendRegistry::default();
    let imgs = images(&net, 3, 40);
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();

    // Reference: the plain single-threaded flat plan.
    let mut reference_plan =
        PlanBuilder::new(&net, &params).schedule(uniform_sched(&net, 1)).batch(3).build().unwrap();
    let reference = reference_plan.run_batch(&refs).unwrap();

    for &threads in &[1usize, 2, 4] {
        for &cap in &[1usize, 4, 8] {
            let plan = PlanBuilder::new(&net, &params)
                .schedule(uniform_sched(&net, threads))
                .batch(cap)
                .build()
                .unwrap();
            let mut staged = StagedPlan::from_plan(&plan).unwrap();
            // One stage, and its step sequence is exactly the flat
            // plan's — no transfers, no reordering (satellite c).
            assert_eq!(staged.stage_count(), 1, "t={threads} cap={cap}");
            assert_eq!(staged.stage_backends(), vec![BackendTarget::Native]);
            assert_eq!(staged.step_kinds(), plan.step_kinds(), "t={threads} cap={cap}");
            staged.verify().unwrap();
            let live = cap.min(3);
            let got = staged.run_batch_seq(&refs[..live], &registry).unwrap();
            assert_eq!(got, reference[..live].to_vec(), "t={threads} cap={cap} live={live}");
        }
    }
}

#[test]
fn native_mock_native_split_is_bitwise_through_every_path() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 11, 4).unwrap();
    let registry = BackendRegistry::default();
    let imgs = images(&net, 4, 90);
    let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();

    // conv2 runs row-major FLP while its neighbours run packed
    // map-major OLP, so both stage cuts sit on a kernel-family (and
    // layout) boundary — the hardest seam to get bitwise right.
    let mk = || {
        let mut s = uniform_sched(&net, 2);
        s.layers.get_mut("conv2").unwrap().parallelism = Parallelism::Flp;
        s
    };
    let mut uniform_plan =
        PlanBuilder::new(&net, &params).schedule(mk()).batch(4).build().unwrap();
    let mut split = mk();
    split.layers.get_mut("conv2").unwrap().backend = BackendTarget::Mock;
    let split_plan = PlanBuilder::new(&net, &params).schedule(split).batch(4).build().unwrap();

    let mut staged = StagedPlan::from_plan(&split_plan).unwrap();
    assert_eq!(
        staged.stage_backends(),
        vec![BackendTarget::Native, BackendTarget::Mock, BackendTarget::Native],
        "conv2-on-mock must partition Native -> Mock -> Native"
    );
    staged.verify().unwrap();

    // Full and partial batches, through all three execution paths.
    for &live in &[1usize, 3, 4] {
        let want = uniform_plan.run_batch(&refs[..live]).unwrap();
        assert_eq!(staged.run_batch(&refs[..live]).unwrap(), want, "fused walk, live={live}");
        assert_eq!(
            staged.run_batch_seq(&refs[..live], &registry).unwrap(),
            want,
            "sequential staged walk, live={live}"
        );
        let mut pipe = Pipeline::new(&staged, &registry, 2).unwrap();
        assert_eq!(pipe.infer_batch(&refs[..live]).unwrap(), want, "pipeline, live={live}");
    }
}

#[test]
fn every_zoo_net_native_mock_split_is_bitwise_identical() {
    let registry = BackendRegistry::default();
    for net in zoo::all() {
        let params = EngineParams::random(&net, 17, 4).unwrap();
        let names = net.param_layer_names();
        assert!(names.len() >= 2, "{}: need two param layers to split", net.name);
        let mut split = uniform_sched(&net, 2);
        for name in &names[names.len() / 2..] {
            split.layers.get_mut(name.as_str()).unwrap().backend = BackendTarget::Mock;
        }
        let mut uniform_plan = PlanBuilder::new(&net, &params)
            .schedule(uniform_sched(&net, 2))
            .batch(1)
            .build()
            .unwrap();
        let split_plan =
            PlanBuilder::new(&net, &params).schedule(split).batch(1).build().unwrap();
        let mut staged = StagedPlan::from_plan(&split_plan).unwrap();
        assert!(staged.stage_count() >= 2, "{}: split schedule must stage", net.name);
        staged.verify().unwrap();

        let imgs = images(&net, 1, 170);
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();
        let want = uniform_plan.run_batch(&refs).unwrap();
        assert_eq!(
            staged.run_batch_seq(&refs, &registry).unwrap(),
            want,
            "{}: staged walk diverged from the uniform plan",
            net.name
        );
    }
}

#[test]
fn staged_corruptions_are_rejected_with_the_stage_cut_rule() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 23, 4).unwrap();
    let mut split = uniform_sched(&net, 2);
    split.layers.get_mut("conv2").unwrap().backend = BackendTarget::Mock;
    let plan = PlanBuilder::new(&net, &params).schedule(split).batch(2).build().unwrap();

    for m in StagedMutation::ALL {
        let mut corrupt = StagedPlan::from_plan(&plan).unwrap();
        assert!(corrupt.apply_staged_mutation(m), "staged plan has no site for {}", m.as_str());
        match corrupt.verify() {
            Err(Error::Verify { rule, .. }) => {
                assert_eq!(rule, VerifyRule::StageCut, "corruption {}", m.as_str());
            }
            Err(e) => panic!("corruption {} surfaced the wrong error: {e}", m.as_str()),
            Ok(()) => panic!("corruption {} was not rejected", m.as_str()),
        }
    }
}

#[test]
fn misspelled_key_fixture_loads_lenient_rejects_strict() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures/misspelled_schedule.json");
    // Lenient path: the typo'd "backned" key warns and is ignored — in
    // particular it must NOT assign a backend.
    let lenient = Schedule::load(path).unwrap();
    assert_eq!(lenient.layers["conv2"].backend, BackendTarget::Native);
    assert!(!lenient.is_staged());
    // Strict path: typed rejection naming the offending key.
    match Schedule::load_strict(path) {
        Err(Error::Config(msg)) => {
            assert!(msg.contains("backned"), "rejection must name the key: {msg}")
        }
        other => panic!("strict parse must reject the fixture, got ok={}", other.is_ok()),
    }
}
