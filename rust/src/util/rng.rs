//! Deterministic PRNG (SplitMix64 + xoshiro256**) — the vendored crate
//! set has no `rand`, and reproducible workloads/weights matter for
//! benchmarks and tests.

/// xoshiro256** seeded via SplitMix64; good statistical quality, tiny.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// He-normal initialised weights for a layer with `fan_in` inputs.
    pub fn he_normal_vec(&mut self, n: usize, fan_in: usize) -> Vec<f32> {
        let std = (2.0 / fan_in as f32).sqrt();
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Fork a stream for a named sub-purpose (stable across runs).
    pub fn fork(&mut self, tag: &str) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV-1a
        for b in tag.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.next_u64() ^ h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(5);
        let mut a = r.fork("weights");
        let mut r2 = Rng::new(5);
        let mut b = r2.fork("weights");
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Rng::new(5).fork("other");
        assert_ne!(Rng::new(5).fork("weights").next_u64(), c.next_u64());
    }
}
