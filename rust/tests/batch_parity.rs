//! Bitwise batch parity: `run_batch` of N images must equal N
//! independent single-image `run` calls — across every executor family
//! (map-major OLP, row-major scalar baseline, FLP/KLP ablation), every
//! arithmetic mode, and thread counts {1, 2, 4}.
//!
//! Bitwise equality (not tolerance) is the point: lowering the batch
//! loop into the step sequence, sizing the arena `B x`, and spanning
//! one parallel region over `B x alpha` items must be pure refactorings
//! of the per-image numerics. Partial batches (`len < capacity`) get
//! the same guarantee — padded lanes never feed replies.

use cappuccino::engine::{
    run_mapmajor_legacy, ArithMode, ConvTiling, EngineParams, ExecConfig, ExecutionPlan,
    ModeAssignment, Parallelism, PlanBuilder,
};
use cappuccino::model::{zoo, Network};
use cappuccino::util::rng::Rng;
use cappuccino::Error;

const THREAD_SWEEP: [usize; 3] = [1, 2, 4];
const BATCH: usize = 4;

/// One builder configuration under test.
#[derive(Clone, Copy)]
struct Cfg<'m> {
    modes: Option<&'m ModeAssignment>,
    threads: usize,
    policy: Option<Parallelism>,
    baseline: bool,
}

impl<'m> Cfg<'m> {
    fn mapmajor(modes: &'m ModeAssignment, threads: usize) -> Self {
        Cfg { modes: Some(modes), threads, policy: None, baseline: false }
    }

    fn policy(modes: &'m ModeAssignment, threads: usize, policy: Parallelism) -> Self {
        Cfg { modes: Some(modes), threads, policy: Some(policy), baseline: false }
    }

    fn baseline() -> Self {
        Cfg { modes: None, threads: 1, policy: None, baseline: true }
    }

    fn build(&self, net: &Network, params: &EngineParams, batch: usize) -> ExecutionPlan {
        let mut b = PlanBuilder::new(net, params).threads(self.threads).batch(batch);
        if let Some(m) = self.modes {
            b = b.modes(m);
        }
        if let Some(p) = self.policy {
            b = b.policy(p);
        }
        if self.baseline {
            b = b.baseline();
        }
        b.build().unwrap()
    }
}

fn batch_inputs(net: &Network, seed: u64, n: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_vec(net.input.elements())).collect()
}

/// Compare `run_batch` against per-image `run` for one configuration.
fn assert_batch_parity(
    net: &Network,
    params: &EngineParams,
    cfg: Cfg<'_>,
    label: &str,
    seed: u64,
) {
    let inputs = batch_inputs(net, seed, BATCH);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let mut single = cfg.build(net, params, 1);
    let mut batched = cfg.build(net, params, BATCH);
    let rows = batched.run_batch(&refs).unwrap();
    assert_eq!(rows.len(), BATCH, "{label}: row count");
    for (i, (row, input)) in rows.iter().zip(&inputs).enumerate() {
        let want = single.run(input).unwrap();
        assert_eq!(row, &want, "{label}: batch lane {i} diverged from single run");
    }
    // Partial batch over the same (now dirty) arena: live rows only.
    let partial = batched.run_batch(&refs[..BATCH - 1]).unwrap();
    assert_eq!(partial.len(), BATCH - 1, "{label}: partial row count");
    for (i, row) in partial.iter().enumerate() {
        assert_eq!(row, &rows[i], "{label}: partial lane {i} leaked stale data");
    }
}

#[test]
fn mapmajor_batches_bitwise_match_singles_across_modes_threads() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 60, 4).unwrap();
    for mode in ArithMode::ALL {
        let modes = ModeAssignment::uniform(mode);
        for threads in THREAD_SWEEP {
            assert_batch_parity(
                &net,
                &params,
                Cfg::mapmajor(&modes, threads),
                &format!("map-major mode={mode} threads={threads}"),
                61,
            );
        }
    }
}

#[test]
fn baseline_batches_bitwise_match_singles() {
    // The baseline family pins precise/1-thread itself; the batch
    // dimension is the only variable.
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 62, 4).unwrap();
    assert_batch_parity(&net, &params, Cfg::baseline(), "baseline", 63);
}

#[test]
fn flp_klp_batches_bitwise_match_singles_across_modes_threads() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 64, 4).unwrap();
    for policy in [Parallelism::Flp, Parallelism::Klp] {
        for mode in ArithMode::ALL {
            let modes = ModeAssignment::uniform(mode);
            for threads in THREAD_SWEEP {
                assert_batch_parity(
                    &net,
                    &params,
                    Cfg::policy(&modes, threads, policy),
                    &format!("{policy} mode={mode} threads={threads}"),
                    65,
                );
            }
        }
    }
}

#[test]
fn fork_and_lrn_lowerings_keep_batch_parity() {
    // Fork/concat (fire module), LRN, flatten->dense->softmax: every
    // batched step kind in one network.
    use cappuccino::config::parse_cappnet;
    let net = parse_cappnet(
        "net mixed\ninput 3 23 23\nclasses 8\n\
         conv conv1 m=8 k=3 s=1 p=1\nlrn size=3\nmaxpool k=2 s=2\n\
         fire fire2 s1=8 e1=8 e3=8\n\
         conv conv3 m=8 k=1 s=1 p=0\navgpool k=2 s=2\n\
         flatten\ndense fc1 o=16 relu=1\ndense fc2 o=8 relu=0\nsoftmax\n",
    )
    .unwrap();
    let params = EngineParams::random(&net, 66, 4).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise);
    for threads in THREAD_SWEEP {
        assert_batch_parity(
            &net,
            &params,
            Cfg::mapmajor(&modes, threads),
            &format!("mixed threads={threads}"),
            67,
        );
    }
}

#[test]
fn mixed_per_layer_modes_keep_batch_parity() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 68, 4).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise)
        .with("conv2", ArithMode::Precise)
        .with("fc5", ArithMode::Relaxed);
    assert_batch_parity(&net, &params, Cfg::mapmajor(&modes, 2), "mixed-modes", 69);
}

#[test]
fn tiling_edge_cases_bitwise_match_legacy_across_modes_threads() {
    // Grids the tiles do NOT divide (remainder stack tiles and row
    // bands), k > s overlap on both conv layers, padding rows landing
    // inside tile bands (p=1 and p=2), and u != 4 — every combination
    // must stay bitwise identical to the unpacked legacy interpreter,
    // and run_batch must stay bitwise identical to single runs.
    use cappuccino::config::parse_cappnet;
    let net = parse_cappnet(
        "net tiled\ninput 3 13 13\nclasses 8\n\
         conv c1 m=12 k=3 s=1 p=1\n\
         conv c2 m=8 k=5 s=2 p=2\n\
         gap\n",
    )
    .unwrap();
    let tiles = [
        ConvTiling { tm: 2, th: 4 },   // remainder in both dimensions
        ConvTiling { tm: 3, th: 5 },
        ConvTiling { tm: 1, th: 1 },   // plain row walk
        ConvTiling { tm: 16, th: 64 }, // oversized -> clamped whole-layer tile
    ];
    for u in [2usize, 4, 8] {
        let params = EngineParams::random(&net, 80 + u as u64, u).unwrap();
        let inputs = batch_inputs(&net, 90 + u as u64, BATCH);
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        for mode in ArithMode::ALL {
            let modes = ModeAssignment::uniform(mode);
            for threads in THREAD_SWEEP {
                let cfg = ExecConfig { threads, ..Default::default() };
                let wants: Vec<Vec<f32>> = inputs
                    .iter()
                    .map(|x| run_mapmajor_legacy(&net, &params, x, &modes, cfg).unwrap())
                    .collect();
                for tile in tiles {
                    let mut plan = PlanBuilder::new(&net, &params)
                        .modes(&modes)
                        .threads(threads)
                        .batch(BATCH)
                        .tiling(tile)
                        .build()
                        .unwrap();
                    let rows = plan.run_batch(&refs).unwrap();
                    for (i, (row, want)) in rows.iter().zip(&wants).enumerate() {
                        assert_eq!(
                            row, want,
                            "u={u} mode={mode} threads={threads} tile={tile:?} lane {i}"
                        );
                    }
                    // Plan-side allocation meter: the request path hands
                    // out logits rows and nothing else, at any u.
                    assert_eq!(
                        plan.alloc_bytes_per_run(),
                        (4 * plan.output_len()) as f64,
                        "u={u} tile={tile:?}: request path allocated beyond logits"
                    );
                    assert_eq!(plan.alloc().allocs(), 1, "one record per batch walk");
                }
            }
        }
    }
}

#[test]
fn run_batch_into_matches_run_batch() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 70, 4).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise);
    let mut plan = Cfg::mapmajor(&modes, 2).build(&net, &params, BATCH);
    let inputs = batch_inputs(&net, 71, BATCH);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let rows = plan.run_batch(&refs).unwrap();
    let out_len = plan.output_len();
    let mut flat = vec![0.0f32; BATCH * out_len];
    plan.run_batch_into(&refs, &mut flat).unwrap();
    for (r, row) in rows.iter().enumerate() {
        assert_eq!(&flat[r * out_len..(r + 1) * out_len], row.as_slice(), "row {r}");
    }
}

#[test]
fn capacity_and_shape_violations_rejected() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 72, 4).unwrap();
    let mut plan = PlanBuilder::new(&net, &params).batch(2).build().unwrap();
    let inputs = batch_inputs(&net, 73, 3);
    let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    // Over capacity.
    assert!(matches!(plan.run_batch(&refs), Err(Error::Invalid(_))));
    // Bad row length.
    let bad = [&refs[0][..7]];
    assert!(matches!(plan.run_batch(&bad), Err(Error::Shape(_))));
    // Nothing executed.
    assert_eq!(plan.runs(), 0);
}
