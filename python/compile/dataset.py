"""Synthetic validation dataset — Cappuccino's third input (Fig. 3).

The paper uses 5000 random images from the ILSVRC-2012 validation set to
drive the inexact-computing analysis. That dataset is not available here,
so we substitute a procedurally generated 8-class image set (DESIGN.md,
substitution table): classes are distinct spatial patterns (stripes of
several orientations, checkerboards, blobs, rings, gradients) with
per-image random phase / frequency / colour tint and additive noise, so
a small CNN learns real (non-trivial) decision boundaries — which is
what the accuracy-delta analysis actually needs.

The file format (``dataset.bin``) is shared with
``rust/src/data/dataset.rs``::

  magic    8 bytes  b"CAPPDATA"
  version  u32      1
  n        u32      total images
  n_train  u32      leading images reserved for training
  c,h,w    u32 * 3
  classes  u32
  images   f32 * n*c*h*w   (NCHW, little-endian)
  labels   u16 * n
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"CAPPDATA"
VERSION = 1
NUM_CLASSES = 8
C, H, W = 3, 16, 16


def _pattern(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Greyscale base pattern in [0,1] for one class, randomly jittered."""
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    freq = rng.uniform(0.8, 1.6)
    phase = rng.uniform(0, 2 * np.pi)
    if cls == 0:    # horizontal stripes
        img = np.sin(yy * freq + phase)
    elif cls == 1:  # vertical stripes
        img = np.sin(xx * freq + phase)
    elif cls == 2:  # diagonal stripes
        img = np.sin((xx + yy) * freq * 0.7 + phase)
    elif cls == 3:  # checkerboard
        img = np.sin(xx * freq + phase) * np.sin(yy * freq + phase)
    elif cls == 4:  # centred blob
        cy, cx = rng.uniform(5, 11, size=2)
        img = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / rng.uniform(8, 20))
    elif cls == 5:  # corner gradient
        sy, sx = rng.choice([-1.0, 1.0], size=2)
        img = (sy * yy / H + sx * xx / W) * 0.5
    elif cls == 6:  # rings
        cy, cx = rng.uniform(6, 10, size=2)
        r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        img = np.sin(r * freq * 1.5 + phase)
    elif cls == 7:  # blocky noise (low-frequency random field)
        coarse = rng.standard_normal((4, 4)).astype(np.float32)
        img = np.kron(coarse, np.ones((4, 4), np.float32))
    else:
        raise ValueError(cls)
    img = (img - img.min()) / (img.max() - img.min() + 1e-8)
    return img.astype(np.float32)


def make_image(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One (C,H,W) float32 image: tinted pattern + noise, zero-mean-ish."""
    base = _pattern(cls, rng)
    tint = rng.uniform(0.4, 1.0, size=(C, 1, 1)).astype(np.float32)
    img = base[None] * tint
    img = img + rng.normal(0, 0.15, size=img.shape).astype(np.float32)
    return (img - 0.5).astype(np.float32)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Balanced dataset: ``(n, C, H, W)`` images + ``(n,)`` u16 labels."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    images = np.stack([make_image(int(c), rng) for c in labels])
    return images.astype(np.float32), labels.astype(np.uint16)


def write_dataset(path: str, images: np.ndarray, labels: np.ndarray,
                  n_train: int) -> None:
    n, c, h, w = images.shape
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<IIIIIII", VERSION, n, n_train, c, h, w,
                            NUM_CLASSES))
        f.write(np.ascontiguousarray(images, "<f4").tobytes())
        f.write(np.ascontiguousarray(labels, "<u2").tobytes())


def read_dataset(path: str):
    """Returns ``(images, labels, n_train)``."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:8] != MAGIC:
        raise ValueError(f"{path}: bad magic")
    version, n, n_train, c, h, w, ncls = struct.unpack_from("<IIIIIII", data, 8)
    if version != VERSION or ncls != NUM_CLASSES:
        raise ValueError(f"{path}: version/class mismatch")
    off = 8 + 7 * 4
    images = np.frombuffer(data, "<f4", count=n * c * h * w,
                           offset=off).reshape(n, c, h, w).copy()
    off += 4 * n * c * h * w
    labels = np.frombuffer(data, "<u2", count=n, offset=off).copy()
    return images, labels, n_train
