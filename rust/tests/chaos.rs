//! Chaos suite: deterministic fault injection end to end.
//!
//! One `#[test]` with sequential phases — `cappuccino::faults` installs
//! a **process-global** config, so the phases must not run concurrently
//! with each other (or with any other test in this binary; keep it the
//! only one).
//!
//! Phase 1 proves engine-level containment: an injected panic inside a
//! plan step surfaces as a typed [`Error::TaskPanicked`] naming the
//! step, and the shared thread pool stays fully usable (bitwise parity)
//! afterwards. Phase 2 proves serve-level supervision: two tenants,
//! injection addressed at one (`panic:worker@a`), every request
//! answered (Ok or typed fault, zero drops), the faulted tenant
//! respawns, the healthy tenant untouched — and the whole run is
//! reproducible bit-for-bit from the seed. Phase 3 re-checks engine
//! parity after all the contained chaos.

use cappuccino::engine::{EngineParams, PlanBuilder};
use cappuccino::faults::{self, FaultConfig};
use cappuccino::model::zoo;
use cappuccino::serve::{
    Backend, BackendFactory, BatchPolicy, Rejected, Server, SloTable, SupervisorPolicy, Tenant,
};
use cappuccino::util::rng::Rng;
use cappuccino::Error;

/// Answers each image with its element sum. All faults in this suite
/// come from the injection layer, never the backend itself.
struct SumBackend;

impl Backend for SumBackend {
    fn input_len(&self) -> usize {
        4
    }

    fn batch_sizes(&self) -> &[usize] {
        &[4]
    }

    fn infer_batch(
        &mut self,
        images: &[&[f32]],
        _capacity: usize,
    ) -> cappuccino::Result<Vec<Vec<f32>>> {
        Ok(images.iter().map(|img| vec![img.iter().sum()]).collect())
    }
}

fn sum_factory() -> BackendFactory {
    Box::new(|| Ok(Box::new(SumBackend) as Box<dyn Backend>))
}

fn tenant(name: &str) -> Tenant {
    Tenant {
        name: name.into(),
        factory: sum_factory(),
        policy: BatchPolicy::default(),
        image_ms: None,
        input_len: 4,
        fallback: None,
        supervision: SupervisorPolicy::default(),
    }
}

/// One seeded serve-chaos run: two tenants, panics injected only at
/// tenant "a"'s worker, `n` sequential blocking requests per tenant.
/// Returns `(a_ok, a_faulted, a_contained, a_respawns)`.
fn serve_chaos_run(spec: &str, n: usize) -> (usize, usize, u64, u64) {
    faults::install(Some(FaultConfig::parse(spec).unwrap()));
    let server =
        Server::start_tenants(vec![tenant("a"), tenant("b")], SloTable::default()).unwrap();

    let (mut a_ok, mut a_faulted) = (0usize, 0usize);
    for _ in 0..n {
        // Sequential singleton batches keep the per-spec draw counter on
        // a single deterministic sequence.
        match server.router().infer_blocking("a", vec![1.0; 4]) {
            Ok(resp) => {
                assert_eq!(resp.logits, vec![4.0]);
                a_ok += 1;
            }
            Err(Error::Rejected(Rejected::Fault { model, .. })) => {
                assert_eq!(model, "a");
                a_faulted += 1;
            }
            Err(e) => panic!("tenant a: expected Ok or typed fault, got {e}"),
        }
    }
    // The healthy tenant must be completely unaffected.
    for _ in 0..n {
        let resp = server.router().infer_blocking("b", vec![2.0; 4]).unwrap();
        assert_eq!(resp.logits, vec![8.0]);
    }

    use std::sync::atomic::Ordering;
    let a_stats = server.metrics().faults.stats("a").expect("tenant a registered");
    let contained = a_stats.faults_contained.load(Ordering::Relaxed);
    let respawns = a_stats.worker_respawns.load(Ordering::Relaxed);
    let b_stats = server.metrics().faults.stats("b").expect("tenant b registered");
    assert_eq!(
        b_stats.faults_contained.load(Ordering::Relaxed),
        0,
        "injection addressed at a must never touch b"
    );
    assert_eq!(b_stats.worker_respawns.load(Ordering::Relaxed), 0);
    assert_eq!(server.router().admission("a").unwrap().pending(), 0);
    assert_eq!(server.router().admission("b").unwrap().pending(), 0);
    server.shutdown();
    faults::install(None);
    (a_ok, a_faulted, contained, respawns)
}

#[test]
fn chaos_injection_is_contained_supervised_and_deterministic() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 42, 4).unwrap();
    let mut rng = Rng::new(5);
    let input = rng.normal_vec(net.input.elements());

    // ---- Phase 1: engine-level containment ---------------------------
    // Every conv step panics; the walk must surface a typed
    // TaskPanicked naming a conv step — not poison the pool, not abort
    // the process.
    faults::install(Some(FaultConfig::parse("seed=1,panic:conv:1").unwrap()));
    let mut plan = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
    match plan.run(&input) {
        Err(Error::TaskPanicked { layer, .. }) => {
            assert!(layer.contains("conv"), "panicked step should be a conv, got {layer:?}");
        }
        other => panic!("expected TaskPanicked, got ok={}", other.is_ok()),
    }
    faults::install(None);
    // The same plan object (and the shared pool) is fully usable after
    // the contained panic, and stays bitwise deterministic.
    let clean = plan.run(&input).unwrap();
    assert_eq!(plan.run(&input).unwrap(), clean, "pool lost parity after contained panic");

    // ---- Phase 2: serve-level supervision under seeded chaos ---------
    let spec = "seed=3,panic:worker@a:0.4";
    let n = 30;
    let (a_ok, a_faulted, contained, respawns) = serve_chaos_run(spec, n);
    assert_eq!(a_ok + a_faulted, n, "a reply went missing: ok={a_ok} faulted={a_faulted}");
    assert!(a_ok > 0, "p=0.4 with one retry should complete most requests");
    assert!(contained >= 1, "no faults landed at p=0.4 over {n} requests");
    assert!(respawns >= 1, "contained faults must respawn the backend");
    assert!(respawns >= contained, "every contained fault respawns (factory never fails)");

    // Same seed, same sequence: the whole chaos run is reproducible.
    let rerun = serve_chaos_run(spec, n);
    assert_eq!(
        rerun,
        (a_ok, a_faulted, contained, respawns),
        "seeded chaos run is not deterministic"
    );
    // ---- Phase 3: engine parity after all the chaos ------------------
    // A freshly compiled plan on the shared pool still reproduces the
    // pre-chaos output bit for bit.
    let mut fresh = PlanBuilder::new(&net, &params).threads(2).build().unwrap();
    assert_eq!(fresh.run(&input).unwrap(), clean, "engine lost parity after chaos runs");

    // Injected errors (not panics) surface as typed faults too: err at
    // the backend site quarantines without ever panicking a thread.
    faults::install(Some(FaultConfig::parse("seed=9,err:worker@a:1").unwrap()));
    let server = Server::start_tenants(vec![tenant("a")], SloTable::default()).unwrap();
    match server.router().infer_blocking("a", vec![1.0; 4]) {
        Err(Error::Rejected(Rejected::Fault { error, .. })) => {
            assert!(error.contains("injected"), "fault detail lost: {error}");
        }
        other => panic!("err:worker@a:1 must quarantine, got ok={}", other.is_ok()),
    }
    server.shutdown();
    faults::install(None);

    // ---- Phase 4: staged-plan chaos at the transfer site -------------
    // A heterogeneous backend split lowers explicit Transfer steps at
    // every stage cut; the `transfer` fault site addresses exactly
    // those cross-backend copies. Both injection kinds surface as
    // typed errors from the staged walk, and a clean rerun is bitwise
    // the uniform plan's output.
    {
        use cappuccino::engine::{
            ArithMode, BackendTarget, ModeAssignment, Parallelism, PoolSettings, Schedule,
            StagedPlan,
        };
        use cappuccino::runtime::backends::BackendRegistry;

        let mut sched = Schedule::from_uniform(
            &net,
            4,
            &ModeAssignment::uniform(ArithMode::Imprecise),
            Parallelism::Olp,
            true,
            None,
            PoolSettings { threads: 2, affinity: false, cores: None },
        )
        .unwrap();
        let names = net.param_layer_names();
        assert!(names.len() >= 2, "need two param layers to split");
        for name in &names[names.len() / 2..] {
            sched.layers.get_mut(name.as_str()).unwrap().backend = BackendTarget::Mock;
        }
        let plan = PlanBuilder::new(&net, &params).schedule(sched).batch(2).build().unwrap();
        let mut staged = StagedPlan::from_plan(&plan).unwrap();
        assert!(staged.stage_count() >= 2, "split schedule must stage");
        let registry = BackendRegistry::default();
        let imgs: Vec<Vec<f32>> = (0..2)
            .map(|i| Rng::new(100 + i as u64).normal_vec(net.input.elements()))
            .collect();
        let refs: Vec<&[f32]> = imgs.iter().map(|v| v.as_slice()).collect();

        faults::install(Some(FaultConfig::parse("seed=2,panic:transfer:1").unwrap()));
        match staged.run_batch_seq(&refs, &registry) {
            Err(Error::TaskPanicked { layer, .. }) => {
                assert_eq!(layer, "transfer", "panicked step should be a stage-cut transfer");
            }
            other => {
                panic!("panic:transfer:1 must surface TaskPanicked, got ok={}", other.is_ok())
            }
        }
        faults::install(Some(FaultConfig::parse("seed=2,err:transfer:1").unwrap()));
        match staged.run_batch_seq(&refs, &registry) {
            Err(Error::Serve(detail)) => {
                assert!(detail.contains("injected"), "fault detail lost: {detail}");
            }
            other => {
                panic!("err:transfer:1 must surface a typed error, got ok={}", other.is_ok())
            }
        }
        faults::install(None);
        let clean_staged = staged.run_batch_seq(&refs, &registry).unwrap();
        let mut uniform = PlanBuilder::new(&net, &params).threads(2).batch(2).build().unwrap();
        assert_eq!(
            clean_staged,
            uniform.run_batch(&refs).unwrap(),
            "staged walk lost parity after transfer chaos"
        );
    }
}
