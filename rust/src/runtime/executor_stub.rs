//! PJRT executor stub — compiled when the `pjrt` feature is off.
//!
//! Mirrors the public API of `executor.rs` exactly so the rest of the
//! crate (serving, benches, CLI) compiles unchanged; every attempt to
//! actually reach PJRT reports a clear `Error::Xla`. Artifact-gated
//! tests and benches skip gracefully because they probe for
//! `manifest.json` before constructing a [`Runtime`], and environments
//! without the vendored `xla` crate ship no artifacts.

use crate::config::modelfile::ModelFile;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::util::error::{Error, Result};

/// Where parameter values come from when loading an artifact.
pub enum ParamSource {
    /// A `.capp` file already in map-major layout (e.g. the build-time
    /// reordered `tinynet_mm.capp`).
    MapMajorFile(ModelFile),
    /// Deterministic random weights in the manifest's shapes — for
    /// latency work on nets without shipped weights (values don't
    /// affect timing).
    Random(u64),
}

fn unavailable() -> Error {
    Error::Xla(
        "built without the `pjrt` feature: the PJRT executor needs the vendored `xla` \
         crate (rebuild with `--features pjrt`)"
            .into(),
    )
}

/// A PJRT CPU runtime: owns the client; loads artifacts. Stub —
/// construction always fails with an actionable message.
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Err(unavailable())
    }

    pub fn platform(&self) -> String {
        "pjrt-unavailable".to_string()
    }

    /// Compile an artifact and upload its weights. Stub — unreachable
    /// in practice since [`Runtime::new`] always errors.
    pub fn load(
        &self,
        _manifest: &Manifest,
        _spec: &ArtifactSpec,
        _source: &ParamSource,
    ) -> Result<LoadedModel> {
        Err(unavailable())
    }
}

/// A compiled artifact with device-resident weights. Stub.
pub struct LoadedModel {
    pub spec: ArtifactSpec,
}

impl LoadedModel {
    /// Batch capacity baked into the artifact.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    /// Run inference on a full map-major input batch.
    pub fn infer(&self, _x_mm: &[f32]) -> Result<Vec<f32>> {
        Err(unavailable())
    }

    /// Convenience: per-image logits rows.
    pub fn infer_rows(&self, _x_mm: &[f32]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_missing_feature() {
        let err = Runtime::new().err().expect("stub runtime must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
