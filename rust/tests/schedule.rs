//! Schedule-IR acceptance: the artifact round trip (fluent setters →
//! exported `Schedule` → JSON → reload → rebuilt plan) must be
//! **bitwise** identical, and per-layer heterogeneity must be real —
//! a plan mixing parallelism families and packing choices across
//! layers has to match the legacy per-layer oracles bitwise, not just
//! approximately.

use cappuccino::config::modelfile::{ModelFile, NamedTensor};
use cappuccino::engine::{
    ArithMode, ConvTiling, EngineParams, ModeAssignment, Parallelism, PlanBuilder, Schedule,
};
use cappuccino::model::{zoo, Layer, LayerOp, Network, TensorShape};
use cappuccino::testing::{check, Gen};
use cappuccino::util::json::Json;
use cappuccino::util::rng::Rng;
use cappuccino::Error;

/// Export → serialize → reload → rebuild, bitwise, across the full
/// threads x u sweep the artifact must survive.
#[test]
fn schedule_roundtrip_is_bitwise_across_threads_and_u() {
    let net = zoo::tinynet();
    for u in [1usize, 2, 4] {
        let params = EngineParams::random(&net, 40 + u as u64, u).unwrap();
        for threads in [1usize, 2, 4] {
            let modes = ModeAssignment::uniform(ArithMode::Imprecise)
                .with("conv2", ArithMode::Precise)
                .with("fc5", ArithMode::Relaxed);
            let mut fluent = PlanBuilder::new(&net, &params)
                .modes(&modes)
                .threads(threads)
                .batch(4)
                .build()
                .unwrap();
            let exported = fluent.schedule().clone();
            let text = exported.to_json().to_string();
            let loaded = Schedule::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(loaded, exported, "u={u} threads={threads}: JSON not identity");
            let mut rebuilt = PlanBuilder::new(&net, &params)
                .schedule(loaded)
                .batch(4)
                .build()
                .unwrap();
            let mut rng = Rng::new(60 + (u * 10 + threads) as u64);
            let inputs: Vec<Vec<f32>> =
                (0..3).map(|_| rng.normal_vec(net.input.elements())).collect();
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            assert_eq!(
                fluent.run_batch(&refs).unwrap(),
                rebuilt.run_batch(&refs).unwrap(),
                "u={u} threads={threads}: rebuilt plan diverged"
            );
        }
    }
}

/// Random uniform knobs through the same round trip (property form).
#[test]
fn prop_schedule_roundtrip_under_random_knobs() {
    let net = zoo::tinynet();
    let layer_names = net.param_layer_names();
    check("schedule roundtrip", 10, 0x5EED, |g: &mut Gen| {
        let u = g.choose(&[1usize, 2, 4]);
        let threads = g.choose(&[1usize, 2, 4]);
        let params = EngineParams::random(&net, 70 + u as u64, u).map_err(|e| e.to_string())?;
        let mut modes = ModeAssignment::uniform(g.choose(&ArithMode::ALL));
        for name in &layer_names {
            if g.bool() {
                modes = modes.with(name.clone(), g.choose(&ArithMode::ALL));
            }
        }
        let policy = g.choose(&[Parallelism::Olp, Parallelism::Flp, Parallelism::Klp]);
        let mut builder = PlanBuilder::new(&net, &params)
            .modes(&modes)
            .threads(threads)
            .policy(policy)
            .packing(g.bool())
            .batch(2);
        if g.bool() {
            builder = builder.tiling(ConvTiling { tm: g.int(1, 8), th: g.int(1, 8) });
        }
        let mut fluent = builder.build().map_err(|e| e.to_string())?;
        let exported = fluent.schedule().clone();
        let loaded = Schedule::from_json(
            &Json::parse(&exported.to_json().to_string()).map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        if loaded != exported {
            return Err("schedule JSON round trip not identity".into());
        }
        let mut rebuilt = PlanBuilder::new(&net, &params)
            .schedule(loaded)
            .batch(2)
            .build()
            .map_err(|e| e.to_string())?;
        let x1 = g.normal_vec(net.input.elements());
        let x2 = g.normal_vec(net.input.elements());
        let a = fluent.run_batch(&[&x1[..], &x2[..]]).map_err(|e| e.to_string())?;
        let b = rebuilt.run_batch(&[&x1[..], &x2[..]]).map_err(|e| e.to_string())?;
        if a != b {
            return Err(format!("diverged (u={u} threads={threads} policy={policy})"));
        }
        Ok(())
    });
}

/// Save/load through a real file — the exact tune → serve artifact path.
#[test]
fn schedule_file_artifact_roundtrips() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 80, 4).unwrap();
    let mut fluent = PlanBuilder::new(&net, &params)
        .modes(&ModeAssignment::uniform(ArithMode::Imprecise))
        .threads(2)
        .build()
        .unwrap();
    let path = std::env::temp_dir()
        .join(format!("cappuccino_schedule_{}.json", std::process::id()));
    fluent.schedule().save(&path).unwrap();
    let loaded = Schedule::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(&loaded, fluent.schedule());
    let mut rebuilt = PlanBuilder::new(&net, &params).schedule(loaded).build().unwrap();
    let mut rng = Rng::new(81);
    let input = rng.normal_vec(net.input.elements());
    assert_eq!(fluent.run(&input).unwrap(), rebuilt.run(&input).unwrap());
}

/// The four-layer mixed net used by the heterogeneity tests, with
/// deterministic weights shared through a model file so sub-networks
/// compile the exact same parameters.
fn mixnet() -> (Network, Network, Network, ModelFile) {
    let full = Network {
        name: "mixnet".into(),
        input: TensorShape::maps(3, 12, 12),
        classes: 8,
        layers: vec![
            Layer::new("c1", LayerOp::Conv { m: 8, k: 3, s: 1, p: 1, relu: true }),
            Layer::new("pool1", LayerOp::MaxPool { k: 2, s: 2, p: 0 }),
            Layer::new("c2", LayerOp::Conv { m: 8, k: 3, s: 1, p: 0, relu: true }),
            Layer::new("gap", LayerOp::Gap),
        ],
    };
    let prefix = Network {
        name: "mixnet-prefix".into(),
        input: TensorShape::maps(3, 12, 12),
        classes: 8,
        layers: full.layers[..2].to_vec(),
    };
    let suffix = Network {
        name: "mixnet-suffix".into(),
        input: TensorShape::maps(8, 6, 6),
        classes: 8,
        layers: full.layers[2..].to_vec(),
    };
    let mut rng = Rng::new(0x0317);
    let mut mf = ModelFile::new();
    mf.insert("c1/w", NamedTensor::new(vec![8, 3, 3, 3], rng.normal_vec(8 * 3 * 3 * 3)));
    mf.insert("c1/b", NamedTensor::new(vec![8], rng.normal_vec(8)));
    mf.insert("c2/w", NamedTensor::new(vec![8, 8, 3, 3], rng.normal_vec(8 * 8 * 3 * 3)));
    mf.insert("c2/b", NamedTensor::new(vec![8], rng.normal_vec(8)));
    (full, prefix, suffix, mf)
}

/// Acceptance: two layers carrying different `parallelism` AND
/// `packing` in one plan, proven bitwise against the legacy per-layer
/// oracles. The oracle is compositional: the OLP prefix runs as its own
/// uniform plan (itself bitwise-locked to `run_mapmajor_legacy` by
/// `plan_parity`), its NCHW output feeds a uniform FLP suffix plan —
/// exactly the per-layer kernels the mixed plan claims to execute, with
/// the layout reorder at the boundary being the same exact permutation
/// as the prefix's output extraction.
#[test]
fn heterogeneous_parallelism_and_packing_match_composed_oracle_bitwise() {
    let (full, prefix, suffix, mf) = mixnet();
    let params_full = EngineParams::compile(&full, &mf, 4).unwrap();
    let params_prefix = EngineParams::compile(&prefix, &mf, 4).unwrap();
    let params_suffix = EngineParams::compile(&suffix, &mf, 4).unwrap();
    let mut rng = Rng::new(90);
    let input = rng.normal_vec(full.input.elements());

    for threads in [1usize, 2, 4] {
        // Mixed schedule: c1 OLP + packed + imprecise, c2 FLP + unpacked
        // + precise — different parallelism and packing per layer.
        let mut sched = Schedule::default_for(&full, 4);
        sched.pool.threads = threads;
        {
            let c1 = sched.layers.get_mut("c1").unwrap();
            c1.mode = ArithMode::Imprecise;
            c1.packing = true;
        }
        {
            let c2 = sched.layers.get_mut("c2").unwrap();
            c2.parallelism = Parallelism::Flp;
            c2.packing = false;
        }
        let mut mixed = PlanBuilder::new(&full, &params_full).schedule(sched).build().unwrap();
        let got = mixed.run(&input).unwrap();

        // Composed oracle from uniform plans.
        let mut head = PlanBuilder::new(&prefix, &params_prefix)
            .modes(&ModeAssignment::uniform(ArithMode::Precise).with("c1", ArithMode::Imprecise))
            .threads(threads)
            .build()
            .unwrap();
        let mid = head.run(&input).unwrap();
        let mut tail = PlanBuilder::new(&suffix, &params_suffix)
            .policy(Parallelism::Flp)
            .threads(threads)
            .build()
            .unwrap();
        let want = tail.run(&mid).unwrap();
        assert_eq!(got, want, "threads={threads}: mixed plan diverged from composed oracle");
        // The mixture is real, not collapsed: the mixed plan runs
        // map-major (u = 4) where the uniform-FLP lowering runs u = 1.
        assert_eq!(mixed.u(), 4);
    }
}

/// The mirror mixture — row-major (FLP) first, OLP second. The plan
/// must start the input row-major (no map-major transform that a
/// reorder would immediately undo: exactly one Reorder step, at the
/// FLP→OLP boundary) and still match the composed uniform-plan oracle
/// bitwise.
#[test]
fn rowmajor_first_mixture_starts_nchw_and_matches_oracle_bitwise() {
    let (full, prefix, suffix, mf) = mixnet();
    let params_full = EngineParams::compile(&full, &mf, 4).unwrap();
    let params_prefix = EngineParams::compile(&prefix, &mf, 4).unwrap();
    let params_suffix = EngineParams::compile(&suffix, &mf, 4).unwrap();
    let mut rng = Rng::new(95);
    let input = rng.normal_vec(full.input.elements());

    for threads in [1usize, 2] {
        let mut sched = Schedule::default_for(&full, 4);
        sched.pool.threads = threads;
        sched.layers.get_mut("c1").unwrap().parallelism = Parallelism::Flp;
        sched.layers.get_mut("c2").unwrap().mode = ArithMode::Imprecise;
        let mut mixed = PlanBuilder::new(&full, &params_full).schedule(sched).build().unwrap();
        // Input, ConvNchw(c1), PoolNchw, Reorder, ConvMm(c2), Gap — the
        // input starts row-major, so there is exactly one reorder.
        assert_eq!(mixed.step_count(), 6, "unexpected lowering for the FLP-first mixture");
        let got = mixed.run(&input).unwrap();

        let mut head = PlanBuilder::new(&prefix, &params_prefix)
            .policy(Parallelism::Flp)
            .threads(threads)
            .build()
            .unwrap();
        let mid = head.run(&input).unwrap();
        let mut tail = PlanBuilder::new(&suffix, &params_suffix)
            .modes(&ModeAssignment::uniform(ArithMode::Precise).with("c2", ArithMode::Imprecise))
            .threads(threads)
            .build()
            .unwrap();
        let want = tail.run(&mid).unwrap();
        assert_eq!(got, want, "threads={threads}: FLP-first mixture diverged from oracle");
    }
}

/// Per-layer packing against the true legacy interpreter: packing is a
/// bitwise-invisible permutation, so any per-layer mixture must still
/// equal `run_mapmajor_legacy` exactly.
#[test]
fn per_layer_packing_mixture_matches_legacy_interpreter_bitwise() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 91, 4).unwrap();
    let modes = ModeAssignment::uniform(ArithMode::Imprecise);
    let mut rng = Rng::new(92);
    let input = rng.normal_vec(net.input.elements());
    for threads in [1usize, 2, 4] {
        let cfg = cappuccino::engine::ExecConfig { threads, affinity: false };
        let want =
            cappuccino::engine::run_mapmajor_legacy(&net, &params, &input, &modes, cfg).unwrap();
        let mut sched = Schedule::default_for(&net, 4);
        sched.pool.threads = threads;
        for (i, ls) in sched.layers.values_mut().enumerate() {
            ls.mode = ArithMode::Imprecise;
            ls.packing = i % 2 == 0; // alternate packed / unpacked
        }
        let mut plan = PlanBuilder::new(&net, &params).schedule(sched).build().unwrap();
        assert_eq!(
            plan.run(&input).unwrap(),
            want,
            "threads={threads}: packing mixture diverged from legacy"
        );
    }
}

/// A schedule built for one net cannot be applied to another, and
/// malformed artifacts surface as typed config/parse errors.
#[test]
fn schedule_artifact_validation_is_typed() {
    let net = zoo::tinynet();
    let params = EngineParams::random(&net, 93, 4).unwrap();
    let (full, ..) = mixnet();
    let foreign = Schedule::default_for(&full, 4);
    assert!(matches!(
        PlanBuilder::new(&net, &params).schedule(foreign).build(),
        Err(Error::Config(_))
    ));
    assert!(Schedule::from_json(&Json::parse("{\"net\":\"x\"}").unwrap()).is_err());
    assert!(Schedule::load(std::path::Path::new("/nonexistent/schedule.json")).is_err());
}
