//! Serving workload generation and replay: arrival processes for
//! driving the router/batcher, and an open-loop replay driver that
//! measures latency-under-load (p50/p99) against a running
//! [`Server`](super::Server).
//!
//! The paper evaluates single-inference latency; the serving layer this
//! repo adds needs load *patterns* to characterise the dynamic batcher.
//! All processes are deterministic per seed. The heavy-tailed
//! bounded-Pareto process is the interesting one for a front-end with
//! admission control: most gaps are short (bursts that pile the queue
//! up) with occasional long gaps (idle valleys), which is what makes
//! deadline-based load shedding earn its keep.

use std::time::{Duration, Instant};

use crate::metrics::LatencyHistogram;
use crate::serve::{Rejected, RequestOptions, Server};
use crate::util::error::Error;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Request arrival process.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalProcess {
    /// All requests at t=0 (closed-loop burst).
    Burst,
    /// Fixed inter-arrival gap (open-loop, deterministic rate).
    Uniform { rate_per_s: f64 },
    /// Exponential inter-arrival times (open-loop Poisson).
    Poisson { rate_per_s: f64 },
    /// Bursts of `size` back-to-back requests separated by `gap`.
    Bursty { size: usize, gap: Duration },
    /// Heavy-tailed inter-arrival times: bounded Pareto with shape
    /// `alpha` (smaller = heavier tail; > 1 for a finite mean) and an
    /// upper bound of `cap ×` the minimum gap. The minimum gap is
    /// scaled so the process's *mean* rate is `rate_per_s` — directly
    /// comparable to `Poisson` at the same rate, but with gap bursts
    /// and valleys instead of memoryless spacing.
    BoundedPareto { rate_per_s: f64, alpha: f64, cap: f64 },
}

impl ArrivalProcess {
    /// Generate the inter-arrival delays for `n` requests (delay *before*
    /// each request; first is always zero).
    pub fn delays(&self, n: usize, seed: u64) -> Vec<Duration> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                if i == 0 {
                    return Duration::ZERO;
                }
                match *self {
                    ArrivalProcess::Burst => Duration::ZERO,
                    ArrivalProcess::Uniform { rate_per_s } => {
                        Duration::from_secs_f64(1.0 / rate_per_s.max(1e-9))
                    }
                    ArrivalProcess::Poisson { rate_per_s } => {
                        // Inverse-CDF exponential sampling.
                        let u = rng.f64().max(1e-12);
                        Duration::from_secs_f64(-u.ln() / rate_per_s.max(1e-9))
                    }
                    ArrivalProcess::Bursty { size, gap } => {
                        if i % size == 0 {
                            gap
                        } else {
                            Duration::ZERO
                        }
                    }
                    ArrivalProcess::BoundedPareto { rate_per_s, alpha, cap } => {
                        Duration::from_secs_f64(bounded_pareto_gap(
                            &mut rng, rate_per_s, alpha, cap,
                        ))
                    }
                }
            })
            .collect()
    }

    pub fn label(&self) -> String {
        match *self {
            ArrivalProcess::Burst => "burst".into(),
            ArrivalProcess::Uniform { rate_per_s } => format!("uniform-{rate_per_s:.0}rps"),
            ArrivalProcess::Poisson { rate_per_s } => format!("poisson-{rate_per_s:.0}rps"),
            ArrivalProcess::Bursty { size, gap } => {
                format!("bursty-{size}x{}ms", gap.as_millis())
            }
            ArrivalProcess::BoundedPareto { rate_per_s, alpha, cap } => {
                format!("pareto-{rate_per_s:.0}rps-a{alpha}-k{cap:.0}")
            }
        }
    }
}

/// One bounded-Pareto gap (seconds) with mean `1 / rate_per_s`.
///
/// Bounded Pareto on `[L, H]` with `H = cap × L` via the inverse CDF
/// `x = L / (1 − U·(1 − cap^−α))^(1/α)`; the mean of the *unit*
/// (`L = 1`) distribution is `α/(α−1) · (1 − cap^(1−α))/(1 − cap^(−α))`
/// (for `α ≠ 1`), so dividing the requested mean gap by it yields the
/// `L` that hits the target rate exactly.
fn bounded_pareto_gap(rng: &mut Rng, rate_per_s: f64, alpha: f64, cap: f64) -> f64 {
    let a = alpha.max(1.0 + 1e-6);
    let k = cap.max(1.0 + 1e-9);
    let mean_unit = a / (a - 1.0) * (1.0 - k.powf(1.0 - a)) / (1.0 - k.powf(-a));
    let l = (1.0 / rate_per_s.max(1e-9)) / mean_unit;
    let u = rng.f64().min(1.0 - 1e-12);
    l / (1.0 - u * (1.0 - k.powf(-a))).powf(1.0 / a)
}

/// Replay configuration: how many requests, spaced how, tagged how.
#[derive(Debug, Clone)]
pub struct ReplaySpec {
    pub requests: usize,
    pub arrivals: ArrivalProcess,
    pub seed: u64,
    /// SLO class tags cycled round-robin over requests (empty = none).
    pub classes: Vec<String>,
    /// Explicit relative deadline applied to every request.
    pub deadline: Option<Duration>,
    /// When no explicit deadline: per-tenant deadline of
    /// `factor × image_ms × max_batch` ms (i.e. `factor` batch walks) —
    /// scale-free across devices, so a factor tightens/loosens load
    /// shedding identically on any host. Ignored for tenants without a
    /// service estimate.
    pub deadline_factor: Option<f64>,
}

impl ReplaySpec {
    pub fn new(requests: usize, arrivals: ArrivalProcess, seed: u64) -> ReplaySpec {
        ReplaySpec {
            requests,
            arrivals,
            seed,
            classes: Vec::new(),
            deadline: None,
            deadline_factor: None,
        }
    }
}

/// What a replay run observed, ready for `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct ReplayOutcome {
    pub label: String,
    pub submitted: usize,
    pub completed: usize,
    pub shed_deadline: usize,
    pub rejected_queue_full: usize,
    pub rejected_other: usize,
    /// Admitted requests answered with a typed fault
    /// ([`Rejected::Fault`]) by the supervisor — quarantined poison
    /// pills and respawn exhaustion. Chaos runs expect these; they are
    /// *answers*, not drops.
    pub faulted: usize,
    /// Admitted requests whose reply channel closed without a reply —
    /// the front-end's contract says this must be zero.
    pub dropped: usize,
    pub deadline_missed: usize,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// `(class, completed, p50_ms, p99_ms)` per SLO class used.
    pub per_class: Vec<(String, usize, f64, f64)>,
}

impl ReplayOutcome {
    /// One-line result summary (stable `key=value` format — CI greps it).
    pub fn summary_line(&self) -> String {
        format!(
            "replay: submitted={} completed={} shed_deadline={} rejected_queue_full={} \
             rejected_other={} faulted={} dropped={} deadline_missed={} throughput_rps={:.1} \
             mean_batch={:.2} p50_ms={:.3} p99_ms={:.3}",
            self.submitted,
            self.completed,
            self.shed_deadline,
            self.rejected_queue_full,
            self.rejected_other,
            self.faulted,
            self.dropped,
            self.deadline_missed,
            self.throughput_rps,
            self.mean_batch,
            self.p50_ms,
            self.p99_ms,
        )
    }

    /// The `BENCH_serve.json` payload.
    pub fn to_json(&self) -> Json {
        let per_class = self
            .per_class
            .iter()
            .map(|(name, n, p50, p99)| {
                Json::obj(vec![
                    ("class", Json::str(name.clone())),
                    ("completed", Json::num(*n as f64)),
                    ("p50_ms", Json::num(*p50)),
                    ("p99_ms", Json::num(*p99)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("bench", Json::str("serve_replay")),
            ("arrivals", Json::str(self.label.clone())),
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("shed_deadline", Json::num(self.shed_deadline as f64)),
            ("rejected_queue_full", Json::num(self.rejected_queue_full as f64)),
            ("rejected_other", Json::num(self.rejected_other as f64)),
            ("faulted", Json::num(self.faulted as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("deadline_missed", Json::num(self.deadline_missed as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("mean_batch", Json::num(self.mean_batch)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("per_class", Json::Arr(per_class)),
        ])
    }
}

fn quantile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Open-loop replay against a running server: requests round-robin over
/// the resident tenants at the spec's arrival spacing, then the driver
/// waits for every admitted reply. Typed rejections are counted by
/// reason; an admitted request whose reply never arrives counts as
/// `dropped` (contract violation).
pub fn replay(server: &Server, spec: &ReplaySpec) -> ReplayOutcome {
    let tenants = server.tenants();
    assert!(!tenants.is_empty(), "replay needs at least one tenant");
    let delays = spec.arrivals.delays(spec.requests, spec.seed);
    let mut rng = Rng::new(spec.seed ^ 0x9e37_79b9_7f4a_7c15);

    // Pre-resolve per-tenant deadlines (explicit wins over factor).
    let deadlines: Vec<Option<Duration>> = tenants
        .iter()
        .map(|t| {
            spec.deadline.or_else(|| {
                let f = spec.deadline_factor?;
                let image_ms = t.image_ms?;
                Some(Duration::from_secs_f64(f * image_ms * t.max_batch as f64 / 1e3))
            })
        })
        .collect();

    let mut inflight: Vec<(
        usize,
        std::sync::mpsc::Receiver<crate::util::error::Result<super::ServeResponse>>,
    )> = Vec::new();
    let (mut shed_deadline, mut rejected_queue_full, mut rejected_other) = (0, 0, 0);
    let start = Instant::now();
    for (i, delay) in delays.iter().enumerate() {
        if !delay.is_zero() {
            std::thread::sleep(*delay);
        }
        let t = i % tenants.len();
        let image = rng.normal_vec(tenants[t].input_len.max(1));
        let (slot, class) = if spec.classes.is_empty() {
            (0, None)
        } else {
            let slot = i % spec.classes.len();
            (slot, Some(spec.classes[slot].clone()))
        };
        let opts = RequestOptions { class, deadline: deadlines[t] };
        match server.router().submit_with(&tenants[t].name, image, opts) {
            Ok(rx) => inflight.push((slot, rx)),
            Err(Error::Rejected(Rejected::DeadlineInfeasible { .. })) => shed_deadline += 1,
            Err(Error::Rejected(Rejected::QueueFull { .. })) => rejected_queue_full += 1,
            Err(_) => rejected_other += 1,
        }
    }

    // Collect every admitted reply; per-class latency via one histogram
    // per class slot (slot 0 doubles as "untagged" when classless).
    let n_classes = spec.classes.len().max(1);
    let mut class_lat: Vec<Vec<f64>> = vec![Vec::new(); n_classes];
    let mut all_lat: Vec<f64> = Vec::new();
    let mut completed = 0;
    let mut faulted = 0;
    let mut dropped = 0;
    let mut deadline_missed = 0;
    for (slot, rx) in inflight {
        match rx.recv() {
            Ok(Ok(resp)) => {
                completed += 1;
                if !resp.deadline_met {
                    deadline_missed += 1;
                }
                let ms = resp.latency.as_secs_f64() * 1e3;
                all_lat.push(ms);
                class_lat[slot].push(ms);
            }
            // A typed fault answer (quarantine / respawn exhaustion):
            // the contract held — the request was answered.
            Ok(Err(_)) => faulted += 1,
            Err(_) => dropped += 1,
        }
    }
    let wall_s = start.elapsed().as_secs_f64();

    all_lat.sort_by(|a, b| a.total_cmp(b));
    let per_class = spec
        .classes
        .iter()
        .enumerate()
        .map(|(slot, name)| {
            let lat = &mut class_lat[slot];
            lat.sort_by(|a, b| a.total_cmp(b));
            (name.clone(), lat.len(), quantile_ms(lat, 0.5), quantile_ms(lat, 0.99))
        })
        .collect();

    ReplayOutcome {
        label: spec.arrivals.label(),
        submitted: spec.requests,
        completed,
        shed_deadline,
        rejected_queue_full,
        rejected_other,
        faulted,
        dropped,
        deadline_missed,
        wall_s,
        throughput_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        mean_batch: server.metrics().counters.mean_batch_size(),
        p50_ms: quantile_ms(&all_lat, 0.5),
        p99_ms: quantile_ms(&all_lat, 0.99),
        per_class,
    }
}

/// Shared helper for latency summaries over raw millisecond samples
/// (bench drivers that don't go through [`LatencyHistogram`]).
pub fn percentiles_ms(samples: &mut Vec<f64>) -> (f64, f64) {
    samples.sort_by(|a, b| a.total_cmp(b));
    (quantile_ms(samples, 0.5), quantile_ms(samples, 0.99))
}

/// Bucketed histogram variant (metrics-path parity check in tests).
pub fn histogram_percentiles_ms(h: &LatencyHistogram) -> (f64, f64) {
    (
        h.quantile(0.5).as_secs_f64() * 1e3,
        h.quantile(0.99).as_secs_f64() * 1e3,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_has_zero_delays() {
        let d = ArrivalProcess::Burst.delays(10, 1);
        assert_eq!(d.len(), 10);
        assert!(d.iter().all(|&x| x.is_zero()));
    }

    #[test]
    fn uniform_rate_matches() {
        let d = ArrivalProcess::Uniform { rate_per_s: 100.0 }.delays(11, 1);
        let total: Duration = d.iter().sum();
        assert!((total.as_secs_f64() - 0.1).abs() < 1e-6, "{total:?}");
    }

    #[test]
    fn poisson_mean_close_to_rate() {
        let rate = 200.0;
        let n = 5000;
        let d = ArrivalProcess::Poisson { rate_per_s: rate }.delays(n, 7);
        let mean = d.iter().map(|x| x.as_secs_f64()).sum::<f64>() / (n - 1) as f64;
        assert!((mean * rate - 1.0).abs() < 0.1, "mean gap {mean}");
    }

    #[test]
    fn poisson_deterministic_per_seed() {
        let a = ArrivalProcess::Poisson { rate_per_s: 50.0 }.delays(20, 3);
        let b = ArrivalProcess::Poisson { rate_per_s: 50.0 }.delays(20, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn bursty_structure() {
        let gap = Duration::from_millis(5);
        let d = ArrivalProcess::Bursty { size: 4, gap }.delays(12, 1);
        assert_eq!(d[0], Duration::ZERO);
        assert_eq!(d[4], gap);
        assert_eq!(d[5], Duration::ZERO);
        assert_eq!(d[8], gap);
    }

    #[test]
    fn pareto_mean_matches_requested_rate() {
        // The L normalisation must land the empirical mean on 1/rate.
        // n=20000 keeps the sample error of a heavy-tailed (but
        // bounded) mean a couple of percent; assert within 10%.
        let rate = 500.0;
        let n = 20_000;
        let d = ArrivalProcess::BoundedPareto { rate_per_s: rate, alpha: 1.5, cap: 1000.0 }
            .delays(n, 11);
        let mean = d.iter().map(|x| x.as_secs_f64()).sum::<f64>() / (n - 1) as f64;
        assert!(
            (mean * rate - 1.0).abs() < 0.1,
            "mean gap {mean} vs requested {}",
            1.0 / rate
        );
        assert!(d.iter().skip(1).all(|x| x.as_secs_f64() > 0.0));
    }

    #[test]
    fn pareto_is_heavy_tailed_and_bounded() {
        let d = ArrivalProcess::BoundedPareto { rate_per_s: 100.0, alpha: 1.5, cap: 1000.0 }
            .delays(5000, 13);
        let gaps: Vec<f64> = d.iter().skip(1).map(|x| x.as_secs_f64()).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let max = gaps.iter().cloned().fold(0.0, f64::max);
        // Heavy tail: the max gap dwarfs the mean (Poisson at this n
        // gives max/mean ≈ ln n ≈ 8.5; the tail index here pushes far
        // beyond — but never past the bound).
        assert!(max / mean > 10.0, "max {max} mean {mean}: tail not heavy");
        let min = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min <= 1000.0 + 1e-6, "bound violated: {max} / {min}");
    }

    #[test]
    fn pareto_deterministic_per_seed() {
        let p = ArrivalProcess::BoundedPareto { rate_per_s: 50.0, alpha: 1.2, cap: 100.0 };
        assert_eq!(p.delays(50, 3), p.delays(50, 3));
        assert_ne!(p.delays(50, 3), p.delays(50, 4));
    }

    #[test]
    fn labels() {
        assert_eq!(ArrivalProcess::Burst.label(), "burst");
        assert_eq!(
            ArrivalProcess::Bursty { size: 4, gap: Duration::from_millis(5) }.label(),
            "bursty-4x5ms"
        );
        assert_eq!(
            ArrivalProcess::BoundedPareto { rate_per_s: 100.0, alpha: 1.5, cap: 1000.0 }.label(),
            "pareto-100rps-a1.5-k1000"
        );
    }

    #[test]
    fn quantiles_over_samples() {
        let mut s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        // Shuffle-free check: percentiles_ms sorts internally.
        s.reverse();
        let (p50, p99) = percentiles_ms(&mut s);
        assert_eq!(p50, 51.0);
        assert_eq!(p99, 99.0);
        let (p50, p99) = percentiles_ms(&mut Vec::new());
        assert_eq!((p50, p99), (0.0, 0.0));
    }

    #[test]
    fn outcome_json_and_summary_shape() {
        let o = ReplayOutcome {
            label: "burst".into(),
            submitted: 10,
            completed: 7,
            shed_deadline: 2,
            rejected_queue_full: 1,
            rejected_other: 0,
            faulted: 1,
            dropped: 0,
            deadline_missed: 1,
            wall_s: 0.5,
            throughput_rps: 14.0,
            mean_batch: 3.5,
            p50_ms: 1.25,
            p99_ms: 9.75,
            per_class: vec![("gold".into(), 4, 1.0, 2.0)],
        };
        let line = o.summary_line();
        assert!(line.contains("completed=7"), "{line}");
        assert!(line.contains("shed_deadline=2"), "{line}");
        assert!(line.contains("faulted=1"), "{line}");
        assert!(line.contains("dropped=0"), "{line}");
        let j = o.to_json().to_string();
        assert!(j.contains("\"bench\":\"serve_replay\""), "{j}");
        assert!(j.contains("\"p99_ms\":9.75"), "{j}");
        assert!(j.contains("\"class\":\"gold\""), "{j}");
        // Round-trips through the parser.
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_f64().unwrap(), 7.0);
    }
}
