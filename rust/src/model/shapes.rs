//! Shape inference + cost profiling over the layer IR.
//!
//! The cost numbers (FLOPs, parameter/activation bytes) feed the SoC
//! simulator's roofline model (`soc::latency`), so they are computed per
//! *primitive* layer, branches included.

use crate::model::{Layer, LayerOp, Network, TensorShape};
use crate::util::error::{Error, Result};

/// Conv/pool output size: `floor((size + 2p - k) / s) + 1`.
pub fn conv_out(size: usize, k: usize, s: usize, p: usize) -> Result<usize> {
    let padded = size + 2 * p;
    if padded < k {
        return Err(Error::Shape(format!(
            "window k={k} larger than padded input {padded}"
        )));
    }
    Ok((padded - k) / s + 1)
}

/// Parameter geometry of one conv/dense layer.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamLayer {
    pub name: String,
    /// Input shape this layer sees.
    pub input: TensorShape,
    /// Output shape it produces.
    pub output: TensorShape,
    pub weight_elems: usize,
    pub bias_elems: usize,
    /// Kernel size (0 for dense).
    pub k: usize,
    /// For the first dense after a `flatten`: the `(C, H, W)` shape the
    /// flatten consumed — needed to permute FC weight columns for the
    /// map-major flatten order (compile-time reorder).
    pub flatten_src: Option<(usize, usize, usize)>,
}

/// Per-primitive-layer cost entry for the simulator.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub name: String,
    pub kind: &'static str,
    /// Multiply–accumulates counted as 2 FLOPs each; pools/LRN counted
    /// as one op per element visited.
    pub flops: f64,
    /// Parameter bytes (f32) this layer must stream in.
    pub param_bytes: f64,
    pub input_bytes: f64,
    pub output_bytes: f64,
    /// Output elements — the OLP thread count for this layer (alpha in
    /// section IV.A: one thread per output pixel).
    pub output_elems: usize,
}

/// Full inference result.
#[derive(Debug, Clone)]
pub struct NetworkInfo {
    pub output: TensorShape,
    pub param_layers: Vec<ParamLayer>,
    pub costs: Vec<LayerCost>,
    /// Inference-time state: `(C,H,W)` a pending flatten consumed, handed
    /// to the next dense layer (then cleared).
    pending_flatten: Option<(usize, usize, usize)>,
}

impl NetworkInfo {
    pub fn total_flops(&self) -> f64 {
        self.costs.iter().map(|c| c.flops).sum()
    }

    pub fn total_param_bytes(&self) -> f64 {
        self.costs.iter().map(|c| c.param_bytes).sum()
    }

    pub fn param_layer(&self, name: &str) -> Option<&ParamLayer> {
        self.param_layers.iter().find(|p| p.name == name)
    }
}

/// Infer every shape + cost in the network.
pub fn infer(net: &Network) -> Result<NetworkInfo> {
    let mut info = NetworkInfo {
        output: net.input,
        param_layers: Vec::new(),
        costs: Vec::new(),
        pending_flatten: None,
    };
    let out = walk(&net.layers, net.input, &mut info)?;
    info.output = out;
    Ok(info)
}

fn walk(layers: &[Layer], mut shape: TensorShape, info: &mut NetworkInfo) -> Result<TensorShape> {
    for layer in layers {
        shape = step(layer, shape, info)?;
    }
    Ok(shape)
}

fn step(layer: &Layer, shape: TensorShape, info: &mut NetworkInfo) -> Result<TensorShape> {
    let f32b = 4.0;
    match &layer.op {
        LayerOp::Conv { m, k, s, p, .. } => {
            let (c, h, w) = shape.as_maps().map_err(|e| named(e, layer))?;
            let ho = conv_out(h, *k, *s, *p).map_err(|e| named(e, layer))?;
            let wo = conv_out(w, *k, *s, *p).map_err(|e| named(e, layer))?;
            let out = TensorShape::maps(*m, ho, wo);
            let weight_elems = m * c * k * k;
            info.param_layers.push(ParamLayer {
                name: layer.name.clone(),
                input: shape,
                output: out,
                weight_elems,
                bias_elems: *m,
                k: *k,
                flatten_src: None,
            });
            info.costs.push(LayerCost {
                name: layer.name.clone(),
                kind: "conv",
                flops: 2.0 * (m * c * k * k * ho * wo) as f64,
                param_bytes: f32b * (weight_elems + m) as f64,
                input_bytes: f32b * shape.elements() as f64,
                output_bytes: f32b * out.elements() as f64,
                output_elems: out.elements(),
            });
            Ok(out)
        }
        LayerOp::MaxPool { k, s, p } | LayerOp::AvgPool { k, s, p } => {
            let (c, h, w) = shape.as_maps().map_err(|e| named(e, layer))?;
            let ho = conv_out(h, *k, *s, *p).map_err(|e| named(e, layer))?;
            let wo = conv_out(w, *k, *s, *p).map_err(|e| named(e, layer))?;
            let out = TensorShape::maps(c, ho, wo);
            info.costs.push(LayerCost {
                name: layer.name.clone(),
                kind: if matches!(layer.op, LayerOp::MaxPool { .. }) {
                    "maxpool"
                } else {
                    "avgpool"
                },
                flops: (c * ho * wo * k * k) as f64,
                param_bytes: 0.0,
                input_bytes: f32b * shape.elements() as f64,
                output_bytes: f32b * out.elements() as f64,
                output_elems: out.elements(),
            });
            Ok(out)
        }
        LayerOp::Lrn { size, .. } => {
            let _ = shape.as_maps().map_err(|e| named(e, layer))?;
            info.costs.push(LayerCost {
                name: layer.name.clone(),
                kind: "lrn",
                // per element: `size` squares+adds, a power, a divide ≈ size+4
                flops: (shape.elements() * (size + 4)) as f64,
                param_bytes: 0.0,
                input_bytes: f32b * shape.elements() as f64,
                output_bytes: f32b * shape.elements() as f64,
                output_elems: shape.elements(),
            });
            Ok(shape)
        }
        LayerOp::Fork { branches } => {
            let (_, h0, w0) = shape.as_maps().map_err(|e| named(e, layer))?;
            let mut total_c = 0;
            let mut out_hw = None;
            for br in branches {
                let out = walk(br, shape, info)?;
                let (c, h, w) = out.as_maps().map_err(|e| named(e, layer))?;
                if let Some((ph, pw)) = out_hw {
                    if (h, w) != (ph, pw) {
                        return Err(Error::Shape(format!(
                            "fork {}: branch spatial mismatch {h}x{w} vs {ph}x{pw}",
                            layer.name
                        )));
                    }
                } else {
                    out_hw = Some((h, w));
                }
                total_c += c;
            }
            let (h, w) = out_hw.unwrap_or((h0, w0));
            Ok(TensorShape::maps(total_c, h, w))
        }
        LayerOp::Flatten => {
            if let TensorShape::Maps { c, h, w } = shape {
                info.pending_flatten = Some((c, h, w));
            }
            Ok(TensorShape::Flat { len: shape.elements() })
        }
        LayerOp::Gap => {
            let (c, h, w) = shape.as_maps().map_err(|e| named(e, layer))?;
            info.costs.push(LayerCost {
                name: layer.name.clone(),
                kind: "gap",
                flops: (c * h * w) as f64,
                param_bytes: 0.0,
                input_bytes: f32b * shape.elements() as f64,
                output_bytes: f32b * c as f64,
                output_elems: c,
            });
            Ok(TensorShape::Flat { len: c })
        }
        LayerOp::Dense { o, .. } => {
            let len = match shape {
                TensorShape::Flat { len } => len,
                TensorShape::Maps { .. } => {
                    return Err(named(
                        Error::Shape("dense requires flatten/gap first".into()),
                        layer,
                    ))
                }
            };
            let out = TensorShape::Flat { len: *o };
            info.param_layers.push(ParamLayer {
                name: layer.name.clone(),
                input: shape,
                output: out,
                weight_elems: o * len,
                bias_elems: *o,
                k: 0,
                flatten_src: info.pending_flatten.take(),
            });
            info.costs.push(LayerCost {
                name: layer.name.clone(),
                kind: "dense",
                flops: 2.0 * (o * len) as f64,
                param_bytes: f32b * (o * len + o) as f64,
                input_bytes: f32b * len as f64,
                output_bytes: f32b * *o as f64,
                output_elems: *o,
            });
            Ok(out)
        }
        LayerOp::Softmax => Ok(shape),
    }
}

fn named(e: Error, layer: &Layer) -> Error {
    Error::Shape(format!("layer {}: {e}", layer.name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn conv_out_matches_python() {
        assert_eq!(conv_out(227, 11, 4, 0).unwrap(), 55);
        assert_eq!(conv_out(55, 3, 2, 0).unwrap(), 27);
        assert_eq!(conv_out(112, 3, 2, 1).unwrap(), 56); // ceil-mode emulation
        assert!(conv_out(4, 5, 1, 0).is_err());
    }

    #[test]
    fn tinynet_shapes() {
        let info = infer(&zoo::tinynet()).unwrap();
        assert_eq!(info.output, TensorShape::Flat { len: 8 });
        let fc4 = info.param_layer("fc4").unwrap();
        assert_eq!(fc4.input, TensorShape::Flat { len: 512 });
        assert_eq!(fc4.weight_elems, 64 * 512);
    }

    #[test]
    fn alexnet_shapes_and_flops() {
        let info = infer(&zoo::alexnet()).unwrap();
        assert_eq!(info.output, TensorShape::Flat { len: 1000 });
        let conv1 = info.param_layer("conv1").unwrap();
        assert_eq!(conv1.output.as_maps().unwrap(), (96, 55, 55));
        let fc6 = info.param_layer("fc6").unwrap();
        assert_eq!(fc6.input, TensorShape::Flat { len: 9216 });
        // Our AlexNet is the group=1 (single-tower) variant: ≈ 2.28
        // GFLOPs (the paper's group=2 original is ≈ 1.45) — DESIGN.md.
        let gf = info.total_flops() / 1e9;
        assert!((2.0..2.5).contains(&gf), "alexnet GFLOPs {gf}");
        let params = zoo::alexnet().param_count() as f64 / 1e6;
        assert!((58.0..63.0).contains(&params), "alexnet params {params}M");
    }

    #[test]
    fn squeezenet_param_count_matches_paper_scale() {
        // SqueezeNet's claim to fame: ~1.2M params (50x fewer than AlexNet).
        let params = zoo::squeezenet().param_count() as f64 / 1e6;
        assert!((1.0..1.5).contains(&params), "squeezenet params {params}M");
    }

    #[test]
    fn googlenet_shapes() {
        let info = infer(&zoo::googlenet()).unwrap();
        assert_eq!(info.output, TensorShape::Flat { len: 1000 });
        let b1 = info.param_layer("inc3a/b1").unwrap();
        assert_eq!(b1.input.as_maps().unwrap(), (192, 28, 28));
        let fc = info.param_layer("fc").unwrap();
        assert_eq!(fc.input, TensorShape::Flat { len: 1024 });
        // ~7M params, ~3 GFLOPs
        let params = zoo::googlenet().param_count() as f64 / 1e6;
        assert!((5.5..8.0).contains(&params), "googlenet params {params}M");
    }

    #[test]
    fn dense_without_flatten_rejected() {
        use crate::model::{Layer, Network};
        let net = Network {
            name: "bad".into(),
            input: TensorShape::maps(3, 8, 8),
            classes: 4,
            layers: vec![Layer::new("fc", LayerOp::Dense { o: 4, relu: false })],
        };
        assert!(infer(&net).is_err());
    }

    #[test]
    fn fork_spatial_mismatch_rejected() {
        use crate::model::{Layer, Network};
        let net = Network {
            name: "bad".into(),
            input: TensorShape::maps(4, 8, 8),
            classes: 4,
            layers: vec![Layer::new(
                "fork",
                LayerOp::Fork {
                    branches: vec![
                        vec![Layer::new("a", LayerOp::Conv { m: 4, k: 1, s: 1, p: 0, relu: true })],
                        vec![Layer::new("b", LayerOp::Conv { m: 4, k: 3, s: 1, p: 0, relu: true })],
                    ],
                },
            )],
        };
        assert!(infer(&net).is_err());
    }
}
