//! CPU topology probe and thread-affinity primitives for the
//! topology-aware thread pool (ROADMAP "NUMA/affinity-aware thread
//! pool").
//!
//! Mobile SoCs are heterogeneous: big.LITTLE designs pair high-capacity
//! cores with efficiency cores, and each cluster has its own L2. A
//! thread-workload allocation that assumes threads stay where their
//! caches are (paper section IV.A) needs to know that grouping, so this
//! module answers two questions with zero external dependencies:
//!
//! * **What does the machine look like?** [`Topology::probe`] reads
//!   Linux sysfs: per-CPU `cpu_capacity` (the scheduler's relative
//!   per-core throughput, 1024 = the biggest core) groups cores into
//!   clusters; when capacities are uniform, `physical_package_id`
//!   distinguishes multi-socket hosts. Only CPUs in the calling
//!   process's affinity mask (`sched_getaffinity`) are considered, so a
//!   `taskset -c 0,1` harness sees exactly the two cores it was given.
//!   Off Linux — or when sysfs is absent — the probe degrades to
//!   [`Topology::uniform`]: one cluster, `available_parallelism` cores,
//!   and every pinning request becomes a no-op.
//! * **How do threads stay put?** [`pin_current_thread`] wraps
//!   `sched_setaffinity` via a direct libc FFI declaration (the crate
//!   stays std-only). Failures — and non-Linux builds — are silent
//!   no-ops: affinity is a performance hint, never a correctness
//!   dependency, so every parity suite must pass identically with
//!   pinning on, off, or unavailable.
//!
//! [`CoreSet`] is the serve-layer face of the same machinery: a small
//! copyable CPU mask a model worker can be pinned to, with
//! [`Topology::partition`] handing co-hosted models **disjoint** sets so
//! they stop trampling each other's caches.

use crate::engine::parallel::chunk_ranges;

/// The `cpu_capacity` value of a baseline big core (Linux convention).
pub const DEFAULT_CAPACITY: u32 = 1024;

/// One group of cores sharing a capacity class (and, in practice, an L2
/// slice): a big or LITTLE cluster, or one socket of a multi-socket
/// host.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreCluster {
    /// CPU ids in the cluster, ascending.
    pub cpus: Vec<usize>,
    /// Relative per-core compute capacity (sysfs `cpu_capacity` scale;
    /// [`DEFAULT_CAPACITY`] when the host does not report one).
    pub capacity: u32,
}

/// The machine's core grouping, as seen through the process's CPU
/// affinity mask.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Topology {
    /// Clusters sorted by capacity, biggest first.
    pub clusters: Vec<CoreCluster>,
    /// True when `cpus` hold real ids from the affinity mask (pinning
    /// is meaningful); false for the uniform fallback (pinning no-ops).
    pub probed: bool,
}

impl Topology {
    /// Probe the host. Linux: sysfs capacities + packages filtered by
    /// the `sched_getaffinity` mask. Elsewhere (or on probe failure):
    /// the uniform fallback.
    pub fn probe() -> Topology {
        #[cfg(target_os = "linux")]
        {
            if let Some(t) = probe_linux() {
                return t;
            }
        }
        Topology::uniform(
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
        )
    }

    /// One homogeneous cluster of `n` logical cores with placeholder
    /// ids. `probed` is false, so pinning requests derived from it are
    /// no-ops — this is the portable fallback the constrained-host CI
    /// job exercises.
    pub fn uniform(n: usize) -> Topology {
        let n = n.max(1);
        Topology {
            clusters: vec![CoreCluster {
                cpus: (0..n).collect(),
                capacity: DEFAULT_CAPACITY,
            }],
            probed: false,
        }
    }

    /// Total cores across clusters.
    pub fn cpu_count(&self) -> usize {
        self.clusters.iter().map(|c| c.cpus.len()).sum()
    }

    /// Split the machine's cores into `n` **disjoint** [`CoreSet`]s
    /// (contiguous runs, biggest cluster first) for co-hosted serve
    /// workers. Unprobed topologies yield empty sets: pinning stays a
    /// no-op rather than guessing ids.
    pub fn partition(&self, n: usize) -> Vec<CoreSet> {
        if n == 0 {
            return Vec::new();
        }
        if !self.probed {
            return vec![CoreSet::empty(); n];
        }
        let all: Vec<usize> = self
            .clusters
            .iter()
            .flat_map(|c| c.cpus.iter().copied())
            .collect();
        let mut out = vec![CoreSet::empty(); n];
        for (i, r) in chunk_ranges(all.len(), n).into_iter().enumerate() {
            out[i] = CoreSet::of(&all[r]);
        }
        out
    }
}

/// A copyable set of CPU ids (0..64) for serve-worker affinity
/// requests. Ids >= 64 are ignored — the serve layer targets mobile
/// SoCs and small hosts; the engine pool's own pinning has no such
/// limit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CoreSet(u64);

impl CoreSet {
    /// The empty set (pinning no-op).
    pub fn empty() -> CoreSet {
        CoreSet(0)
    }

    /// Set of the given CPU ids (ids >= 64 ignored).
    pub fn of(cpus: &[usize]) -> CoreSet {
        let mut bits = 0u64;
        for &c in cpus {
            if c < 64 {
                bits |= 1 << c;
            }
        }
        CoreSet(bits)
    }

    /// CPU ids in the set, ascending.
    pub fn cpus(&self) -> Vec<usize> {
        (0..64).filter(|&c| self.0 >> c & 1 == 1).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    /// True when the two sets share no CPU — what co-hosted models
    /// should verify before pinning.
    pub fn disjoint(&self, other: &CoreSet) -> bool {
        self.0 & other.0 == 0
    }
}

impl std::fmt::Display for CoreSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let ids: Vec<String> = self.cpus().iter().map(|c| c.to_string()).collect();
        write!(f, "{{{}}}", ids.join(","))
    }
}

/// Pin the calling thread to `cpus`. Returns whether the kernel
/// accepted the mask; empty sets, failures (ids outside the process
/// mask), and non-Linux builds are no-ops returning false. Never
/// affects correctness — only where the scheduler may place the thread.
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    #[cfg(target_os = "linux")]
    {
        if cpus.is_empty() {
            return false;
        }
        let mut set = sys::CpuSet::zero();
        for &c in cpus {
            set.set(c);
        }
        // SAFETY: plain FFI into glibc's `sched_setaffinity`; pid 0 =
        // the calling thread, and the mask pointer/size describe a
        // fully-initialised `CpuSet` matching the kernel's `cpu_set_t`
        // ABI (`#[repr(C)]`, 1024 bits). The call reads the mask and
        // touches no other memory.
        unsafe {
            sys::sched_setaffinity(0, std::mem::size_of::<sys::CpuSet>(), &set) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpus;
        false
    }
}

// ---------------------------------------------------------------------------
// Linux probe internals
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    /// Fixed 1024-CPU mask matching glibc's `cpu_set_t`.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct CpuSet {
        pub bits: [u64; 16],
    }

    impl CpuSet {
        pub fn zero() -> CpuSet {
            CpuSet { bits: [0; 16] }
        }

        pub fn set(&mut self, cpu: usize) {
            if cpu < 1024 {
                self.bits[cpu / 64] |= 1 << (cpu % 64);
            }
        }

        pub fn has(&self, cpu: usize) -> bool {
            cpu < 1024 && self.bits[cpu / 64] >> (cpu % 64) & 1 == 1
        }
    }

    extern "C" {
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
        pub fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut CpuSet) -> i32;
    }
}

/// CPUs the current process may run on, per `sched_getaffinity` — the
/// honest universe for both the probe and pinning (a `taskset` wrapper
/// shrinks it).
#[cfg(target_os = "linux")]
fn allowed_cpus() -> Option<Vec<usize>> {
    let mut set = sys::CpuSet::zero();
    // SAFETY: plain FFI into glibc's `sched_getaffinity`; pid 0 = the
    // calling thread, and the out-pointer/size describe an exclusively
    // borrowed `CpuSet` matching the kernel's `cpu_set_t` ABI. The call
    // writes only into that mask.
    let rc = unsafe {
        sys::sched_getaffinity(0, std::mem::size_of::<sys::CpuSet>(), &mut set)
    };
    if rc != 0 {
        return None;
    }
    let cpus: Vec<usize> = (0..1024).filter(|&c| set.has(c)).collect();
    if cpus.is_empty() {
        None
    } else {
        Some(cpus)
    }
}

#[cfg(target_os = "linux")]
fn read_sysfs_u32(path: &str) -> Option<u32> {
    std::fs::read_to_string(path).ok()?.trim().parse().ok()
}

#[cfg(target_os = "linux")]
fn probe_linux() -> Option<Topology> {
    let cpus = allowed_cpus()?;
    // Capacity classes (big.LITTLE). Hosts without cpu_capacity report
    // one uniform class.
    let caps: Vec<u32> = cpus
        .iter()
        .map(|&c| {
            read_sysfs_u32(&format!("/sys/devices/system/cpu/cpu{c}/cpu_capacity"))
                .unwrap_or(DEFAULT_CAPACITY)
        })
        .collect();
    let mut clusters: Vec<CoreCluster> = Vec::new();
    for (&cpu, &cap) in cpus.iter().zip(&caps) {
        match clusters.iter_mut().find(|cl| cl.capacity == cap) {
            Some(cl) => cl.cpus.push(cpu),
            None => clusters.push(CoreCluster { cpus: vec![cpu], capacity: cap }),
        }
    }
    // Uniform capacities on >1 CPU: fall back to package grouping so
    // multi-socket hosts still get per-socket queues.
    if clusters.len() == 1 && cpus.len() > 1 {
        let pkgs: Vec<Option<u32>> = cpus
            .iter()
            .map(|&c| {
                read_sysfs_u32(&format!(
                    "/sys/devices/system/cpu/cpu{c}/topology/physical_package_id"
                ))
            })
            .collect();
        if pkgs.iter().all(|p| p.is_some()) {
            let mut by_pkg: Vec<(u32, Vec<usize>)> = Vec::new();
            for (&cpu, pkg) in cpus.iter().zip(&pkgs) {
                let pkg = pkg.unwrap();
                match by_pkg.iter_mut().find(|(p, _)| *p == pkg) {
                    Some((_, v)) => v.push(cpu),
                    None => by_pkg.push((pkg, vec![cpu])),
                }
            }
            if by_pkg.len() > 1 {
                clusters = by_pkg
                    .into_iter()
                    .map(|(_, cpus)| CoreCluster { cpus, capacity: DEFAULT_CAPACITY })
                    .collect();
            }
        }
    }
    // Biggest cluster first; stable order for deterministic placement.
    clusters.sort_by(|a, b| b.capacity.cmp(&a.capacity));
    Some(Topology { clusters, probed: true })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_reports_at_least_one_core() {
        let t = Topology::probe();
        assert!(!t.clusters.is_empty());
        assert!(t.cpu_count() >= 1);
        for cl in &t.clusters {
            assert!(!cl.cpus.is_empty());
            assert!(cl.capacity > 0);
        }
    }

    #[test]
    fn uniform_fallback_shape() {
        let t = Topology::uniform(4);
        assert_eq!(t.clusters.len(), 1);
        assert_eq!(t.cpu_count(), 4);
        assert!(!t.probed);
        // Unprobed topologies hand out empty (no-op) core sets.
        let sets = t.partition(2);
        assert_eq!(sets.len(), 2);
        assert!(sets.iter().all(|s| s.is_empty()));
        assert_eq!(Topology::uniform(0).cpu_count(), 1);
    }

    #[test]
    fn partition_is_disjoint_and_covers() {
        let t = Topology {
            clusters: vec![
                CoreCluster { cpus: vec![0, 1, 2, 3], capacity: 1024 },
                CoreCluster { cpus: vec![4, 5], capacity: 512 },
            ],
            probed: true,
        };
        let sets = t.partition(3);
        assert_eq!(sets.len(), 3);
        for i in 0..sets.len() {
            for j in i + 1..sets.len() {
                assert!(sets[i].disjoint(&sets[j]), "sets {i} and {j} overlap");
            }
        }
        let mut all: Vec<usize> = sets.iter().flat_map(|s| s.cpus()).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn core_set_roundtrip() {
        let s = CoreSet::of(&[0, 3, 63, 64, 1000]);
        assert_eq!(s.cpus(), vec![0, 3, 63]); // >= 64 ignored
        assert!(!s.is_empty());
        assert!(CoreSet::empty().is_empty());
        assert!(s.disjoint(&CoreSet::of(&[1, 2])));
        assert!(!s.disjoint(&CoreSet::of(&[3])));
        assert_eq!(format!("{}", CoreSet::of(&[1, 2])), "{1,2}");
    }

    #[test]
    fn pinning_is_a_safe_no_op_or_success() {
        // Whatever the host, pinning must never panic; empty = no-op.
        assert!(!pin_current_thread(&[]));
        let t = Topology::probe();
        if t.probed {
            let first = t.clusters[0].cpus[0];
            // Pinning to a CPU from our own mask should succeed on
            // Linux; restore the full mask afterwards.
            assert!(pin_current_thread(&[first]));
            let all: Vec<usize> =
                t.clusters.iter().flat_map(|c| c.cpus.iter().copied()).collect();
            assert!(pin_current_thread(&all));
        }
    }
}
