//! PJRT execution of the AOT artifacts (`xla` crate, CPU plugin).
//!
//! Load path: HLO **text** → `HloModuleProto::from_text_file` →
//! `XlaComputation` → `client.compile` (see /opt/xla-example and
//! DESIGN.md: text is the interchange format because jax ≥ 0.5 emits
//! 64-bit-id protos that xla_extension 0.5.1 rejects).
//!
//! Weights are uploaded to device buffers **once** at load
//! (`execute_b` fast path); per-inference work is one host→device input
//! transfer + execute + one device→host logits readback.
//!
//! `PjRtClient` is `Rc`-based (not `Send`): a [`Runtime`] and everything
//! loaded from it must stay on one thread. The serving layer
//! ([`crate::serve`]) owns a runtime on a dedicated worker thread.

use crate::config::modelfile::ModelFile;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Where parameter values come from when loading an artifact.
pub enum ParamSource {
    /// A `.capp` file already in map-major layout (e.g. the build-time
    /// reordered `tinynet_mm.capp`).
    MapMajorFile(ModelFile),
    /// Deterministic random weights in the manifest's shapes — for
    /// latency work on nets without shipped weights (values don't
    /// affect timing).
    Random(u64),
}

fn xla_err(e: xla::Error) -> Error {
    Error::Xla(e.to_string())
}

/// A PJRT CPU runtime: owns the client; loads artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu().map_err(xla_err)? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact and upload its weights.
    pub fn load(
        &self,
        manifest: &Manifest,
        spec: &ArtifactSpec,
        source: &ParamSource,
    ) -> Result<LoadedModel> {
        let path = manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Invalid(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(xla_err)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xla_err)?;

        // Upload parameters once, in manifest order (w, b per layer).
        let mut param_buffers = Vec::with_capacity(spec.params.len() * 2);
        let mut rng = Rng::new(match source {
            ParamSource::Random(seed) => *seed,
            _ => 0,
        });
        for p in &spec.params {
            let (w, b): (Vec<f32>, Vec<f32>) = match source {
                ParamSource::MapMajorFile(mf) => {
                    let (wt, bt) = mf.layer_params(&p.name)?;
                    if wt.data.len() != p.w_len() || bt.data.len() != p.b_len() {
                        return Err(Error::Shape(format!(
                            "artifact {} layer {}: file {}/{} vs manifest {}/{}",
                            spec.name,
                            p.name,
                            wt.data.len(),
                            bt.data.len(),
                            p.w_len(),
                            p.b_len()
                        )));
                    }
                    (wt.data.clone(), bt.data.clone())
                }
                ParamSource::Random(_) => {
                    let mut lrng = rng.fork(&p.name);
                    // Scale roughly He-normal by fan-in of the map-major
                    // weight's trailing dims (values are irrelevant to
                    // latency; just keep activations finite).
                    let fan = p.w_dims.iter().skip(2).product::<usize>().max(1);
                    (lrng.he_normal_vec(p.w_len(), fan), vec![0.0; p.b_len()])
                }
            };
            param_buffers.push(
                self.client
                    .buffer_from_host_buffer(&w, &p.w_dims, None)
                    .map_err(xla_err)?,
            );
            param_buffers.push(
                self.client
                    .buffer_from_host_buffer(&b, &p.b_dims, None)
                    .map_err(xla_err)?,
            );
        }

        Ok(LoadedModel {
            client: self.client.clone(),
            spec: spec.clone(),
            exe,
            param_buffers,
        })
    }
}

/// A compiled artifact with device-resident weights.
pub struct LoadedModel {
    client: xla::PjRtClient,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    param_buffers: Vec<xla::PjRtBuffer>,
}

impl LoadedModel {
    /// Batch capacity baked into the artifact.
    pub fn batch(&self) -> usize {
        self.spec.batch
    }

    /// Run inference on a full map-major input batch
    /// (`spec.input_shape` elements) → logits `(batch * classes)`.
    pub fn infer(&self, x_mm: &[f32]) -> Result<Vec<f32>> {
        if x_mm.len() != self.spec.input_len() {
            return Err(Error::Shape(format!(
                "artifact {}: input {} vs expected {}",
                self.spec.name,
                x_mm.len(),
                self.spec.input_len()
            )));
        }
        let input = self
            .client
            .buffer_from_host_buffer(x_mm, &self.spec.input_shape, None)
            .map_err(xla_err)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.param_buffers.len());
        args.push(&input);
        args.extend(self.param_buffers.iter());
        let result = self.exe.execute_b(&args).map_err(xla_err)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Xla("execute returned no outputs".into()))?
            .to_literal_sync()
            .map_err(xla_err)?;
        // Artifacts are lowered with return_tuple=True: unwrap the 1-tuple.
        let logits = out.to_tuple1().map_err(xla_err)?;
        logits.to_vec::<f32>().map_err(xla_err)
    }

    /// Convenience: per-image logits rows.
    pub fn infer_rows(&self, x_mm: &[f32]) -> Result<Vec<Vec<f32>>> {
        let flat = self.infer(x_mm)?;
        let classes = self.spec.output_shape[1];
        Ok(flat.chunks(classes).map(|c| c.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ops::softmax;

    fn manifest() -> Option<Manifest> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(dir).unwrap())
        } else {
            eprintln!("skipping: artifacts not built");
            None
        }
    }

    fn golden(m: &Manifest) -> ModelFile {
        ModelFile::read_from(m.dir.join("golden_tinynet.capp")).unwrap()
    }

    fn tinynet_weights(m: &Manifest) -> ModelFile {
        ModelFile::read_from(m.dir.join("tinynet_mm.capp")).unwrap()
    }

    #[test]
    fn tinynet_matches_golden_logits() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::new().unwrap();
        let spec = m.find("tinynet", "precise", 8).unwrap();
        let model = rt
            .load(&m, spec, &ParamSource::MapMajorFile(tinynet_weights(&m)))
            .unwrap();
        let g = golden(&m);
        let x = &g.get("x_mm").unwrap().data;
        let want = &g.get("logits_precise").unwrap().data;
        let got = model.infer(x).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn imprecise_artifact_matches_golden() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::new().unwrap();
        let spec = m.find("tinynet", "imprecise", 8).unwrap();
        let model = rt
            .load(&m, spec, &ParamSource::MapMajorFile(tinynet_weights(&m)))
            .unwrap();
        let g = golden(&m);
        let got = model.infer(&g.get("x_mm").unwrap().data).unwrap();
        let want = &g.get("logits_imprecise").unwrap().data;
        for (a, b) in got.iter().zip(want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn golden_labels_predicted() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::new().unwrap();
        let spec = m.find("tinynet", "precise", 8).unwrap();
        let model = rt
            .load(&m, spec, &ParamSource::MapMajorFile(tinynet_weights(&m)))
            .unwrap();
        let g = golden(&m);
        let rows = model.infer_rows(&g.get("x_mm").unwrap().data).unwrap();
        let labels = &g.get("labels").unwrap().data;
        let correct = rows
            .iter()
            .zip(labels)
            .filter(|(row, &lbl)| {
                let probs = softmax(row);
                let pred = probs
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .unwrap()
                    .0;
                pred == lbl as usize
            })
            .count();
        assert!(correct >= 6, "only {correct}/8 golden images classified");
    }

    #[test]
    fn wrong_input_len_rejected() {
        let Some(m) = manifest() else { return };
        let rt = Runtime::new().unwrap();
        let spec = m.find("tinynet", "precise", 1).unwrap();
        let model = rt
            .load(&m, spec, &ParamSource::Random(3))
            .unwrap();
        assert!(model.infer(&[0.0; 7]).is_err());
    }

    #[test]
    fn batch_to_mapmajor_pads() {
        let img = vec![1.0f32; 3 * 2 * 2];
        let out = crate::runtime::batch_to_mapmajor(&[&img], 3, 2, 2, 4, 2);
        // One stack of u=4 per image: 2*2*4 = 16 floats per image slot.
        assert_eq!(out.len(), 32);
        assert!(out[..16].iter().any(|&v| v != 0.0));
        assert!(out[16..].iter().all(|&v| v == 0.0));
    }
}
