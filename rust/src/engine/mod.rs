//! Native execution engine — the synthesized program's runtime body.
//!
//! Cappuccino's synthesizer emits a *plan* (see [`crate::synth`]); this
//! module is the machine that executes plans: map-major tensors,
//! OLP-threaded vectorised convolutions (section IV.A/IV.B), per-layer
//! arithmetic modes (section IV.C), plus the baseline and the rejected
//! KLP/FLP policies for the ablation benches.

pub mod conv;
pub mod mode;
pub mod network;
pub mod ops;
pub mod parallel;
pub mod tensor;

pub use conv::{conv_mm, conv_nchw_flp, conv_nchw_klp, conv_nchw_scalar};
pub use mode::ArithMode;
pub use network::{run_baseline, run_mapmajor, EngineParams, ExecConfig, ModeAssignment};
pub use parallel::Parallelism;
pub use tensor::{MapTensor, Tensor};
