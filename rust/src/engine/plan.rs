//! Compiled execution plans — compile once, execute many.
//!
//! Cappuccino's premise is that inference software is *synthesized*
//! ahead of time and then runs with no interpretive or allocation
//! overhead on the request path. [`ExecutionPlan`] is that executable
//! form for the native engine: given a network, compiled parameters, a
//! per-layer mode assignment and an execution config, `compile`:
//!
//! 1. runs shape inference **once** (every window/shape violation
//!    surfaces here as `Error::Shape`, never as a hot-path underflow),
//! 2. lowers the layer tree into a flat step sequence over an explicit
//!    register file of activation buffers,
//! 3. **bakes** every layer's weights into its arithmetic mode's domain
//!    (the per-call weight cast the legacy executor paid is gone), and
//! 4. sizes a buffer arena — per-step outputs, one shared pad/cast
//!    scratch, and per-thread FLP/KLP reduction buffers — that is
//!    allocated once and reused across every inference.
//!
//! `run` then walks the steps with zero steady-state allocation — at
//! `threads = 1` the returned logits vector is the only per-inference
//! heap traffic (metered through [`crate::metrics::AllocCounter`]);
//! multi-threaded runs additionally pay a handful of small dispatch
//! boxes per parallel section — and zero thread spawns (all parallel
//! sections run on the persistent [`crate::engine::parallel`] pool).
//!
//! Three lowering families share the machinery:
//!
//! * [`ExecutionPlan::compile`] — map-major + OLP `conv_mm`: the
//!   synthesized program (what [`crate::engine::run_mapmajor`] wraps).
//! * [`ExecutionPlan::compile_baseline`] — row-major scalar, precise:
//!   the Table I baseline (what [`crate::engine::run_baseline`] wraps).
//! * [`ExecutionPlan::compile_policy`] — FLP/KLP network-level plans
//!   for the section IV.A ablation, with their per-thread partial
//!   buffers preallocated in the arena.

use std::ops::Range;
use std::sync::Arc;

use crate::engine::conv;
use crate::engine::mode::{self, ArithMode};
use crate::engine::network::{EngineParams, ExecConfig, ModeAssignment};
use crate::engine::ops;
use crate::engine::parallel::{self, Parallelism};
use crate::engine::tensor;
use crate::layout;
use crate::metrics::AllocCounter;
use crate::model::{shapes, Layer, LayerOp, Network};
use crate::util::ceil_div;
use crate::util::error::{Error, Result};

/// Which executor family a plan lowers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Family {
    /// Map-major activations, OLP-threaded vectorised convolutions.
    MapMajor,
    /// Row-major activations with the named conv implementation.
    Nchw(NchwConv),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NchwConv {
    Scalar,
    Flp,
    Klp,
}

/// Static shape of one activation register.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SlotShape {
    /// Map-major `(ceil(c/u), h, w, u)` data; `u = 1` is row-major NCHW.
    Maps { c: usize, h: usize, w: usize, u: usize },
    Flat { len: usize },
}

impl SlotShape {
    fn len(&self) -> usize {
        match *self {
            SlotShape::Maps { c, h, w, u } => ceil_div(c, u) * h * w * u,
            SlotShape::Flat { len } => len,
        }
    }
}

fn maps_of(s: SlotShape) -> (usize, usize, usize, usize) {
    match s {
        SlotShape::Maps { c, h, w, u } => (c, h, w, u),
        SlotShape::Flat { .. } => unreachable!("plan step expected a maps register"),
    }
}

fn flat_of(s: SlotShape) -> usize {
    match s {
        SlotShape::Flat { len } => len,
        SlotShape::Maps { .. } => unreachable!("plan step expected a flat register"),
    }
}

/// One lowered instruction. Weights are baked (mode-cast at compile
/// time) and shared via `Arc` so cloning a plan (one arena per serve
/// batch capacity) does not duplicate parameters.
#[derive(Clone)]
enum Step {
    /// Prologue: conventional NCHW request data into the input register.
    Input { dst: usize },
    ConvMm {
        src: usize,
        dst: usize,
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
        mode: ArithMode,
    },
    ConvNchw {
        src: usize,
        dst: usize,
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        k: usize,
        s: usize,
        p: usize,
        relu: bool,
        mode: ArithMode,
        policy: NchwConv,
    },
    PoolMm { src: usize, dst: usize, k: usize, s: usize, p: usize, is_max: bool },
    PoolNchw { src: usize, dst: usize, k: usize, s: usize, p: usize, is_max: bool },
    Lrn { src: usize, dst: usize, size: usize, alpha: f32, beta: f32 },
    Gap { src: usize, dst: usize },
    Copy { src: usize, dst: usize },
    Concat { srcs: Vec<usize>, dst: usize },
    Dense {
        src: usize,
        dst: usize,
        w: Arc<Vec<f32>>,
        b: Arc<Vec<f32>>,
        relu: bool,
        mode: ArithMode,
    },
    Softmax { src: usize, dst: usize },
}

/// The preallocated buffer arena: activation registers, one shared
/// pad/cast scratch sized to the largest conv/pool working set, and
/// per-thread FLP/KLP reduction buffers. Compile-time sized, reused
/// across every inference.
#[derive(Clone)]
struct Arena {
    bufs: Vec<Vec<f32>>,
    scratch: Vec<f32>,
    reduce: Vec<Vec<f32>>,
}

impl Arena {
    fn bytes(&self) -> usize {
        let elems: usize = self.bufs.iter().map(|b| b.len()).sum::<usize>()
            + self.scratch.len()
            + self.reduce.iter().map(|b| b.len()).sum::<usize>();
        4 * elems
    }
}

/// A compiled, immediately executable inference program for the native
/// engine. Holds baked weights and a resident buffer arena; `run` is
/// allocation-free apart from the returned logits vector.
#[derive(Clone)]
pub struct ExecutionPlan {
    u: usize,
    threads: usize,
    input_shape: (usize, usize, usize),
    slots: Vec<SlotShape>,
    steps: Vec<Step>,
    out_slot: usize,
    arena: Arena,
    baked_param_bytes: usize,
    runs: u64,
    alloc: AllocCounter,
}

impl std::fmt::Debug for ExecutionPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecutionPlan")
            .field("u", &self.u)
            .field("threads", &self.threads)
            .field("steps", &self.steps.len())
            .field("registers", &self.slots.len())
            .field("arena_bytes", &self.arena.bytes())
            .field("baked_param_bytes", &self.baked_param_bytes)
            .field("runs", &self.runs)
            .finish()
    }
}

impl ExecutionPlan {
    /// Compile the map-major OLP program — the synthesized software.
    pub fn compile(
        net: &Network,
        params: &EngineParams,
        modes: &ModeAssignment,
        cfg: ExecConfig,
    ) -> Result<ExecutionPlan> {
        Self::compile_with(net, params, modes, cfg, Family::MapMajor)
    }

    /// Compile the single-threaded scalar row-major baseline (Table I's
    /// "single-threaded Java" program, functionally).
    pub fn compile_baseline(net: &Network, params: &EngineParams) -> Result<ExecutionPlan> {
        Self::compile_with(
            net,
            params,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 1 },
            Family::Nchw(NchwConv::Scalar),
        )
    }

    /// Compile under an explicit thread-workload-allocation policy:
    /// OLP lowers map-major (same as [`ExecutionPlan::compile`]),
    /// FLP/KLP lower row-major with per-thread reduction buffers in the
    /// arena — the section IV.A ablation executors.
    pub fn compile_policy(
        net: &Network,
        params: &EngineParams,
        modes: &ModeAssignment,
        cfg: ExecConfig,
        policy: Parallelism,
    ) -> Result<ExecutionPlan> {
        let family = match policy {
            Parallelism::Olp => Family::MapMajor,
            Parallelism::Flp => Family::Nchw(NchwConv::Flp),
            Parallelism::Klp => Family::Nchw(NchwConv::Klp),
        };
        Self::compile_with(net, params, modes, cfg, family)
    }

    fn compile_with(
        net: &Network,
        params: &EngineParams,
        modes: &ModeAssignment,
        cfg: ExecConfig,
        family: Family,
    ) -> Result<ExecutionPlan> {
        // Shape inference once, up front: every undersized window or
        // malformed topology becomes Error::Shape here instead of an
        // arithmetic underflow on the request path.
        shapes::infer(net)?;
        let (c, h, w) = net.input.as_maps()?;
        let u = match family {
            Family::MapMajor => params.u,
            Family::Nchw(_) => 1,
        };
        let threads = cfg.threads.max(1);
        let mut lw = Lowerer {
            params,
            modes,
            family,
            slots: Vec::new(),
            steps: Vec::new(),
            scratch_len: 0,
            reduce_len: 0,
            baked_param_bytes: 0,
        };
        let in_slot = lw.slot(SlotShape::Maps { c, h, w, u });
        lw.steps.push(Step::Input { dst: in_slot });
        let out_slot = lw.lower(&net.layers, in_slot)?;

        let bufs: Vec<Vec<f32>> = lw.slots.iter().map(|s| vec![0.0f32; s.len()]).collect();
        let scratch = vec![0.0f32; lw.scratch_len];
        let n_reduce = if lw.reduce_len > 0 { threads } else { 0 };
        let reduce: Vec<Vec<f32>> =
            (0..n_reduce).map(|_| vec![0.0f32; lw.reduce_len]).collect();

        Ok(ExecutionPlan {
            u,
            threads,
            input_shape: (c, h, w),
            slots: lw.slots,
            steps: lw.steps,
            out_slot,
            arena: Arena { bufs, scratch, reduce },
            baked_param_bytes: lw.baked_param_bytes,
            runs: 0,
            alloc: AllocCounter::new(),
        })
    }

    /// Execute one inference. `input` is conventional `(C, H, W)` data;
    /// the map-major transform of the request is the plan's prologue
    /// (the only dynamic reorder in the pipeline). Steady-state
    /// allocation-free apart from the returned logits vector.
    pub fn run(&mut self, input: &[f32]) -> Result<Vec<f32>> {
        let (c, h, w) = self.input_shape;
        if input.len() != c * h * w {
            return Err(Error::Shape(format!(
                "input len {} vs expected {c}x{h}x{w}",
                input.len()
            )));
        }
        let slots = &self.slots;
        let threads = self.threads;
        for step in &self.steps {
            exec_step(step, slots, &mut self.arena, input, threads);
        }
        self.runs += 1;
        let out = match slots[self.out_slot] {
            SlotShape::Flat { len } => self.arena.bufs[self.out_slot][..len].to_vec(),
            SlotShape::Maps { c, h, w, u } => {
                layout::mapmajor_to_nchw(&self.arena.bufs[self.out_slot], c, h, w, u)
            }
        };
        self.alloc.record(4 * out.len());
        Ok(out)
    }

    /// Vector width the plan was compiled for (1 for row-major plans).
    pub fn u(&self) -> usize {
        self.u
    }

    /// Pool-chunk parallelism the plan executes with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Expected per-image input element count.
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Lowered step count (prologue included).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Resident arena bytes (activation registers + scratch + reduction
    /// buffers) — what the legacy executor re-allocated every inference.
    pub fn arena_bytes(&self) -> usize {
        self.arena.bytes()
    }

    /// Bytes of baked (mode-cast) parameters the plan holds — what the
    /// legacy executor re-cast every inference for inexact layers.
    pub fn baked_param_bytes(&self) -> usize {
        self.baked_param_bytes
    }

    /// Inferences executed so far.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Request-path allocation meter (logits vectors only, by design).
    pub fn alloc(&self) -> &AllocCounter {
        &self.alloc
    }

    /// Mean request-path bytes allocated per inference.
    pub fn alloc_bytes_per_run(&self) -> f64 {
        self.alloc.per_inference(self.runs)
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

struct Lowerer<'a> {
    params: &'a EngineParams,
    modes: &'a ModeAssignment,
    family: Family,
    slots: Vec<SlotShape>,
    steps: Vec<Step>,
    scratch_len: usize,
    reduce_len: usize,
    baked_param_bytes: usize,
}

impl Lowerer<'_> {
    fn slot(&mut self, shape: SlotShape) -> usize {
        self.slots.push(shape);
        self.slots.len() - 1
    }

    fn bake(&mut self, w: &[f32], mode: ArithMode) -> Arc<Vec<f32>> {
        self.baked_param_bytes += 4 * w.len();
        Arc::new(conv::cast_weights(w, mode))
    }

    fn bias(&mut self, b: &[f32]) -> Arc<Vec<f32>> {
        self.baked_param_bytes += 4 * b.len();
        Arc::new(b.to_vec())
    }

    fn lower(&mut self, layers: &[Layer], mut cur: usize) -> Result<usize> {
        for layer in layers {
            cur = self.lower_layer(layer, cur)?;
        }
        Ok(cur)
    }

    fn lower_layer(&mut self, layer: &Layer, cur: usize) -> Result<usize> {
        let named = |e: Error| Error::Shape(format!("layer {}: {e}", layer.name));
        match &layer.op {
            LayerOp::Conv { m, k, s, p, relu } => {
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                let ho = shapes::conv_out(h, *k, *s, *p).map_err(named)?;
                let wo = shapes::conv_out(w, *k, *s, *p).map_err(named)?;
                let lp = self.params.layer_params(&layer.name)?;
                let mode = self.modes.mode_of(&layer.name);
                let dst = self.slot(SlotShape::Maps { c: *m, h: ho, w: wo, u });
                match self.family {
                    Family::MapMajor => {
                        let (mb, cb) = (ceil_div(*m, u), ceil_div(c, u));
                        if lp.w_mm.len() != mb * u * cb * k * k * u
                            || lp.b_mm.len() != mb * u
                        {
                            return Err(Error::Shape(format!(
                                "layer {}: map-major params {}x{} vs expected {}x{}",
                                layer.name,
                                lp.w_mm.len(),
                                lp.b_mm.len(),
                                mb * u * cb * k * k * u,
                                mb * u
                            )));
                        }
                        if *p > 0 || mode != ArithMode::Precise {
                            let padded = cb * (h + 2 * p) * (w + 2 * p) * u;
                            self.scratch_len = self.scratch_len.max(padded);
                        }
                        let (wgt, b) = (self.bake(&lp.w_mm, mode), self.bias(&lp.b_mm));
                        self.steps.push(Step::ConvMm {
                            src: cur,
                            dst,
                            w: wgt,
                            b,
                            k: *k,
                            s: *s,
                            p: *p,
                            relu: *relu,
                            mode,
                        });
                    }
                    Family::Nchw(policy) => {
                        if lp.w_conv.len() != m * c * k * k || lp.b_conv.len() != *m {
                            return Err(Error::Shape(format!(
                                "layer {}: params {}x{} vs expected {}x{}",
                                layer.name,
                                lp.w_conv.len(),
                                lp.b_conv.len(),
                                m * c * k * k,
                                m
                            )));
                        }
                        if mode != ArithMode::Precise {
                            self.scratch_len = self.scratch_len.max(c * h * w);
                        }
                        if policy != NchwConv::Scalar {
                            self.reduce_len = self.reduce_len.max(m * ho * wo);
                        }
                        let (wgt, b) = (self.bake(&lp.w_conv, mode), self.bias(&lp.b_conv));
                        self.steps.push(Step::ConvNchw {
                            src: cur,
                            dst,
                            w: wgt,
                            b,
                            k: *k,
                            s: *s,
                            p: *p,
                            relu: *relu,
                            mode,
                            policy,
                        });
                    }
                }
                Ok(dst)
            }
            LayerOp::MaxPool { k, s, p } | LayerOp::AvgPool { k, s, p } => {
                let is_max = matches!(layer.op, LayerOp::MaxPool { .. });
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                let ho = shapes::conv_out(h, *k, *s, *p).map_err(named)?;
                let wo = shapes::conv_out(w, *k, *s, *p).map_err(named)?;
                let dst = self.slot(SlotShape::Maps { c, h: ho, w: wo, u });
                match self.family {
                    Family::MapMajor => {
                        if *p > 0 {
                            let padded = ceil_div(c, u) * (h + 2 * p) * (w + 2 * p) * u;
                            self.scratch_len = self.scratch_len.max(padded);
                        }
                        self.steps.push(Step::PoolMm {
                            src: cur,
                            dst,
                            k: *k,
                            s: *s,
                            p: *p,
                            is_max,
                        });
                    }
                    Family::Nchw(_) => {
                        self.steps.push(Step::PoolNchw {
                            src: cur,
                            dst,
                            k: *k,
                            s: *s,
                            p: *p,
                            is_max,
                        });
                    }
                }
                Ok(dst)
            }
            LayerOp::Lrn { size, alpha, beta } => {
                let (c, h, w, u) = self.require_maps(cur, layer)?;
                let dst = self.slot(SlotShape::Maps { c, h, w, u });
                self.steps.push(Step::Lrn {
                    src: cur,
                    dst,
                    size: *size,
                    alpha: *alpha,
                    beta: *beta,
                });
                Ok(dst)
            }
            LayerOp::Fork { branches } => {
                let (_, _, _, u) = self.require_maps(cur, layer)?;
                let mut outs = Vec::with_capacity(branches.len());
                for br in branches {
                    outs.push(self.lower(br, cur)?);
                }
                let mut total_c = 0;
                let mut hw: Option<(usize, usize)> = None;
                for &o in &outs {
                    let (bc, bh, bw, _) = match self.slots[o] {
                        SlotShape::Maps { c, h, w, u } => (c, h, w, u),
                        SlotShape::Flat { .. } => {
                            return Err(Error::Invalid(format!(
                                "fork {}: branch produced flat activation",
                                layer.name
                            )))
                        }
                    };
                    if let Some((ph, pw)) = hw {
                        if (bh, bw) != (ph, pw) {
                            return Err(Error::Shape(format!(
                                "fork {}: branch spatial mismatch {bh}x{bw} vs {ph}x{pw}",
                                layer.name
                            )));
                        }
                    } else {
                        hw = Some((bh, bw));
                    }
                    if self.family == Family::MapMajor && bc % u != 0 {
                        return Err(Error::Invalid(format!(
                            "fork {}: branch width {bc} not aligned to u={u}",
                            layer.name
                        )));
                    }
                    total_c += bc;
                }
                let (h, w) = hw.ok_or_else(|| {
                    Error::Invalid(format!("fork {}: no branches", layer.name))
                })?;
                let dst = self.slot(SlotShape::Maps { c: total_c, h, w, u });
                self.steps.push(Step::Concat { srcs: outs, dst });
                Ok(dst)
            }
            LayerOp::Flatten => {
                let len = self.slots[cur].len();
                let dst = self.slot(SlotShape::Flat { len });
                self.steps.push(Step::Copy { src: cur, dst });
                Ok(dst)
            }
            LayerOp::Gap => {
                let (c, ..) = self.require_maps(cur, layer)?;
                let dst = self.slot(SlotShape::Flat { len: c });
                self.steps.push(Step::Gap { src: cur, dst });
                Ok(dst)
            }
            LayerOp::Dense { o, relu } => {
                let len = match self.slots[cur] {
                    SlotShape::Flat { len } => len,
                    SlotShape::Maps { .. } => {
                        return Err(Error::Invalid(format!(
                            "layer {}: dense/softmax requires flatten or gap first",
                            layer.name
                        )))
                    }
                };
                let lp = self.params.layer_params(&layer.name)?;
                let mode = self.modes.mode_of(&layer.name);
                let (w_src, b_src) = match self.family {
                    Family::MapMajor => (&lp.w_mm, &lp.b_mm),
                    Family::Nchw(_) => (&lp.w_conv, &lp.b_conv),
                };
                if w_src.len() != o * len || b_src.len() != *o {
                    return Err(Error::Shape(format!(
                        "layer {}: dense params {}x{} vs expected {}x{}",
                        layer.name,
                        w_src.len(),
                        b_src.len(),
                        o * len,
                        o
                    )));
                }
                if mode != ArithMode::Precise {
                    self.scratch_len = self.scratch_len.max(len);
                }
                let (wgt, b) = (self.bake(w_src, mode), self.bias(b_src));
                let dst = self.slot(SlotShape::Flat { len: *o });
                self.steps.push(Step::Dense { src: cur, dst, w: wgt, b, relu: *relu, mode });
                Ok(dst)
            }
            LayerOp::Softmax => {
                let len = match self.slots[cur] {
                    SlotShape::Flat { len } => len,
                    SlotShape::Maps { .. } => {
                        return Err(Error::Invalid(format!(
                            "layer {}: dense/softmax requires flatten or gap first",
                            layer.name
                        )))
                    }
                };
                let dst = self.slot(SlotShape::Flat { len });
                self.steps.push(Step::Softmax { src: cur, dst });
                Ok(dst)
            }
        }
    }

    fn require_maps(&self, slot: usize, layer: &Layer) -> Result<(usize, usize, usize, usize)> {
        match self.slots[slot] {
            SlotShape::Maps { c, h, w, u } => Ok((c, h, w, u)),
            SlotShape::Flat { .. } => Err(Error::Invalid(format!(
                "layer {}: op {:?} cannot consume a flat activation",
                layer.name, layer.op
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// Execution
// ---------------------------------------------------------------------------

/// Disjoint (read, write) access into the register file.
fn pair_mut(bufs: &mut [Vec<f32>], read: usize, write: usize) -> (&[f32], &mut [f32]) {
    assert_ne!(read, write, "plan step reads and writes the same register");
    if read < write {
        let (lo, hi) = bufs.split_at_mut(write);
        (lo[read].as_slice(), hi[0].as_mut_slice())
    } else {
        let (lo, hi) = bufs.split_at_mut(read);
        (hi[0].as_slice(), lo[write].as_mut_slice())
    }
}

fn exec_step(step: &Step, slots: &[SlotShape], arena: &mut Arena, input: &[f32], threads: usize) {
    match step {
        Step::Input { dst } => {
            let (c, h, w, u) = maps_of(slots[*dst]);
            layout::nchw_to_mapmajor_into(input, c, h, w, u, &mut arena.bufs[*dst]);
        }
        Step::ConvMm { src, dst, w, b, k, s, p, relu, mode } => {
            let (cin, h, wd, u) = maps_of(slots[*src]);
            let (m, ho, wo, _) = maps_of(slots[*dst]);
            let (cb, mb) = (ceil_div(cin, u), ceil_div(m, u));
            let (hp, wp) = (h + 2 * p, wd + 2 * p);
            if *p > 0 || *mode != ArithMode::Precise {
                let plen = cb * hp * wp * u;
                tensor::pad_cast_into(
                    &arena.bufs[*src],
                    cb,
                    h,
                    wd,
                    u,
                    *p,
                    0.0,
                    *mode,
                    &mut arena.scratch[..plen],
                );
                conv::conv_mm_core(
                    &arena.scratch[..plen],
                    hp,
                    wp,
                    cb,
                    u,
                    w,
                    b,
                    &mut arena.bufs[*dst],
                    mb,
                    *k,
                    *s,
                    ho,
                    wo,
                    *relu,
                    threads,
                );
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                conv::conv_mm_core(x, hp, wp, cb, u, w, b, out, mb, *k, *s, ho, wo, *relu, threads);
            }
        }
        Step::ConvNchw { src, dst, w, b, k, s, p, relu, mode, policy } => {
            let (cin, h, wd, _) = maps_of(slots[*src]);
            let (m, ho, wo, _) = maps_of(slots[*dst]);
            let x_len = cin * h * wd;
            if *mode != ArithMode::Precise {
                mode::cast_slice_into(&arena.bufs[*src], *mode, &mut arena.scratch[..x_len]);
            }
            match policy {
                NchwConv::Scalar => {
                    if *mode != ArithMode::Precise {
                        let x = &arena.scratch[..x_len];
                        conv::conv_nchw_scalar_into(
                            x, cin, h, wd, w, b, m, *k, *s, *p, *relu, ho, wo,
                            &mut arena.bufs[*dst],
                        );
                    } else {
                        let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                        conv::conv_nchw_scalar_into(
                            x, cin, h, wd, w, b, m, *k, *s, *p, *relu, ho, wo, out,
                        );
                    }
                }
                NchwConv::Flp | NchwConv::Klp => {
                    let is_flp = matches!(policy, NchwConv::Flp);
                    let items = if is_flp { m * cin } else { cin * k };
                    let buf_len = m * ho * wo;
                    {
                        let x: &[f32] = if *mode != ArithMode::Precise {
                            &arena.scratch[..x_len]
                        } else {
                            &arena.bufs[*src]
                        };
                        let wgt: &[f32] = w;
                        let (kk, ss, pp) = (*k, *s, *p);
                        parallel::parallel_reduce_with(
                            items,
                            threads,
                            buf_len,
                            &mut arena.reduce,
                            &|_i, range: Range<usize>, buf: &mut [f32]| {
                                if is_flp {
                                    conv::flp_accumulate(
                                        x, cin, h, wd, wgt, kk, ss, pp, ho, wo, range, buf,
                                    );
                                } else {
                                    conv::klp_accumulate(
                                        x, cin, h, wd, wgt, m, kk, ss, pp, ho, wo, range, buf,
                                    );
                                }
                            },
                        );
                    }
                    let out = &mut arena.bufs[*dst];
                    out[..].copy_from_slice(&arena.reduce[0][..buf_len]);
                    conv::finish_bias_relu(out, b, m, ho * wo, *relu);
                }
            }
        }
        Step::PoolMm { src, dst, k, s, p, is_max } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let (_, ho, wo, _) = maps_of(slots[*dst]);
            let cb = ceil_div(c, u);
            let fill = if *is_max { f32::NEG_INFINITY } else { 0.0 };
            if *p > 0 {
                let (hp, wp) = (h + 2 * p, wd + 2 * p);
                let plen = cb * hp * wp * u;
                tensor::pad_spatial_into(
                    &arena.bufs[*src],
                    cb,
                    h,
                    wd,
                    u,
                    *p,
                    fill,
                    &mut arena.scratch[..plen],
                );
                ops::pool_mm_core(
                    &arena.scratch[..plen],
                    hp,
                    wp,
                    u,
                    cb,
                    &mut arena.bufs[*dst],
                    ho,
                    wo,
                    *k,
                    *s,
                    *is_max,
                );
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                ops::pool_mm_core(x, h, wd, u, cb, out, ho, wo, *k, *s, *is_max);
            }
        }
        Step::PoolNchw { src, dst, k, s, p, is_max } => {
            let (c, h, wd, _) = maps_of(slots[*src]);
            let (_, ho, wo, _) = maps_of(slots[*dst]);
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            ops::pool_nchw_into(x, c, h, wd, *k, *s, *p, *is_max, ho, wo, out);
        }
        Step::Lrn { src, dst, size, alpha, beta } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            ops::lrn_mm_into(x, c, h, wd, u, *size, *alpha, *beta, out);
        }
        Step::Gap { src, dst } => {
            let (c, h, wd, u) = maps_of(slots[*src]);
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            ops::gap_mm_into(x, c, h, wd, u, out);
        }
        Step::Copy { src, dst } => {
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            out.copy_from_slice(x);
        }
        Step::Concat { srcs, dst } => {
            let mut off = 0;
            for &sidx in srcs {
                let part_len = slots[sidx].len();
                let (x, out) = pair_mut(&mut arena.bufs, sidx, *dst);
                out[off..off + part_len].copy_from_slice(x);
                off += part_len;
            }
        }
        Step::Dense { src, dst, w, b, relu, mode } => {
            let o = flat_of(slots[*dst]);
            let len = flat_of(slots[*src]);
            if *mode != ArithMode::Precise {
                mode::cast_slice_into(&arena.bufs[*src], *mode, &mut arena.scratch[..len]);
                let x = &arena.scratch[..len];
                ops::dense_into(x, w, b, o, *relu, &mut arena.bufs[*dst]);
            } else {
                let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
                ops::dense_into(x, w, b, o, *relu, out);
            }
        }
        Step::Softmax { src, dst } => {
            let (x, out) = pair_mut(&mut arena.bufs, *src, *dst);
            ops::softmax_into(x, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_cappnet;
    use crate::model::zoo;
    use crate::util::rng::Rng;

    fn rand_input(net: &Network, seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(net.input.elements())
    }

    #[test]
    fn plan_compiles_and_runs_tinynet() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 42, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Precise);
        let mut plan =
            ExecutionPlan::compile(&net, &params, &modes, ExecConfig { threads: 2 }).unwrap();
        let input = rand_input(&net, 7);
        let a = plan.run(&input).unwrap();
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|v| v.is_finite()));
        // Re-running the same plan with the same input is bitwise stable
        // (the arena leaks no state between inferences).
        let b = plan.run(&input).unwrap();
        assert_eq!(a, b);
        assert_eq!(plan.runs(), 2);
    }

    #[test]
    fn plan_interleaved_inputs_do_not_contaminate() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 1, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let cfg = ExecConfig { threads: 2 };
        let mut plan = ExecutionPlan::compile(&net, &params, &modes, cfg).unwrap();
        let x1 = rand_input(&net, 2);
        let x2 = rand_input(&net, 3);
        let a1 = plan.run(&x1).unwrap();
        let a2 = plan.run(&x2).unwrap();
        let a1_again = plan.run(&x1).unwrap();
        assert_ne!(a1, a2);
        assert_eq!(a1, a1_again, "arena state leaked between inferences");
    }

    #[test]
    fn plan_alloc_is_logits_only() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Imprecise);
        let mut plan =
            ExecutionPlan::compile(&net, &params, &modes, ExecConfig { threads: 1 }).unwrap();
        let input = rand_input(&net, 9);
        for _ in 0..4 {
            plan.run(&input).unwrap();
        }
        // 8 logits * 4 bytes per inference, nothing else.
        assert_eq!(plan.alloc_bytes_per_run(), 32.0);
        assert_eq!(plan.alloc().allocs(), 4);
        assert!(plan.arena_bytes() > 0);
        assert!(plan.baked_param_bytes() > 0);
    }

    #[test]
    fn plan_clone_shares_weights_not_arena() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Precise);
        let plan =
            ExecutionPlan::compile(&net, &params, &modes, ExecConfig { threads: 1 }).unwrap();
        let mut a = plan.clone();
        let mut b = plan;
        let input = rand_input(&net, 11);
        assert_eq!(a.run(&input).unwrap(), b.run(&input).unwrap());
    }

    #[test]
    fn oversized_window_is_shape_error_not_panic() {
        let net = parse_cappnet(
            "net bad\ninput 3 4 4\nclasses 4\nconv c1 m=4 k=7 s=1 p=0\ngap\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 0, 4);
        // Shape inference fails before any parameter work.
        assert!(params.is_err() || {
            let p = params.unwrap();
            matches!(
                ExecutionPlan::compile(
                    &net,
                    &p,
                    &ModeAssignment::uniform(ArithMode::Precise),
                    ExecConfig::default(),
                ),
                Err(Error::Shape(_))
            )
        });
    }

    #[test]
    fn bad_input_len_rejected() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 0, 4).unwrap();
        let modes = ModeAssignment::uniform(ArithMode::Precise);
        let mut plan =
            ExecutionPlan::compile(&net, &params, &modes, ExecConfig::default()).unwrap();
        assert!(matches!(plan.run(&[0.0; 3]), Err(Error::Shape(_))));
    }

    #[test]
    fn baseline_plan_matches_mapmajor_plan() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 21, 4).unwrap();
        let mut base = ExecutionPlan::compile_baseline(&net, &params).unwrap();
        let mut opt = ExecutionPlan::compile(
            &net,
            &params,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 2 },
        )
        .unwrap();
        let input = rand_input(&net, 22);
        let a = base.run(&input).unwrap();
        let b = opt.run(&input).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-3 * (1.0 + x.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn flp_klp_policy_plans_agree_with_baseline() {
        let net = parse_cappnet(
            "net mini\ninput 3 12 12\nclasses 8\n\
             conv c1 m=8 k=3 s=1 p=1\nmaxpool k=2 s=2\n\
             conv c2 m=8 k=3 s=1 p=0\ngap\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 8, 4).unwrap();
        let mut base = ExecutionPlan::compile_baseline(&net, &params).unwrap();
        let input = rand_input(&net, 13);
        let want = base.run(&input).unwrap();
        for policy in [Parallelism::Flp, Parallelism::Klp] {
            for threads in [1, 3] {
                let mut plan = ExecutionPlan::compile_policy(
                    &net,
                    &params,
                    &ModeAssignment::uniform(ArithMode::Precise),
                    ExecConfig { threads },
                    policy,
                )
                .unwrap();
                assert!(plan.arena_bytes() > 0);
                let got = plan.run(&input).unwrap();
                for (x, y) in want.iter().zip(&got) {
                    assert!(
                        (x - y).abs() < 1e-4 * (1.0 + x.abs()),
                        "{policy}/{threads}: {x} vs {y}"
                    );
                }
            }
        }
    }
}
