//! Full-network execution on the native engine.
//!
//! The canonical executors are thin wrappers over a compiled
//! [`crate::engine::plan::ExecutionPlan`]:
//!
//! * [`run_baseline`] — single-threaded scalar row-major: the
//!   "single-threaded Java" baseline of Table I (functionally, not in
//!   absolute speed — the interpreter factor lives in the SoC model).
//! * [`run_mapmajor`] — the Cappuccino-synthesized program: map-major
//!   end-to-end, OLP-threaded vectorised convs, per-layer arithmetic
//!   modes from a [`ModeAssignment`].
//!
//! Both compile the plan per call, so steady-state callers should hold
//! a compiled plan instead (the serve backend and the inexact analyzer
//! do). The pre-plan interpreters are kept as
//! [`run_mapmajor_legacy`] / [`run_baseline_legacy`]: they re-decide
//! everything per inference — weight casts, output/padding buffers —
//! and exist as the parity oracle and the `engine_hotpath`
//! legacy-vs-plan comparison.
//!
//! Parameter handling mirrors the paper's compile-time flow:
//! [`EngineParams::compile`] takes *conventional* weights (the `.capp`
//! model file) and reorders them once into map-major form.

use std::collections::HashMap;

use crate::config::modelfile::ModelFile;
use crate::engine::conv::{cast_weights, conv_mm, conv_nchw_scalar};
use crate::engine::mode::ArithMode;
use crate::engine::ops;
use crate::engine::plan::PlanBuilder;
use crate::engine::tensor::MapTensor;
use crate::layout;
use crate::model::{shapes, Layer, LayerOp, Network, TensorShape};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Per-layer arithmetic mode assignment (section IV.C). Layers not
/// present use the default mode.
#[derive(Debug, Clone)]
pub struct ModeAssignment {
    pub default: ArithMode,
    pub per_layer: HashMap<String, ArithMode>,
}

impl ModeAssignment {
    pub fn uniform(mode: ArithMode) -> Self {
        ModeAssignment { default: mode, per_layer: HashMap::new() }
    }

    pub fn with(mut self, layer: impl Into<String>, mode: ArithMode) -> Self {
        self.per_layer.insert(layer.into(), mode);
        self
    }

    pub fn mode_of(&self, layer: &str) -> ArithMode {
        self.per_layer.get(layer).copied().unwrap_or(self.default)
    }

    /// Count of layers (out of `names`) that run in an inexact mode.
    pub fn inexact_count(&self, names: &[String]) -> usize {
        names
            .iter()
            .filter(|n| self.mode_of(n) != ArithMode::Precise)
            .count()
    }
}

/// One layer's parameters in both layouts.
#[derive(Debug, Clone)]
pub(crate) struct LayerParams {
    /// Conventional layout: conv `(M,C,K,K)` flat / dense `(O,I)` flat.
    pub(crate) w_conv: Vec<f32>,
    pub(crate) b_conv: Vec<f32>,
    /// Map-major layout (convs: `(Mb,u,Cb,K,K,u)`; first-FC: permuted).
    pub(crate) w_mm: Vec<f32>,
    pub(crate) b_mm: Vec<f32>,
}

/// Compiled parameters for a network.
#[derive(Debug, Clone)]
pub struct EngineParams {
    pub u: usize,
    layers: HashMap<String, LayerParams>,
}

impl EngineParams {
    /// Compile conventional weights (model file) into both layouts —
    /// the paper's compile-time parameter reordering (section III).
    pub fn compile(net: &Network, mf: &ModelFile, u: usize) -> Result<EngineParams> {
        let info = shapes::infer(net)?;
        let mut layers = HashMap::new();
        for pl in &info.param_layers {
            let (w, b) = mf.layer_params(&pl.name)?;
            if w.data.len() != pl.weight_elems || b.data.len() != pl.bias_elems {
                return Err(Error::Shape(format!(
                    "layer {}: model file {}x{} vs expected {}x{}",
                    pl.name,
                    w.data.len(),
                    b.data.len(),
                    pl.weight_elems,
                    pl.bias_elems
                )));
            }
            layers.insert(pl.name.clone(), build_layer_params(pl, &w.data, &b.data, u));
        }
        Ok(EngineParams { u, layers })
    }

    /// Random He-normal parameters (for nets without a trained model
    /// file — weight *values* do not affect latency benchmarks).
    pub fn random(net: &Network, seed: u64, u: usize) -> Result<EngineParams> {
        let info = shapes::infer(net)?;
        let mut rng = Rng::new(seed);
        let mut layers = HashMap::new();
        for pl in &info.param_layers {
            let fan_in = match pl.input {
                TensorShape::Maps { c, .. } => c * pl.k * pl.k,
                TensorShape::Flat { len } => len,
            };
            let mut lrng = rng.fork(&pl.name);
            let w = lrng.he_normal_vec(pl.weight_elems, fan_in.max(1));
            let b = vec![0.0f32; pl.bias_elems];
            layers.insert(pl.name.clone(), build_layer_params(pl, &w, &b, u));
        }
        Ok(EngineParams { u, layers })
    }

    pub(crate) fn layer_params(&self, name: &str) -> Result<&LayerParams> {
        self.layers
            .get(name)
            .ok_or_else(|| Error::Invalid(format!("no params for layer {name:?}")))
    }

    fn get(&self, name: &str) -> Result<&LayerParams> {
        self.layer_params(name)
    }
}

fn build_layer_params(pl: &shapes::ParamLayer, w: &[f32], b: &[f32], u: usize) -> LayerParams {
    match pl.input {
        TensorShape::Maps { c, .. } => {
            let m = pl.bias_elems;
            LayerParams {
                w_mm: layout::weights_to_mapmajor(w, m, c, pl.k, u),
                b_mm: layout::bias_to_mapmajor(b, u),
                w_conv: w.to_vec(),
                b_conv: b.to_vec(),
            }
        }
        TensorShape::Flat { .. } => {
            let o = pl.bias_elems;
            // The first dense after a flatten consumes the map-major
            // flatten order: permute its weight columns at compile time.
            let w_mm = if let Some((c, h, wd)) = pl.flatten_src {
                layout::fc_weights_for_mapmajor(w, o, c, h, wd, u)
            } else {
                w.to_vec()
            };
            LayerParams {
                w_mm,
                b_mm: b.to_vec(),
                w_conv: w.to_vec(),
                b_conv: b.to_vec(),
            }
        }
    }
}

/// Execution configuration for the optimised path.
///
/// `threads` is the number of pool **chunks** each parallel region is
/// split into, not a pool size: the process-wide worker pool
/// ([`crate::engine::parallel::global_pool`]) is shaped once, at first
/// use, by the machine's topology, and a plan compiled with
/// `threads = n` simply submits at most `n` chunks per region. Values
/// above the pool's worker count queue extra chunks rather than
/// spawning threads.
///
/// `affinity` turns on cost-weighted cluster placement for packed conv
/// layers (see [`crate::engine::PlanBuilder::affinity`]): chunks are
/// apportioned across big/LITTLE (or per-socket) clusters by throughput
/// weight and routed to each cluster's own work deque. Off by default;
/// bitwise-invisible either way.
#[derive(Debug, Clone, Copy)]
pub struct ExecConfig {
    pub threads: usize,
    pub affinity: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { threads: 1, affinity: false }
    }
}

/// Optimised executor: map-major, OLP-threaded, per-layer modes.
/// Builds an execution plan (via [`PlanBuilder`]) and runs it once — a
/// convenience for one-shot callers; steady-state callers should build
/// once and call [`crate::engine::ExecutionPlan::run_batch`] per
/// drained batch.
pub fn run_mapmajor(
    net: &Network,
    params: &EngineParams,
    input: &[f32],
    modes: &ModeAssignment,
    cfg: ExecConfig,
) -> Result<Vec<f32>> {
    PlanBuilder::new(net, params)
        .modes(modes)
        .config(cfg)
        .build()?
        .run(input)
}

/// Baseline executor: single-threaded scalar row-major, precise
/// arithmetic — the Table I "Baseline" program, functionally. Plan-
/// compiled per call, like [`run_mapmajor`].
pub fn run_baseline(net: &Network, params: &EngineParams, input: &[f32]) -> Result<Vec<f32>> {
    PlanBuilder::new(net, params)
        .baseline()
        .build()?
        .run(input)
}

// ---------------------------------------------------------------------------
// Legacy interpreters (pre-plan): parity oracle + bench reference
// ---------------------------------------------------------------------------

/// The pre-plan map-major interpreter: walks the layer tree per call,
/// allocates every activation, and re-casts weights for every inexact
/// layer on every inference. Kept as the parity oracle for
/// [`crate::engine::ExecutionPlan`] and the `engine_hotpath`
/// legacy-vs-plan bench.
pub fn run_mapmajor_legacy(
    net: &Network,
    params: &EngineParams,
    input: &[f32],
    modes: &ModeAssignment,
    cfg: ExecConfig,
) -> Result<Vec<f32>> {
    let (c, h, w) = net.input.as_maps()?;
    if input.len() != c * h * w {
        return Err(Error::Shape(format!(
            "input len {} vs expected {}x{}x{}",
            input.len(),
            c,
            h,
            w
        )));
    }
    let x = MapTensor::from_nchw(input, c, h, w, params.u);
    let out = run_layers_mm(&net.layers, x, params, modes, cfg)?;
    match out {
        Activation::Flat(v) => Ok(v),
        Activation::Maps(t) => Ok(t.to_nchw()),
    }
}

enum Activation {
    Maps(MapTensor),
    Flat(Vec<f32>),
}

fn run_layers_mm(
    layers: &[Layer],
    mut x: MapTensor,
    params: &EngineParams,
    modes: &ModeAssignment,
    cfg: ExecConfig,
) -> Result<Activation> {
    let mut flat: Option<Vec<f32>> = None;
    for layer in layers {
        if let Some(v) = flat.take() {
            // Flat activations admit only dense/softmax layers.
            flat = Some(run_flat_layer(layer, v, params, modes)?);
            continue;
        }
        match &layer.op {
            LayerOp::Conv { m, k, s, p, relu } => {
                let lp = params.get(&layer.name)?;
                let mode = modes.mode_of(&layer.name);
                // The legacy behaviour under measurement: parameters are
                // cast into the mode's domain on *every* call.
                let w_cast;
                let w_mm: &[f32] = if mode == ArithMode::Precise {
                    &lp.w_mm
                } else {
                    w_cast = cast_weights(&lp.w_mm, mode);
                    &w_cast
                };
                x = conv_mm(&x, w_mm, &lp.b_mm, *m, *k, *s, *p, *relu, mode, cfg.threads);
            }
            LayerOp::MaxPool { k, s, p } => x = ops::maxpool_mm(&x, *k, *s, *p),
            LayerOp::AvgPool { k, s, p } => x = ops::avgpool_mm(&x, *k, *s, *p),
            LayerOp::Lrn { size, alpha, beta } => x = ops::lrn_mm(&x, *size, *alpha, *beta),
            LayerOp::Fork { branches } => {
                let mut outs = Vec::with_capacity(branches.len());
                for br in branches {
                    match run_layers_mm(br, x.clone(), params, modes, cfg)? {
                        Activation::Maps(t) => outs.push(t),
                        Activation::Flat(_) => {
                            return Err(Error::Invalid(format!(
                                "fork {}: branch produced flat activation",
                                layer.name
                            )))
                        }
                    }
                }
                let refs: Vec<&MapTensor> = outs.iter().collect();
                x = MapTensor::concat_channels(&refs);
            }
            LayerOp::Flatten => flat = Some(x.flatten()),
            LayerOp::Gap => flat = Some(ops::gap_mm(&x)),
            LayerOp::Dense { .. } | LayerOp::Softmax => {
                return Err(Error::Invalid(format!(
                    "layer {}: dense/softmax requires flatten or gap first",
                    layer.name
                )))
            }
        }
    }
    Ok(match flat {
        Some(v) => Activation::Flat(v),
        None => Activation::Maps(x),
    })
}

fn run_flat_layer(
    layer: &Layer,
    v: Vec<f32>,
    params: &EngineParams,
    modes: &ModeAssignment,
) -> Result<Vec<f32>> {
    match &layer.op {
        LayerOp::Dense { o, relu } => {
            let lp = params.get(&layer.name)?;
            let mode = modes.mode_of(&layer.name);
            let w_cast;
            let w: &[f32] = if mode == ArithMode::Precise {
                &lp.w_mm
            } else {
                w_cast = cast_weights(&lp.w_mm, mode);
                &w_cast
            };
            Ok(ops::dense(&v, w, &lp.b_mm, *o, *relu, mode))
        }
        LayerOp::Softmax => Ok(ops::softmax(&v)),
        other => Err(Error::Invalid(format!(
            "layer {}: op {other:?} cannot consume a flat activation",
            layer.name
        ))),
    }
}

/// The pre-plan baseline interpreter (single-threaded scalar row-major,
/// precise). Parity oracle for [`PlanBuilder::baseline`] plans.
pub fn run_baseline_legacy(
    net: &Network,
    params: &EngineParams,
    input: &[f32],
) -> Result<Vec<f32>> {
    let (c, h, w) = net.input.as_maps()?;
    if input.len() != c * h * w {
        return Err(Error::Shape(format!("input len {}", input.len())));
    }
    let out = run_layers_nchw(&net.layers, (input.to_vec(), c, h, w), params)?;
    Ok(match out {
        BaselineAct::Maps(v, ..) => v,
        BaselineAct::Flat(v) => v,
    })
}

enum BaselineAct {
    Maps(Vec<f32>, usize, usize, usize),
    Flat(Vec<f32>),
}

fn run_layers_nchw(
    layers: &[Layer],
    input: (Vec<f32>, usize, usize, usize),
    params: &EngineParams,
) -> Result<BaselineAct> {
    let (mut x, mut c, mut h, mut w) = input;
    let mut flat: Option<Vec<f32>> = None;
    for layer in layers {
        if let Some(v) = flat.take() {
            flat = Some(match &layer.op {
                LayerOp::Dense { o, relu } => {
                    let lp = params.get(&layer.name)?;
                    ops::dense(&v, &lp.w_conv, &lp.b_conv, *o, *relu, ArithMode::Precise)
                }
                LayerOp::Softmax => ops::softmax(&v),
                other => {
                    return Err(Error::Invalid(format!(
                        "baseline: {other:?} after flatten"
                    )))
                }
            });
            continue;
        }
        match &layer.op {
            LayerOp::Conv { m, k, s, p, relu } => {
                let lp = params.get(&layer.name)?;
                let (out, ho, wo) = conv_nchw_scalar(
                    &x, c, h, w, &lp.w_conv, &lp.b_conv, *m, *k, *s, *p, *relu,
                    ArithMode::Precise,
                );
                x = out;
                c = *m;
                h = ho;
                w = wo;
            }
            LayerOp::MaxPool { k, s, p } | LayerOp::AvgPool { k, s, p } => {
                let is_max = matches!(layer.op, LayerOp::MaxPool { .. });
                let (out, ho, wo) = ops::pool_nchw(&x, c, h, w, *k, *s, *p, is_max);
                x = out;
                h = ho;
                w = wo;
            }
            LayerOp::Lrn { size, alpha, beta } => {
                x = ops::lrn_nchw(&x, c, h, w, *size, *alpha, *beta);
            }
            LayerOp::Fork { branches } => {
                let mut outs = Vec::new();
                let mut total_c = 0;
                let mut hw = (0, 0);
                for br in branches {
                    match run_layers_nchw(br, (x.clone(), c, h, w), params)? {
                        BaselineAct::Maps(v, bc, bh, bw) => {
                            total_c += bc;
                            hw = (bh, bw);
                            outs.push(v);
                        }
                        BaselineAct::Flat(_) => {
                            return Err(Error::Invalid("baseline: flat in fork".into()))
                        }
                    }
                }
                x = outs.concat();
                c = total_c;
                h = hw.0;
                w = hw.1;
            }
            LayerOp::Flatten => flat = Some(x.clone()),
            LayerOp::Gap => flat = Some(ops::gap_nchw(&x, c, h, w)),
            LayerOp::Dense { .. } | LayerOp::Softmax => {
                return Err(Error::Invalid("baseline: dense before flatten".into()))
            }
        }
    }
    Ok(match flat {
        Some(v) => BaselineAct::Flat(v),
        None => BaselineAct::Maps(x, c, h, w),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    fn rand_input(net: &Network, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(net.input.elements())
    }

    #[test]
    fn mapmajor_matches_baseline_tinynet() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 42, 4).unwrap();
        let input = rand_input(&net, 7);
        let base = run_baseline(&net, &params, &input).unwrap();
        let opt = run_mapmajor(
            &net,
            &params,
            &input,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 1, ..Default::default() },
        )
        .unwrap();
        assert_eq!(base.len(), 8);
        for (a, b) in base.iter().zip(&opt) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn wrapper_is_bitwise_identical_to_legacy() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 17, 4).unwrap();
        let input = rand_input(&net, 18);
        for mode in ArithMode::ALL {
            let modes = ModeAssignment::uniform(mode);
            for threads in [1, 2] {
                let cfg = ExecConfig { threads, ..Default::default() };
                let a = run_mapmajor(&net, &params, &input, &modes, cfg).unwrap();
                let b = run_mapmajor_legacy(&net, &params, &input, &modes, cfg).unwrap();
                assert_eq!(a, b, "mode={mode} threads={threads}");
            }
        }
        let a = run_baseline(&net, &params, &input).unwrap();
        let b = run_baseline_legacy(&net, &params, &input).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn threaded_matches_single_thread() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 1, 4).unwrap();
        let input = rand_input(&net, 2);
        let modes = ModeAssignment::uniform(ArithMode::Precise);
        let a = run_mapmajor(&net, &params, &input, &modes, ExecConfig::default()).unwrap();
        let b = run_mapmajor(
            &net,
            &params,
            &input,
            &modes,
            ExecConfig { threads: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn fork_network_matches_baseline() {
        // SqueezeNet head (conv1..fire3) at reduced input via a custom net.
        use crate::config::parse_cappnet;
        let net = parse_cappnet(
            "net mini\ninput 3 31 31\nclasses 32\n\
             conv conv1 m=16 k=3 s=2 p=0\n\
             fire fire2 s1=8 e1=16 e3=16\n\
             fire fire3 s1=8 e1=16 e3=16\n\
             conv conv4 m=32 k=1 s=1 p=0\ngap\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 3, 4).unwrap();
        let input = rand_input(&net, 4);
        let base = run_baseline(&net, &params, &input).unwrap();
        let opt = run_mapmajor(
            &net,
            &params,
            &input,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 2, ..Default::default() },
        )
        .unwrap();
        for (a, b) in base.iter().zip(&opt) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn lrn_network_matches_baseline() {
        use crate::config::parse_cappnet;
        let net = parse_cappnet(
            "net lrnnet\ninput 3 16 16\nclasses 8\n\
             conv conv1 m=8 k=3 s=1 p=1\nlrn size=5\n\
             conv conv2 m=8 k=3 s=2 p=1\nflatten\ndense fc o=8 relu=0\n",
        )
        .unwrap();
        let params = EngineParams::random(&net, 5, 4).unwrap();
        let input = rand_input(&net, 6);
        let base = run_baseline(&net, &params, &input).unwrap();
        let opt = run_mapmajor(
            &net,
            &params,
            &input,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig::default(),
        )
        .unwrap();
        for (a, b) in base.iter().zip(&opt) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn per_layer_modes_differ_monotonically() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 9, 4).unwrap();
        let input = rand_input(&net, 10);
        let cfg = ExecConfig::default();
        let precise = run_mapmajor(
            &net, &params, &input,
            &ModeAssignment::uniform(ArithMode::Precise), cfg,
        )
        .unwrap();
        let one_layer = run_mapmajor(
            &net, &params, &input,
            &ModeAssignment::uniform(ArithMode::Precise).with("conv1", ArithMode::Imprecise),
            cfg,
        )
        .unwrap();
        let all = run_mapmajor(
            &net, &params, &input,
            &ModeAssignment::uniform(ArithMode::Imprecise), cfg,
        )
        .unwrap();
        let d1: f32 = precise.iter().zip(&one_layer).map(|(a, b)| (a - b).abs()).sum();
        let da: f32 = precise.iter().zip(&all).map(|(a, b)| (a - b).abs()).sum();
        assert!(d1 > 0.0, "imprecise conv1 must perturb logits");
        assert!(da >= d1, "all-imprecise must perturb at least as much");
    }

    #[test]
    fn mode_assignment_helpers() {
        let ma = ModeAssignment::uniform(ArithMode::Precise)
            .with("a", ArithMode::Imprecise)
            .with("b", ArithMode::Relaxed);
        assert_eq!(ma.mode_of("a"), ArithMode::Imprecise);
        assert_eq!(ma.mode_of("zzz"), ArithMode::Precise);
        assert_eq!(
            ma.inexact_count(&["a".into(), "b".into(), "c".into()]),
            2
        );
    }

    #[test]
    fn compile_rejects_wrong_shapes() {
        use crate::config::modelfile::{ModelFile, NamedTensor};
        let net = zoo::tinynet();
        let mut mf = ModelFile::new();
        // Wrong weight size for conv1.
        mf.insert("conv1/w", NamedTensor::new(vec![2], vec![0.0, 0.0]));
        mf.insert("conv1/b", NamedTensor::new(vec![16], vec![0.0; 16]));
        assert!(EngineParams::compile(&net, &mf, 4).is_err());
    }

    #[test]
    fn bad_input_len_rejected() {
        let net = zoo::tinynet();
        let params = EngineParams::random(&net, 0, 4).unwrap();
        assert!(run_baseline(&net, &params, &[0.0; 3]).is_err());
        assert!(run_baseline_legacy(&net, &params, &[0.0; 3]).is_err());
    }

    #[test]
    fn oversized_window_is_shape_error() {
        use crate::config::parse_cappnet;
        // k=7 over a 4x4 input with no padding: both executors must
        // reject with Error::Shape instead of underflowing/panicking.
        let net = parse_cappnet(
            "net bad\ninput 3 4 4\nclasses 4\nconv c1 m=4 k=7 s=1 p=0\ngap\n",
        )
        .unwrap();
        // Param construction itself shape-infers; build params against a
        // compatible net, then run against the bad one to isolate the
        // executor-side validation.
        match EngineParams::random(&net, 0, 4) {
            Err(Error::Shape(_)) => {}
            Err(e) => panic!("expected shape error, got {e}"),
            Ok(params) => {
                let modes = ModeAssignment::uniform(ArithMode::Precise);
                let r = run_mapmajor(&net, &params, &[0.0; 48], &modes, ExecConfig::default());
                assert!(matches!(r, Err(Error::Shape(_))));
                let r = run_baseline(&net, &params, &[0.0; 48]);
                assert!(matches!(r, Err(Error::Shape(_))));
            }
        }
    }
}
