//! Model zoo: Rust-side builders for the paper's networks.
//!
//! These mirror `python/compile/model.py` exactly (same layer names,
//! same expansion of fire/inception composites); the integration tests
//! cross-check them against the spec embedded in the AOT manifest.

use crate::model::{Layer, LayerOp, Network, TensorShape};

fn conv(name: &str, m: usize, k: usize, s: usize, p: usize) -> Layer {
    Layer::new(name, LayerOp::Conv { m, k, s, p, relu: true })
}

fn maxpool(name: &str, k: usize, s: usize, p: usize) -> Layer {
    Layer::new(name, LayerOp::MaxPool { k, s, p })
}

fn lrn(name: &str) -> Layer {
    Layer::new(name, LayerOp::Lrn { size: 5, alpha: 1e-4, beta: 0.75 })
}

/// SqueezeNet fire module: squeeze 1x1, then fork(expand 1x1, expand 3x3).
fn fire(name: &str, s1: usize, e1: usize, e3: usize) -> Vec<Layer> {
    vec![
        conv(&format!("{name}/s1"), s1, 1, 1, 0),
        Layer::new(
            name,
            LayerOp::Fork {
                branches: vec![
                    vec![conv(&format!("{name}/e1"), e1, 1, 1, 0)],
                    vec![conv(&format!("{name}/e3"), e3, 3, 1, 1)],
                ],
            },
        ),
    ]
}

/// GoogLeNet inception module: 4 branches channel-concatenated.
fn inception(name: &str, b1: usize, b3r: usize, b3: usize, b5r: usize, b5: usize, pp: usize) -> Layer {
    Layer::new(
        name,
        LayerOp::Fork {
            branches: vec![
                vec![conv(&format!("{name}/b1"), b1, 1, 1, 0)],
                vec![
                    conv(&format!("{name}/b3r"), b3r, 1, 1, 0),
                    conv(&format!("{name}/b3"), b3, 3, 1, 1),
                ],
                vec![
                    conv(&format!("{name}/b5r"), b5r, 1, 1, 0),
                    conv(&format!("{name}/b5"), b5, 5, 1, 2),
                ],
                vec![
                    maxpool(&format!("{name}/pool"), 3, 1, 1),
                    conv(&format!("{name}/pp"), pp, 1, 1, 0),
                ],
            ],
        },
    )
}

/// TinyNet: the build-time-trained CNN for the inexact-computing study.
pub fn tinynet() -> Network {
    Network {
        name: "tinynet".into(),
        input: TensorShape::maps(3, 16, 16),
        classes: 8,
        layers: vec![
            conv("conv1", 16, 3, 1, 1),
            maxpool("pool1", 2, 2, 0),
            conv("conv2", 32, 3, 1, 1),
            maxpool("pool2", 2, 2, 0),
            conv("conv3", 32, 3, 1, 1),
            Layer::new("flatten", LayerOp::Flatten),
            Layer::new("fc4", LayerOp::Dense { o: 64, relu: true }),
            Layer::new("fc5", LayerOp::Dense { o: 8, relu: false }),
        ],
    }
}

/// AlexNet (CaffeNet single-tower variant, group=1 — DESIGN.md).
pub fn alexnet() -> Network {
    Network {
        name: "alexnet".into(),
        input: TensorShape::maps(3, 227, 227),
        classes: 1000,
        layers: vec![
            conv("conv1", 96, 11, 4, 0),
            lrn("lrn1"),
            maxpool("pool1", 3, 2, 0),
            conv("conv2", 256, 5, 1, 2),
            lrn("lrn2"),
            maxpool("pool2", 3, 2, 0),
            conv("conv3", 384, 3, 1, 1),
            conv("conv4", 384, 3, 1, 1),
            conv("conv5", 256, 3, 1, 1),
            maxpool("pool5", 3, 2, 0),
            Layer::new("flatten", LayerOp::Flatten),
            Layer::new("fc6", LayerOp::Dense { o: 4096, relu: true }),
            Layer::new("fc7", LayerOp::Dense { o: 4096, relu: true }),
            Layer::new("fc8", LayerOp::Dense { o: 1000, relu: false }),
        ],
    }
}

/// SqueezeNet v1.0.
pub fn squeezenet() -> Network {
    let mut layers = vec![conv("conv1", 96, 7, 2, 0), maxpool("pool1", 3, 2, 0)];
    layers.extend(fire("fire2", 16, 64, 64));
    layers.extend(fire("fire3", 16, 64, 64));
    layers.extend(fire("fire4", 32, 128, 128));
    layers.push(maxpool("pool4", 3, 2, 0));
    layers.extend(fire("fire5", 32, 128, 128));
    layers.extend(fire("fire6", 48, 192, 192));
    layers.extend(fire("fire7", 48, 192, 192));
    layers.extend(fire("fire8", 64, 256, 256));
    layers.push(maxpool("pool8", 3, 2, 0));
    layers.extend(fire("fire9", 64, 256, 256));
    layers.push(conv("conv10", 1000, 1, 1, 0));
    layers.push(Layer::new("gap", LayerOp::Gap));
    Network {
        name: "squeezenet".into(),
        input: TensorShape::maps(3, 227, 227),
        classes: 1000,
        layers,
    }
}

/// GoogLeNet / Inception-v1, main branch (aux heads are train-time only).
pub fn googlenet() -> Network {
    Network {
        name: "googlenet".into(),
        input: TensorShape::maps(3, 224, 224),
        classes: 1000,
        layers: vec![
            conv("conv1", 64, 7, 2, 3),
            maxpool("pool1", 3, 2, 1),
            lrn("lrn1"),
            conv("conv2r", 64, 1, 1, 0),
            conv("conv2", 192, 3, 1, 1),
            lrn("lrn2"),
            maxpool("pool2", 3, 2, 1),
            inception("inc3a", 64, 96, 128, 16, 32, 32),
            inception("inc3b", 128, 128, 192, 32, 96, 64),
            maxpool("pool3", 3, 2, 1),
            inception("inc4a", 192, 96, 208, 16, 48, 64),
            inception("inc4b", 160, 112, 224, 24, 64, 64),
            inception("inc4c", 128, 128, 256, 24, 64, 64),
            inception("inc4d", 112, 144, 288, 32, 64, 64),
            inception("inc4e", 256, 160, 320, 32, 128, 128),
            maxpool("pool4", 3, 2, 1),
            inception("inc5a", 256, 160, 320, 32, 128, 128),
            inception("inc5b", 384, 192, 384, 48, 128, 128),
            Layer::new("gap", LayerOp::Gap),
            Layer::new("fc", LayerOp::Dense { o: 1000, relu: false }),
        ],
    }
}

/// Look a network up by name.
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "tinynet" => Some(tinynet()),
        "alexnet" => Some(alexnet()),
        "squeezenet" => Some(squeezenet()),
        "googlenet" => Some(googlenet()),
        _ => None,
    }
}

/// All networks evaluated in the paper's Table I, plus TinyNet.
pub fn all() -> Vec<Network> {
    vec![tinynet(), alexnet(), squeezenet(), googlenet()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::shapes;

    #[test]
    fn by_name_roundtrip() {
        for net in all() {
            assert_eq!(by_name(&net.name).unwrap().name, net.name);
        }
        assert!(by_name("vgg").is_none());
    }

    #[test]
    fn all_networks_infer() {
        for net in all() {
            let info = shapes::infer(&net).unwrap();
            assert_eq!(
                info.output,
                TensorShape::Flat { len: net.classes },
                "{}",
                net.name
            );
        }
    }

    #[test]
    fn mode_layer_counts_match_python() {
        // Mirrors python tests: tinynet 5, alexnet 8, squeezenet 26,
        // googlenet 58 parameterised layers.
        assert_eq!(tinynet().param_layer_names().len(), 5);
        assert_eq!(alexnet().param_layer_names().len(), 8);
        assert_eq!(squeezenet().param_layer_names().len(), 26);
        assert_eq!(googlenet().param_layer_names().len(), 58);
    }

    #[test]
    fn all_conv_widths_divide_u() {
        for net in all() {
            net.visit(&mut |l| {
                if let LayerOp::Conv { m, .. } = l.op {
                    assert_eq!(m % crate::DEFAULT_U, 0, "{}/{}", net.name, l.name);
                }
            });
        }
    }
}
