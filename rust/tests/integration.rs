//! Cross-layer integration tests: the contracts between the Python
//! compile path (artifacts), the Rust model IR, the native engine, the
//! PJRT runtime, and the serving front-end.
//!
//! Tests that need `make artifacts` skip gracefully when the artifacts
//! directory is absent (CI-before-artifacts), but `make test` always
//! builds artifacts first.

use cappuccino::config::modelfile::ModelFile;
use cappuccino::config::parse_cappnet;
use cappuccino::data::Dataset;
use cappuccino::engine::{self, ArithMode, EngineParams, ExecConfig, ModeAssignment};
use cappuccino::model::zoo;
use cappuccino::runtime::{Manifest, ParamSource, Runtime};
use cappuccino::serve::{pjrt_factory, BatchPolicy, EngineBackend, Server};
use cappuccino::synth::{execute_plan, finalize, PrimarySynthesizer};
use cappuccino::util::rng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = cappuccino::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts`");
        None
    }
}

// ---------------------------------------------------------------------------
// Python <-> Rust contracts
// ---------------------------------------------------------------------------

#[test]
fn engine_matches_python_golden_logits() {
    // The native Rust engine (compile-time reorder + map-major conv) must
    // reproduce the JAX/Pallas pipeline's logits from the same weights.
    let Some(dir) = artifacts() else { return };
    let net = zoo::tinynet();
    let mf = ModelFile::read_from(dir.join("tinynet.capp")).unwrap();
    let params = EngineParams::compile(&net, &mf, 4).unwrap();
    let golden = ModelFile::read_from(dir.join("golden_tinynet.capp")).unwrap();
    let x_nchw = golden.get("x_nchw").unwrap();
    let want = golden.get("logits_precise").unwrap();
    let n_img = x_nchw.dims[0];
    let img_len: usize = x_nchw.dims[1..].iter().product();
    let classes = want.dims[1];
    for i in 0..n_img {
        let img = &x_nchw.data[i * img_len..(i + 1) * img_len];
        let logits = engine::run_mapmajor(
            &net,
            &params,
            img,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 2, ..Default::default() },
        )
        .unwrap();
        for (a, b) in logits.iter().zip(&want.data[i * classes..(i + 1) * classes]) {
            assert!((a - b).abs() < 2e-3, "image {i}: {a} vs {b}");
        }
    }
}

#[test]
fn rust_reorder_matches_python_reorder() {
    // layout::weights_to_mapmajor (Rust) vs reorder_params (Python):
    // tinynet_mm.capp was written by Python from tinynet.capp.
    let Some(dir) = artifacts() else { return };
    let conv = ModelFile::read_from(dir.join("tinynet.capp")).unwrap();
    let mm = ModelFile::read_from(dir.join("tinynet_mm.capp")).unwrap();
    for layer in ["conv1", "conv2", "conv3"] {
        let (w, b) = conv.layer_params(layer).unwrap();
        let (w_mm, b_mm) = mm.layer_params(layer).unwrap();
        let dims = &w.dims;
        let got = cappuccino::layout::weights_to_mapmajor(&w.data, dims[0], dims[1], dims[2], 4);
        assert_eq!(got, w_mm.data, "{layer}: weight reorder mismatch");
        let got_b = cappuccino::layout::bias_to_mapmajor(&b.data, 4);
        assert_eq!(got_b, b_mm.data, "{layer}: bias reorder mismatch");
    }
    // First FC after flatten: column permutation.
    let (w, _) = conv.layer_params("fc4").unwrap();
    let (w_mm, _) = mm.layer_params("fc4").unwrap();
    let got = cappuccino::layout::fc_weights_for_mapmajor(&w.data, 64, 32, 4, 4, 4);
    assert_eq!(got, w_mm.data, "fc4: FC reorder mismatch");
}

#[test]
fn engine_matches_pjrt_runtime() {
    // Same weights, same input: native engine vs compiled artifact.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::new().unwrap();
    let spec = manifest.find("tinynet", "precise", 1).unwrap();
    let mm_weights = ModelFile::read_from(dir.join("tinynet_mm.capp")).unwrap();
    let model = rt
        .load(&manifest, spec, &ParamSource::MapMajorFile(mm_weights))
        .unwrap();

    let net = zoo::tinynet();
    let conv_weights = ModelFile::read_from(dir.join("tinynet.capp")).unwrap();
    let params = EngineParams::compile(&net, &conv_weights, 4).unwrap();

    let mut rng = Rng::new(77);
    for trial in 0..4 {
        let img = rng.normal_vec(net.input.elements());
        let x_mm = cappuccino::layout::nchw_to_mapmajor(&img, 3, 16, 16, 4);
        let pjrt_logits = model.infer(&x_mm).unwrap();
        let engine_logits = engine::run_mapmajor(
            &net,
            &params,
            &img,
            &ModeAssignment::uniform(ArithMode::Precise),
            ExecConfig { threads: 1, ..Default::default() },
        )
        .unwrap();
        for (a, b) in pjrt_logits.iter().zip(&engine_logits) {
            assert!((a - b).abs() < 2e-3, "trial {trial}: pjrt {a} vs engine {b}");
        }
    }
}

#[test]
fn dataset_file_loads_and_is_balanced() {
    let Some(dir) = artifacts() else { return };
    let d = Dataset::read_from(dir.join("dataset.bin")).unwrap();
    assert_eq!((d.c, d.h, d.w), (3, 16, 16));
    assert_eq!(d.classes, 8);
    assert!(d.n_train > 0 && d.n_train < d.len());
    let (val, labels) = d.validation();
    assert_eq!(val.len(), labels.len());
    let mut counts = vec![0usize; d.classes];
    for &l in labels {
        counts[l as usize] += 1;
    }
    assert!(counts.iter().all(|&c| c > 0), "validation split unbalanced: {counts:?}");
}

// ---------------------------------------------------------------------------
// Synthesis pipeline end to end
// ---------------------------------------------------------------------------

#[test]
fn cappnet_to_plan_to_execution() {
    // Fig. 3 end to end on a custom net without any artifacts.
    let net = parse_cappnet(
        "net pipe\ninput 3 24 24\nclasses 16\n\
         conv c1 m=16 k=3 s=1 p=1\nmaxpool k=2 s=2\n\
         fire f2 s1=8 e1=16 e3=16\n\
         conv c3 m=16 k=1 s=1 p=0\ngap\n",
    )
    .unwrap();
    let params = EngineParams::random(&net, 11, 4).unwrap();
    let primary = PrimarySynthesizer::new(4, 2).synthesize(&net).unwrap();
    let plan = finalize(&primary, &ModeAssignment::uniform(ArithMode::Imprecise));

    let mut rng = Rng::new(5);
    let img = rng.normal_vec(net.input.elements());
    let logits = execute_plan(&plan, &net, &params, &img).unwrap();
    assert_eq!(logits.len(), 16);
    assert!(logits.iter().all(|v| v.is_finite()));

    // The plan's imprecise execution stays close to precise.
    let precise = execute_plan(&primary, &net, &params, &img).unwrap();
    let max_rel: f32 = logits
        .iter()
        .zip(&precise)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0, f32::max);
    assert!(max_rel < 0.1, "imprecise drifted {max_rel}");
}

#[test]
fn full_analysis_to_serving_flow() {
    // synthesize -> analyze -> finalize -> serve on the native engine.
    let Some(dir) = artifacts() else { return };
    let net = zoo::tinynet();
    let mf = ModelFile::read_from(dir.join("tinynet.capp")).unwrap();
    let params = EngineParams::compile(&net, &mf, 4).unwrap();
    let dataset = Dataset::read_from(dir.join("dataset.bin")).unwrap();
    let cfg = cappuccino::inexact::AnalysisConfig {
        max_accuracy_drop: 0.02,
        max_images: 64,
        threads: 1,
    };
    let report = cappuccino::inexact::analyze(&net, &params, &dataset, &cfg).unwrap();
    assert!(report.inexact_layers() >= 4, "trained tinynet should go inexact");

    let backend = EngineBackend::new(net, params, report.assignment, 1, 8);
    let server = Server::start(vec![(
        "tinynet".into(),
        backend.factory(),
        BatchPolicy::default(),
    )])
    .unwrap();
    let (val, labels) = dataset.validation();
    let mut correct = 0;
    let n = 32;
    for i in 0..n {
        let resp = server
            .router()
            .infer_blocking("tinynet", val[i].clone())
            .unwrap();
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if pred == labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct as f64 / n as f64 > 0.85, "served accuracy {correct}/{n}");
    server.shutdown();
}

#[test]
fn pjrt_serving_end_to_end() {
    // The production path: router -> batcher -> PJRT worker -> response.
    let Some(dir) = artifacts() else { return };
    let factory = pjrt_factory(dir.clone(), "tinynet".into(), "imprecise".into(), None);
    let server = Server::start(vec![(
        "tinynet".into(),
        factory,
        BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(5),
            queue_depth: 64,
            ..Default::default()
        },
    )])
    .unwrap();
    let dataset = Dataset::read_from(dir.join("dataset.bin")).unwrap();
    let (val, labels) = dataset.validation();
    let rxs: Vec<_> = (0..24)
        .map(|i| server.router().submit("tinynet", val[i].clone()).unwrap())
        .collect();
    let mut correct = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        if pred == labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct >= 20, "pjrt served accuracy {correct}/24");
    let m = server.metrics();
    assert!(m.counters.mean_batch_size() > 1.0, "batcher never batched");
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Simulator consistency with the synthesized plans
// ---------------------------------------------------------------------------

#[test]
fn simulator_and_plan_predictions_consistent() {
    use cappuccino::soc::{self, ProcessingMode};
    for net in [zoo::alexnet(), zoo::squeezenet()] {
        for device in soc::catalog() {
            let primary = PrimarySynthesizer::new(4, device.cores)
                .synthesize(&net)
                .unwrap();
            let imprecise_plan =
                finalize(&primary, &ModeAssignment::uniform(ArithMode::Imprecise));
            let plan_ms = cappuccino::synth::predict_latency_ms(&imprecise_plan, &net, &device);
            let sim_par = soc::simulate(&net, &device, ProcessingMode::Parallel).total_ms();
            let sim_imp = soc::simulate(&net, &device, ProcessingMode::Imprecise).total_ms();
            // The all-imprecise plan must land between the two pure
            // simulator endpoints (pool/LRN layers stay at parallel rate).
            assert!(
                plan_ms >= sim_imp * 0.99 && plan_ms <= sim_par * 1.01,
                "{}/{}: plan {plan_ms} vs [{sim_imp}, {sim_par}]",
                net.name,
                device.name
            );
        }
    }
}

#[test]
fn cli_binary_info_runs() {
    // The built binary must at least run `info` (no artifacts needed).
    let exe = env!("CARGO_BIN_EXE_cappuccino");
    let out = std::process::Command::new(exe)
        .arg("info")
        .output()
        .expect("run cappuccino info");
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("alexnet"));
    assert!(stdout.contains("Nexus 5"));
}
