//! End-to-end serving validation (DESIGN.md "End-to-end validation").
//!
//! Loads the **trained** TinyNet through the full AOT stack — Pallas
//! kernels lowered by JAX to HLO text, compiled by PJRT, weights from
//! `tinynet_mm.capp` — and serves batched classification requests from
//! the real validation set through the L3 router + dynamic batcher.
//!
//! Reports: end-to-end accuracy (the model must actually classify),
//! latency percentiles, throughput, and mean batch size under three
//! client arrival patterns. Results are recorded in EXPERIMENTS.md.
//!
//! Run (needs `make artifacts`):
//! `cargo run --release --example serve_batch`

use std::time::{Duration, Instant};

use cappuccino::data::Dataset;
use cappuccino::engine::ops::softmax;
use cappuccino::serve::{pjrt_factory, BatchPolicy, Server};

fn main() -> cappuccino::Result<()> {
    let dir = cappuccino::artifacts_dir();
    let dataset = Dataset::read_from(dir.join("dataset.bin"))?;
    let (val_images, val_labels) = dataset.validation();
    println!(
        "validation set: {} images ({} classes)",
        val_images.len(),
        dataset.classes
    );

    for (scenario, mode, n_requests, inter_arrival) in [
        ("closed-loop burst", "imprecise", 256usize, Duration::ZERO),
        ("open-loop 500 rps", "imprecise", 128, Duration::from_millis(2)),
        ("precise burst", "precise", 128, Duration::ZERO),
    ] {
        let factory = pjrt_factory(dir.clone(), "tinynet".into(), mode.into(), None);
        let policy = BatchPolicy {
            max_batch: 8,
            max_delay: Duration::from_millis(2),
            queue_depth: 512,
            ..Default::default()
        };
        let server = Server::start(vec![("tinynet".into(), factory, policy)])?;

        let t0 = Instant::now();
        let mut receivers = Vec::with_capacity(n_requests);
        for i in 0..n_requests {
            let img = val_images[i % val_images.len()].clone();
            receivers.push((i, server.router().submit("tinynet", img)?));
            if !inter_arrival.is_zero() {
                std::thread::sleep(inter_arrival);
            }
        }
        let mut correct = 0usize;
        for (i, rx) in receivers {
            let resp = rx
                .recv()
                .map_err(|_| cappuccino::Error::Serve("lost response".into()))?;
            let probs = softmax(&resp.logits);
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(c, _)| c)
                .unwrap();
            if pred == val_labels[i % val_labels.len()] as usize {
                correct += 1;
            }
        }
        let wall = t0.elapsed();
        let m = server.metrics();
        let accuracy = correct as f64 / n_requests as f64;
        println!("\n=== {scenario} (mode={mode}, n={n_requests}) ===");
        println!(
            "accuracy {:.4}  wall {:.2?}  throughput {:.1} img/s  mean batch {:.2}",
            accuracy,
            wall,
            n_requests as f64 / wall.as_secs_f64(),
            m.counters.mean_batch_size()
        );
        println!("latency: {}", m.latency.summary());
        assert!(
            accuracy > 0.9,
            "{scenario}: served accuracy {accuracy} — model or pipeline broken"
        );
        server.shutdown();
    }
    println!("\nserve_batch OK");
    Ok(())
}
