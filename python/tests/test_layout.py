"""Layout transforms and the paper's index equations (3)-(5)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile.kernels import ref

SETTINGS = dict(max_examples=50, deadline=None)


class TestMapMajor:
    @hypothesis.given(c=st.integers(1, 20), h=st.integers(1, 10),
                      w=st.integers(1, 10), u=st.sampled_from([1, 2, 4, 8]))
    @hypothesis.settings(**SETTINGS)
    def test_roundtrip(self, c, h, w, u):
        rng = np.random.default_rng(hash((c, h, w, u)) % 2**32)
        x = jnp.asarray(rng.standard_normal((c, h, w)), jnp.float32)
        back = ref.mapmajor_to_nchw(ref.nchw_to_mapmajor(x, u), c)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    def test_matches_paper_order_u4(self):
        # Eq. (2) of the paper: (0,0,0),(1,0,0),(2,0,0),(3,0,0),(0,0,1)...
        c, h, w, u = 8, 2, 3, 4
        x = jnp.arange(c * h * w, dtype=jnp.float32).reshape(c, h, w)
        mm = np.asarray(ref.nchw_to_mapmajor(x, u)).reshape(-1)

        def elem(layer, row, col):
            return float(x[layer, row, col])

        # First vector: channels 0..3 at (0,0); second: channels 0..3 at (0,1)
        assert list(mm[:4]) == [elem(0, 0, 0), elem(1, 0, 0),
                                elem(2, 0, 0), elem(3, 0, 0)]
        assert list(mm[4:8]) == [elem(0, 0, 1), elem(1, 0, 1),
                                 elem(2, 0, 1), elem(3, 0, 1)]
        # Second stack (channels 4..7) starts after the full first stack.
        assert mm[h * w * u] == elem(4, 0, 0)

    def test_channel_padding_zeroes(self):
        x = jnp.ones((3, 2, 2), jnp.float32)
        mm = np.asarray(ref.nchw_to_mapmajor(x, 4))
        assert mm.shape == (1, 2, 2, 4)
        np.testing.assert_array_equal(mm[..., 3], 0.0)
        np.testing.assert_array_equal(mm[..., :3], 1.0)

    @hypothesis.given(m=st.integers(1, 12), c=st.integers(1, 9),
                      k=st.sampled_from([1, 3, 5]),
                      u=st.sampled_from([2, 4]))
    @hypothesis.settings(**SETTINGS)
    def test_weight_reorder_roundtrip(self, m, c, k, u):
        rng = np.random.default_rng(hash((m, c, k, u)) % 2**32)
        w = jnp.asarray(rng.standard_normal((m, c, k, k)), jnp.float32)
        w_mm = np.asarray(ref.weights_to_mapmajor(w, u))
        mb = -(-m // u)
        cb = -(-c // u)
        assert w_mm.shape == (mb, u, cb, k, k, u)
        for mi in range(m):
            for ci in range(c):
                np.testing.assert_array_equal(
                    w_mm[mi // u, mi % u, ci // u, :, :, ci % u],
                    np.asarray(w[mi, ci]))

    def test_bias_reorder(self):
        b = jnp.arange(6, dtype=jnp.float32)
        bm = np.asarray(ref.bias_to_mapmajor(b, 4))
        assert bm.shape == (2, 4)
        np.testing.assert_array_equal(bm.reshape(-1)[:6], np.arange(6))
        np.testing.assert_array_equal(bm.reshape(-1)[6:], 0.0)


class TestIndexEquations:
    @hypothesis.given(u=st.sampled_from([1, 2, 4, 8]),
                      wout=st.integers(1, 9), hout=st.integers(1, 9),
                      stacks=st.integers(1, 4))
    @hypothesis.settings(**SETTINGS)
    def test_bijection(self, u, wout, hout, stacks):
        """Eqs. (3)-(5) are a bijection thread-id <-> (w, h, m)."""
        total = u * wout * hout * stacks
        seen = set()
        for x in range(total):
            w, h, m = ref.thread_index_to_whm(x, u, wout, hout)
            assert 0 <= w < wout and 0 <= h < hout and 0 <= m < stacks * u
            assert ref.whm_to_thread_index(w, h, m, u, wout, hout) == x
            seen.add((w, h, m))
        assert len(seen) == total

    def test_paper_example_second_thread(self):
        # Section IV.B.1: "the second element of the output memory must
        # contain (m=1, h=0, w=0)" after reordering.
        w, h, m = ref.thread_index_to_whm(1, 4, 5, 5)
        assert (m, h, w) == (1, 0, 0)

    def test_mapmajor_linear_offset_agrees_with_layout(self):
        """Eq. (3)-(5) indexing == actual memory order of the mm tensor."""
        m_total, hout, wout, u = 8, 3, 4, 4
        x = np.arange(m_total * hout * wout, dtype=np.float32).reshape(
            m_total, hout, wout)
        mm = np.asarray(ref.nchw_to_mapmajor(jnp.asarray(x), u)).reshape(-1)
        for t in range(mm.size):
            w, h, m = ref.thread_index_to_whm(t, u, wout, hout)
            assert mm[t] == x[m, h, w]
